"""Fig. 7 reproduction: generation throughput (Eq. 12) per (model x mode).

Paper: LLM-CoOpt raises throughput 5.7-12.1% over unmodified vLLM across the
five LLaMa variants. Same protocol as fig6 (shared workload), reporting
tokens/s and the relative gain vs Original.
"""
from __future__ import annotations

from repro.configs.paper_models import PAPER_MODELS, bench_reduced
from repro.core.coopt import MODES

from benchmarks.common import run_engine_workload, write_csv
from benchmarks.fig6_latency import MODELS


def run(requests: int = 8, max_new_tokens: int = 12, quick: bool = False):
    models = MODELS[:2] if quick else MODELS
    rows = []
    for name in models:
        cfg = bench_reduced(PAPER_MODELS[name])
        base = None
        for mode, coopt in MODES.items():
            m = run_engine_workload(cfg, coopt, requests=requests,
                                    max_new_tokens=max_new_tokens, seed=7)
            thr = m["throughput_tok_s"]
            if mode == "original":
                base = thr
            gain = 100.0 * (thr - base) / base
            rows.append([name, mode, thr, m["generated_tokens"],
                         round(gain, 2)])
            print(f"fig7 {name:20s} {mode:9s} thr={thr:8.2f} tok/s"
                  f"  gain_vs_original={gain:+.1f}%", flush=True)
    path = write_csv("fig7_throughput.csv",
                     ["model", "mode", "throughput_tok_s",
                      "generated_tokens", "gain_vs_original_pct"], rows)
    return path, rows


if __name__ == "__main__":
    run()
