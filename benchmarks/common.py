"""Shared benchmark utilities."""
from __future__ import annotations

import copy
import os
import time
from typing import Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def ensure_results_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def write_csv(name: str, header: List[str], rows: List[List]) -> str:
    path = os.path.join(ensure_results_dir(), name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def run_engine_workload(cfg, coopt, *, requests: int = 8, num_lanes: int = 3,
                        max_len: int = 256, max_new_tokens: int = 12,
                        scale: float = 0.1, seed: int = 0,
                        warmup: bool = True, num_shards: int = 1,
                        cache=None) -> Dict:
    """One (model, mode) cell of Figs. 6-7: a fixed synthetic ShareGPT mix
    through the continuous-batching engine. Returns Eq. 11/12 metrics
    measured AFTER a warmup pass (jit compile excluded, like the paper's
    steady-state serving numbers). ``cache``: optional CacheConfig (pool
    size override / host-DRAM spill tier)."""
    from repro.data import RequestStream
    from repro.serving import Engine, EngineConfig

    ecfg = EngineConfig(num_lanes=num_lanes, max_len=max_len,
                        prefill_buckets=(16, 32, 64, 128, max_len),
                        seed=seed, num_shards=num_shards, cache=cache)
    engine = Engine(cfg, coopt, ecfg)
    stream = RequestStream(cfg.vocab_size, seed=seed, scale=scale)
    reqs = stream.take(requests, max_new_tokens=max_new_tokens)

    if warmup:  # compile every bucket the measured pass will hit:
        # run the identical workload once, then reset stats
        for r in reqs:
            engine.add_request(copy.deepcopy(r))
        engine.run()
        engine.stats.__init__()

    t0 = time.perf_counter()
    for r in reqs:
        engine.add_request(copy.deepcopy(r))
    engine.run()
    wall = time.perf_counter() - t0
    s = engine.stats
    return {
        "generated_tokens": s.generated_tokens,
        "latency_s": round(wall, 4),                    # Eq. 11 (sum = wall
        "prefill_s": round(s.prefill_time, 4),          # in lockstep engine)
        "decode_s": round(s.decode_time, 4),
        "throughput_tok_s": round(s.generated_tokens / max(wall, 1e-9), 2),
        # per-request latency: TTFT (enqueue -> first token) and mean TPOT
        # percentiles over finished requests
        **s.latency_summary(),
        # shared-pool health (global refcounted allocator): how full the
        # pool ran and how much shared-prompt work the prefix cache saved
        "pool_pages": s.pool_pages,
        "peak_pool_utilization": round(
            s.peak_pages_in_use / max(s.pool_pages, 1), 4),
        "prefix_hit_rate": round(s.prefix_hit_rate(), 4),
        # residency-split hit accounting: device-resident vs restored from
        # the host-DRAM tier vs recomputed (miss)
        "prefix_device_hit_rate": round(s.prefix_device_hit_rate(), 4),
        "prefix_host_hit_rate": round(s.prefix_host_hit_rate(), 4),
        "prefix_miss_rate": round(s.prefix_miss_rate(), 4),
        "spilled_pages": s.spilled_pages,
        "prefetch_committed": s.prefetch_committed,
        "preemptions": s.preemptions,
        # cross-lane prefix sharing seen by decode steps (the page visits
        # the kernels' visit grid dedups; see kernels.visits) — scalar
        # counters ride in via latency_summary(), the histogram maps
        # "lanes sharing a page" -> deduped visit count
        "lanes_per_shared_page": {
            str(k): v for k, v in sorted(s.lanes_per_shared_page.items())},
        # page-range sharding health (per-shard utilization + placement)
        "kv_shards": s.num_shards,
        "shard_peak_utilization": [
            round(p / max(c, 1), 4)
            for p, c in zip(s.peak_shard_pages_in_use, s.shard_pages)],
        "shard_preemptions": list(s.shard_preemptions),
        "placement_prefix_hits": s.placement_prefix_hits,
        "placement_misses": s.placement_misses,
    }
