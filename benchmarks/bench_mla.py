"""MLA fused-latent-kernel serving benchmark -> experiments/BENCH_mla.json.

Runs the SAME synthetic ShareGPT workload through the continuous-batching
engine twice for the mla family — jnp gather reference vs the fused Pallas
latent kernels (``coopt.use_kernel``) — and records Eq. 12 tokens/s plus
per-request TPOT p50/p95, alongside the ``kernel_micro`` latent rows (jnp
wall-clock, analytic HBM traffic of gather-vs-fused, kernel parity error).

On this CPU container the kernels run in Pallas interpret mode, so the
kernel-path wall-clock numbers are NOT a TPU prediction (interpret mode is
an emulator); the HBM-traffic column is the quantity the fused kernels
actually optimize — the jnp reference materialises the lane's whole latent
history in f32 per step, the kernel streams only live fp8 pages once for
all H heads. The JSON keeps both so the perf trajectory starts recording
and TPU runs can drop straight in.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ensure_results_dir

ARCH = "deepseek-v2-lite-16b"
SERVE_KEYS = ("generated_tokens", "throughput_tok_s", "tpot_p50_s",
              "tpot_p95_s", "ttft_p50_s", "ttft_p95_s", "latency_s")


def run(quick: bool = False):
    from benchmarks.kernel_micro import latent_rows
    from repro.launch.serve import serve_workload

    requests, new_toks = (4, 6) if quick else (8, 12)
    out = {"arch": ARCH + "-reduced", "mode": "coopt",
           "note": ("CPU container: kernel path runs in Pallas interpret "
                    "mode (emulated) — compare hbm_bytes_per_call, not "
                    "wall-clock; on TPU configure_for_backend() compiles "
                    "the kernels."),
           "serve": {}}
    from repro.kernels import ops
    for label, uk in (("jnp", False), ("kernel", True)):
        r = serve_workload(ARCH + "-reduced", "coopt", requests=requests,
                           num_lanes=2, max_len=256,
                           max_new_tokens=new_toks, use_kernel=uk)
        out["serve"][label] = {k: r[k] for k in SERVE_KEYS}
        # wall-clock honesty: interpret-mode kernel timings are emulator
        # timings, never comparable to the compiled jnp path
        out["serve"][label]["timing"] = ("interpret" if uk and ops.INTERPRET
                                         else "compiled-xla")
        print(f"bench_mla serve[{label}]: "
              f"{r['throughput_tok_s']} tok/s, "
              f"tpot p50/p95 = {r['tpot_p50_s']}/{r['tpot_p95_s']} s "
              f"[{out['serve'][label]['timing']}]",
              flush=True)
    # headline throughput considers ONLY compiled timings; an interpret-mode
    # kernel run is excluded rather than mislabelled as kernel wall-clock
    out["headline_throughput_tok_s"] = max(
        (s["throughput_tok_s"] for s in out["serve"].values()
         if s["timing"] != "interpret"), default=None)

    header = ["mode", "jnp_us_per_call", "hbm_bytes_per_call",
              "kernel_max_err"]
    out["kernel_micro_latent"] = [dict(zip(header, row))
                                  for row in latent_rows(quick)]
    by_mode = {r["mode"]: r for r in out["kernel_micro_latent"]}
    out["latent_decode_hbm_reduction"] = round(
        1 - by_mode["mla-latent-decode-kernel"]["hbm_bytes_per_call"]
        / by_mode["mla-latent-decode-jnp"]["hbm_bytes_per_call"], 4)

    path = os.path.join(ensure_results_dir(), "BENCH_mla.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"bench_mla: wrote {path} (latent decode HBM traffic "
          f"-{100 * out['latent_decode_hbm_reduction']:.1f}%)", flush=True)
    return path, out


if __name__ == "__main__":
    run()
