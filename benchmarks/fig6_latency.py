"""Fig. 6 reproduction: inference latency per (model x mode).

Paper: 5 LLaMa GPTQ variants x {Original, Opt-KV, Opt-GQA, Opt-Pa,
LLM-CoOpt} on the DCU Z100; LLM-CoOpt cuts latency 4.8-6.8%.

Here: the same 5 models, proportionally bench-reduced (CPU container),
identical request mix per mode, latency per Eq. 11. Absolute numbers are
CPU-scale; the figure's CONTENT is the relative delta vs Original per model
(reported in the last column).
"""
from __future__ import annotations

from repro.configs.paper_models import PAPER_MODELS, bench_reduced
from repro.core.coopt import MODES

from benchmarks.common import run_engine_workload, write_csv

MODELS = ["llama7b-gptq", "llama2-7b-gptq", "llama13b-gptq",
          "llama2-13b-gptq", "llama-pro-8b-gptq"]


def run(requests: int = 8, max_new_tokens: int = 12, quick: bool = False):
    models = MODELS[:2] if quick else MODELS
    rows = []
    for name in models:
        cfg = bench_reduced(PAPER_MODELS[name])
        base = None
        for mode, coopt in MODES.items():
            m = run_engine_workload(cfg, coopt, requests=requests,
                                    max_new_tokens=max_new_tokens)
            if mode == "original":
                base = m["latency_s"]
            delta = 100.0 * (m["latency_s"] - base) / base
            rows.append([name, mode, m["latency_s"], m["prefill_s"],
                         m["decode_s"], round(delta, 2)])
            print(f"fig6 {name:20s} {mode:9s} latency={m['latency_s']:8.3f}s"
                  f"  d_vs_original={delta:+.1f}%", flush=True)
    path = write_csv("fig6_latency.csv",
                     ["model", "mode", "latency_s", "prefill_s", "decode_s",
                      "delta_vs_original_pct"], rows)
    return path, rows


if __name__ == "__main__":
    run()
