"""Kernel micro-benchmark: the fused paged decode hot path.

On this CPU container Pallas runs in interpret mode, so wall-clock is NOT a
TPU prediction; what this table establishes is
  (a) numerical parity kernel-vs-oracle per mode (max |err|),
  (b) the ANALYTIC per-call traffic model of each mode: HBM bytes touched by
      the kernel per token (the quantity Opt-KV/Opt-Pa actually optimize),
  (c) CPU-relative timings between the jnp reference paths of the modes
      (same schedule the TPU executes, jit-compiled by XLA:CPU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.quant import quantize_fp8
from repro.core.coopt import MODES
from repro.core.opt_kv import identity_page_table
from repro.core.opt_pa import paged_decode_attention
from repro.kernels import ops, ref

from benchmarks.common import write_csv


def kernel_bytes_per_call(B, P, ps, Hkv, D, *, opt_kv, opt_pa, opt_gqa, Hq,
                          cache_len):
    """HBM->VMEM traffic of one decode-attention call (bytes)."""
    kv_elt = 1 if opt_kv else 2                   # fp8 vs bf16
    pages_touched = (min((cache_len + ps - 1) // ps, P) if opt_pa else P)
    streams = 1 if opt_gqa else Hq // Hkv         # KV re-streamed per q head
    kv_bytes = 2 * B * pages_touched * ps * Hkv * D * kv_elt * streams
    scale_bytes = (2 * B * pages_touched * ps * Hkv * 4 * streams
                   if opt_kv else 0)
    q_bytes = B * Hq * D * 2
    return kv_bytes + scale_bytes + q_bytes


def run(quick: bool = False):
    B, P, ps, Hkv, G, D = (2, 8, 16, 2, 4, 128) if quick else \
        (4, 32, 16, 2, 4, 128)
    Hq = Hkv * G
    cache_len = P * ps // 2
    PT = B * P                    # global pool, lane-identity partitioned
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, D)).astype(jnp.bfloat16)
    kf = jax.random.normal(ks[1], (PT, ps, Hkv, D), jnp.float32)
    vf = jax.random.normal(ks[2], (PT, ps, Hkv, D), jnp.float32)
    cl = jnp.full((B,), cache_len, jnp.int32)
    phys = identity_page_table(B, PT)
    log = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None], (B, P))

    kq, ksc = quantize_fp8(kf)
    vq, vsc = quantize_fp8(vf)
    kv8, sc8 = jnp.stack([kq, vq]), jnp.stack([ksc, vsc])
    kv16 = jnp.stack([kf, vf]).astype(jnp.bfloat16)

    rows = []
    for mode, co in MODES.items():
        kv, sc = (kv8, sc8) if co.opt_kv else (kv16, None)
        # jnp reference path (jit, XLA:CPU) — the schedule comparison
        fn = jax.jit(lambda q, kv, sc, cl, co=co: paged_decode_attention(
            q, kv, sc, cl, coopt=co))
        out = fn(q, kv, sc, cl).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            out = fn(q, kv, sc, cl)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6

        # kernel parity (interpret mode): Eq. 9 filtering arrives as -1
        # entries in the physical table when opt_pa is on
        if co.opt_pa:
            beyond = log * ps >= cl[:, None]
            kphys = jnp.where(beyond, -1, phys)
        else:
            kphys = phys
        kout = ops.paged_pool_decode(q, kv, sc, cl, kphys, log,
                                     opt_kv=co.opt_kv, opt_gqa=co.opt_gqa)
        ksl = sc[0] if sc is not None else None
        vsl = sc[1] if sc is not None else None
        expected = ref.paged_pool_decode_ref(q, kv[0], kv[1], ksl, vsl, cl,
                                             phys, log, opt_kv=co.opt_kv)
        err = float(np.abs(np.asarray(kout, np.float32) -
                           np.asarray(expected, np.float32)).max())

        traffic = kernel_bytes_per_call(
            B, P, ps, Hkv, D, opt_kv=co.opt_kv, opt_pa=co.opt_pa,
            opt_gqa=co.opt_gqa, Hq=Hq, cache_len=cache_len)
        rows.append([mode, round(us, 1), traffic, f"{err:.4f}"])
        print(f"kernel_micro {mode:9s} jnp={us:9.1f}us/call  "
              f"hbm_traffic={traffic/1024:8.1f}KiB/call  kern_err={err:.4f}",
              flush=True)

    base = rows[0][2]
    print(f"kernel_micro traffic reduction original->coopt: "
          f"{100 * (1 - rows[-1][2] / base):.1f}%")
    path = write_csv("kernel_micro.csv",
                     ["mode", "jnp_us_per_call", "hbm_bytes_per_call",
                      "kernel_max_err"], rows)
    return path, rows


if __name__ == "__main__":
    run()
