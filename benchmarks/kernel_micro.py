"""Kernel micro-benchmark: the fused paged decode hot path, plus the fused
MLA latent kernels (absorbed decode / chunk prefill off the FP8 latent pool).

On this CPU container Pallas runs in interpret mode, so wall-clock is NOT a
TPU prediction; what this table establishes is
  (a) numerical parity kernel-vs-oracle per mode (max |err|),
  (b) the ANALYTIC per-call traffic model of each mode: HBM bytes touched by
      the kernel per token (the quantity Opt-KV/Opt-Pa actually optimize),
  (c) CPU-relative timings between the jnp reference paths of the modes
      (same schedule the TPU executes, jit-compiled by XLA:CPU).
The ``mla-latent-*`` rows compare the jnp gather reference (which
materialises the lane's whole latent history in f32 via ``jnp.take``) with
the fused kernels that stream only live fp8 pages — the "beats" claim is the
traffic column; kernel rows' wall-clock is interpret-mode and only recorded
for completeness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.quant import quantize_fp8, quantize_latent
from repro.configs import get_config
from repro.core.coopt import MODES
from repro.core.opt_kv import identity_page_table
from repro.core.opt_pa import paged_decode_attention
from repro.kernels import ops, ref
from repro.models import mla as mla_mod

from benchmarks.common import write_csv


def kernel_bytes_per_call(B, P, ps, Hkv, D, *, opt_kv, opt_pa, opt_gqa, Hq,
                          cache_len, shared_prefix_pages=0, lanes_sharing=0,
                          share_visits=False):
    """HBM->VMEM traffic of one decode-attention call (bytes).

    ``shared_prefix_pages``/``lanes_sharing`` describe a prompt prefix whose
    pages are refcount-shared by ``lanes_sharing`` lanes. The per-lane grid
    streams each of those pages once PER LANE; the cross-lane visit grid
    (``share_visits=True``, kernels.visits) streams each once TOTAL, so the
    duplicate ``(lanes_sharing - 1) * shared`` page streams drop out."""
    kv_elt = 1 if opt_kv else 2                   # fp8 vs bf16
    pages_touched = (min((cache_len + ps - 1) // ps, P) if opt_pa else P)
    page_streams = B * pages_touched              # per-lane page visits
    if share_visits and lanes_sharing > 1 and shared_prefix_pages > 0:
        shared = min(shared_prefix_pages, pages_touched)
        page_streams -= (min(lanes_sharing, B) - 1) * shared
    streams = 1 if opt_gqa else Hq // Hkv         # KV re-streamed per q head
    kv_bytes = 2 * page_streams * ps * Hkv * D * kv_elt * streams
    scale_bytes = (2 * page_streams * ps * Hkv * 4 * streams
                   if opt_kv else 0)
    q_bytes = B * Hq * D * 2
    return kv_bytes + scale_bytes + q_bytes


def latent_bytes_per_call(B, NP, ps, R, dr, *, fused: bool, opt_kv: bool,
                          cache_len: int, shared_prefix_pages=0,
                          lanes_sharing=0, share_visits=False):
    """HBM traffic of one MLA absorbed decode-attention call (bytes).

    The jnp gather reference ``jnp.take``s the lane's ENTIRE page table and
    materialises it in f32 (read stored dtype + write f32 + re-read f32 for
    the score/value einsums); the fused kernel streams only pages holding
    live context HBM->VMEM ONCE, in the stored (fp8) dtype, shared by all H
    absorbed heads — Opt-GQA at its G = H limit, so head count drops out."""
    W = R + dr
    elt = 1 if opt_kv else 2                       # fp8 vs bf16 storage
    if fused:
        pages = min((cache_len + ps - 1) // ps, NP)  # Eq. 9: -1 never DMA'd
        page_streams = B * pages
        if share_visits and lanes_sharing > 1 and shared_prefix_pages > 0:
            # cross-lane visit grid: shared prefix pages stream once total
            page_streams -= ((min(lanes_sharing, B) - 1)
                             * min(shared_prefix_pages, pages))
        scale = page_streams * ps * 2 * 4 if opt_kv else 0
        return page_streams * ps * W * elt + scale
    stored = B * NP * ps * W * elt + (B * NP * ps * 2 * 4 if opt_kv else 0)
    f32 = B * NP * ps * W * 4
    return stored + 2 * f32                        # materialise + re-read


def _time(fn, *args, n=20):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / n * 1e6


_LATENT_ROWS_CACHE = {}


def latent_rows(quick: bool = False):
    """``mla-latent-{decode,chunk}-{jnp,kernel}`` rows: deepseek-v2-lite
    shaped (H=16, dn=128, dr=64, R=512) unless ``quick`` (reduced dims).
    Memoized per ``quick`` — a full sweep hits this from both the ``kernel``
    and ``mla`` benches, and interpret-mode kernel timing is expensive; the
    CSV and BENCH_mla.json must carry the SAME rows anyway."""
    if quick in _LATENT_ROWS_CACHE:
        return _LATENT_ROWS_CACHE[quick]
    cfg = get_config("deepseek-v2-lite-16b" + ("-reduced" if quick else ""))
    H, dn, dr = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    R, dv = cfg.kv_lora_rank, cfg.v_head_dim
    B, P, ps, S = (2, 8, 16, 8) if quick else (4, 32, 16, 16)
    cache_len = P * ps // 2
    co = MODES["coopt"]
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    p = {"w_uk": jax.random.normal(ks[0], (R, H * dn)) * 0.05,
         "w_uv": jax.random.normal(ks[1], (R, H * dv)) * 0.05}
    qn = jax.random.normal(ks[2], (B, H, dn)).astype(jnp.bfloat16)
    qr = jax.random.normal(ks[3], (B, H, dr)).astype(jnp.bfloat16)
    latf = jax.random.normal(ks[4], (B * P, ps, R + dr), jnp.float32)
    lat, sc = quantize_latent(latf, R)
    cl = jnp.full((B,), cache_len, jnp.int32)
    pt = identity_page_table(B, B * P)

    rows = []

    def cell(name, fn, args, fused_traffic, jnp_traffic):
        jnp_fn = jax.jit(lambda *a: fn(*a, co.replace(use_kernel=False)))
        kern_fn = lambda *a: fn(*a, co.replace(use_kernel=True))  # noqa:E731
        us_jnp = _time(jnp_fn, *args)
        err = float(np.abs(np.asarray(jnp_fn(*args), np.float32)
                           - np.asarray(kern_fn(*args), np.float32)).max())
        us_k = _time(kern_fn, *args)
        rows.append([f"{name}-jnp", round(us_jnp, 1), jnp_traffic, ""])
        rows.append([f"{name}-kernel", round(us_k, 1), fused_traffic,
                     f"{err:.4f}"])
        print(f"kernel_micro {name}: jnp={us_jnp:9.1f}us/call "
              f"traffic={jnp_traffic / 1024:8.1f}KiB -> fused "
              f"traffic={fused_traffic / 1024:8.1f}KiB "
              f"({100 * (1 - fused_traffic / jnp_traffic):.1f}% less), "
              f"err={err:.4f}", flush=True)

    tr = dict(ps=ps, R=R, dr=dr, opt_kv=True, cache_len=cache_len)
    cell("mla-latent-decode",
         lambda qn_, qr_, lat_, sc_, cl_, pt_, co_: mla_mod.mla_paged_decode(
             qn_, qr_, lat_, sc_, cl_, p, cfg, co_, page_table=pt_),
         (qn, qr, lat, sc, cl, pt),
         latent_bytes_per_call(B, P, **tr, fused=True),
         latent_bytes_per_call(B, P, **tr, fused=False))

    qn4 = jnp.broadcast_to(qn[:, None], (B, S, H, dn))
    qr4 = jnp.broadcast_to(qr[:, None], (B, S, H, dr))
    positions = jnp.broadcast_to(jnp.arange(cache_len - S, cache_len),
                                 (B, S)).astype(jnp.int32)
    cell("mla-latent-chunk",
         lambda qn_, qr_, lat_, sc_, pos_, pt_, co_:
             mla_mod.mla_chunk_attention(qn_, qr_, lat_, sc_, pos_, pt_, p,
                                         cfg, co_),
         (qn4, qr4, lat, sc, positions, pt),
         latent_bytes_per_call(B, P, **tr, fused=True),
         latent_bytes_per_call(B, P, **tr, fused=False))
    _LATENT_ROWS_CACHE[quick] = rows
    return rows


def run(quick: bool = False):
    B, P, ps, Hkv, G, D = (2, 8, 16, 2, 4, 128) if quick else \
        (4, 32, 16, 2, 4, 128)
    Hq = Hkv * G
    cache_len = P * ps // 2
    PT = B * P                    # global pool, lane-identity partitioned
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, D)).astype(jnp.bfloat16)
    kf = jax.random.normal(ks[1], (PT, ps, Hkv, D), jnp.float32)
    vf = jax.random.normal(ks[2], (PT, ps, Hkv, D), jnp.float32)
    cl = jnp.full((B,), cache_len, jnp.int32)
    phys = identity_page_table(B, PT)
    log = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None], (B, P))

    kq, ksc = quantize_fp8(kf)
    vq, vsc = quantize_fp8(vf)
    kv8, sc8 = jnp.stack([kq, vq]), jnp.stack([ksc, vsc])
    kv16 = jnp.stack([kf, vf]).astype(jnp.bfloat16)

    rows = []
    for mode, co in MODES.items():
        kv, sc = (kv8, sc8) if co.opt_kv else (kv16, None)
        # jnp reference path (jit, XLA:CPU) — the schedule comparison
        fn = jax.jit(lambda q, kv, sc, cl, co=co: paged_decode_attention(
            q, kv, sc, cl, coopt=co))
        out = fn(q, kv, sc, cl).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            out = fn(q, kv, sc, cl)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6

        # kernel parity (interpret mode): Eq. 9 filtering arrives as -1
        # entries in the physical table when opt_pa is on
        if co.opt_pa:
            beyond = log * ps >= cl[:, None]
            kphys = jnp.where(beyond, -1, phys)
        else:
            kphys = phys
        kout = ops.paged_pool_decode(q, kv, sc, cl, kphys, log,
                                     opt_kv=co.opt_kv, opt_gqa=co.opt_gqa)
        ksl = sc[0] if sc is not None else None
        vsl = sc[1] if sc is not None else None
        expected = ref.paged_pool_decode_ref(q, kv[0], kv[1], ksl, vsl, cl,
                                             phys, log, opt_kv=co.opt_kv)
        err = float(np.abs(np.asarray(kout, np.float32) -
                           np.asarray(expected, np.float32)).max())

        traffic = kernel_bytes_per_call(
            B, P, ps, Hkv, D, opt_kv=co.opt_kv, opt_pa=co.opt_pa,
            opt_gqa=co.opt_gqa, Hq=Hq, cache_len=cache_len)
        rows.append([mode, round(us, 1), traffic, f"{err:.4f}"])
        print(f"kernel_micro {mode:9s} jnp={us:9.1f}us/call  "
              f"hbm_traffic={traffic/1024:8.1f}KiB/call  kern_err={err:.4f}",
              flush=True)

    base = rows[0][2]
    print(f"kernel_micro traffic reduction original->coopt: "
          f"{100 * (1 - rows[-1][2] / base):.1f}%")
    rows += latent_rows(quick)
    path = write_csv("kernel_micro.csv",
                     ["mode", "jnp_us_per_call", "hbm_bytes_per_call",
                      "kernel_max_err"], rows)
    return path, rows


if __name__ == "__main__":
    run()
