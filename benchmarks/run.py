"""Benchmark harness entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # smoke variant
  PYTHONPATH=src python -m benchmarks.run --only fig6,roofline

Outputs CSVs under experiments/ and a summary to stdout.
"""
from __future__ import annotations

import argparse
import os
import time
import traceback

ALL = ("fig6", "fig7", "table12", "kernel", "kernels", "mla", "serving",
       "roofline")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    args = ap.parse_args(argv)
    which = args.only.split(",") if args.only else list(ALL)

    # Pallas kernels run compiled on TPU, interpret-mode elsewhere
    from repro.kernels import ops
    ops.configure_for_backend()

    t0 = time.time()
    failures = []
    for name in which:
        print(f"\n===== {name} =====", flush=True)
        try:
            if name == "fig6":
                from benchmarks.fig6_latency import run
                run(quick=args.quick)
            elif name == "fig7":
                from benchmarks.fig7_throughput import run
                run(quick=args.quick)
            elif name == "table12":
                from benchmarks.table12_accuracy import run
                run(quick=args.quick)
            elif name == "kernel":
                from benchmarks.kernel_micro import run
                run(quick=args.quick)
            elif name == "kernels":
                from benchmarks.bench_kernels import run
                run(quick=args.quick)
            elif name == "mla":
                from benchmarks.bench_mla import run
                run(quick=args.quick)
            elif name == "serving":
                from benchmarks.bench_serving import run
                run(quick=args.quick)
            elif name == "roofline":
                from benchmarks.roofline import run, DRYRUN_FILE
                if os.path.exists(DRYRUN_FILE):
                    run()
                else:
                    print(f"(no {DRYRUN_FILE}; run "
                          f"`python -m repro.launch.dryrun --all --out "
                          f"{DRYRUN_FILE}` first)")
            else:
                print(f"unknown benchmark {name!r}")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    print(f"\n== benchmarks done in {time.time() - t0:.0f}s; "
          f"failures: {failures or 'none'} ==")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
