"""Roofline analysis (deliverable g): three terms per (arch x shape) from the
single-pod dry-run artifacts (experiments/dryrun_single.jsonl).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective = collective_bytes_per_device / link_bw       (~50 GB/s ICI)

cost_analysis() runs on the SPMD-partitioned per-device module, so flops /
bytes are already per-chip. MODEL_FLOPS = 6*N(_active)*D tokens — forward 2ND
+ backward 4ND for train; forward-only shapes use 2ND. The useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from benchmarks.common import RESULTS_DIR, write_csv

_OPT = os.path.join(RESULTS_DIR, "dryrun_single_opt.jsonl")
_BASE = os.path.join(RESULTS_DIR, "dryrun_single.jsonl")
# primary = the optimized sweep when present (§Perf); baseline kept alongside
DRYRUN_FILE = _OPT if os.path.exists(_OPT) else _BASE


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens


def load_records(path: str = DRYRUN_FILE) -> List[Dict]:
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # keep last record per (arch, shape) — reruns append
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"])] = r
    return list(seen.values())


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    flops = rec["cost"].get("flops", 0.0)
    mem_bytes = rec["cost"].get("bytes accessed", 0.0)
    coll = rec.get("collective_bytes", 0.0)
    t_c = flops / PEAK_FLOPS_BF16
    t_m = mem_bytes / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops * rec["devices"]) if flops else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec.get("kind"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": useful,
        "temp_bytes": rec["memory"]["temp_bytes"],
    }


def run(path: str = DRYRUN_FILE, out_csv: str = "roofline.csv"):
    rows, analyses = [], []
    for rec in sorted(load_records(path),
                      key=lambda r: (r["arch"], r["shape"])):
        a = analyze(rec)
        if a is None:
            rows.append([rec["arch"], rec["shape"], rec["status"],
                         "", "", "", "", "", ""])
            continue
        analyses.append(a)
        rows.append([a["arch"], a["shape"], "ok",
                     f"{a['compute_s']:.3e}", f"{a['memory_s']:.3e}",
                     f"{a['collective_s']:.3e}", a["dominant"],
                     f"{a['useful_ratio']:.3f}", a["temp_bytes"]])
        print(f"{a['arch']:22s} {a['shape']:12s} "
              f"C={a['compute_s']:.2e}s M={a['memory_s']:.2e}s "
              f"X={a['collective_s']:.2e}s -> {a['dominant']:10s} "
              f"useful={a['useful_ratio']:.2f}", flush=True)
    p = write_csv(out_csv,
                  ["arch", "shape", "status", "compute_s", "memory_s",
                   "collective_s", "dominant", "useful_ratio",
                   "temp_bytes_per_dev"], rows)
    return p, analyses


if __name__ == "__main__":
    run()
