"""Tables 1-2 reproduction: inference accuracy before/after LLM-CoOpt.

Paper: ARC-C/ARC-E 4-choice accuracy is preserved (±<=1pt) under LLM-CoOpt.
ARC is not on the container, so the proxy (DESIGN.md §8.5) is:

  1. train a small model briefly on the synthetic bigram corpus,
  2. build 4-choice items: (context, true continuation, 3 distractors),
  3. score each choice by decode-path log-likelihood THROUGH THE SERVING
     STACK (prefill + per-token decode against the paged cache) under each
     mode — so the fp8 cache, SkipSet writes and block-wise softmax are all
     in the measurement loop, exactly the code the paper's claim is about,
  4. report accuracy per mode + the mean |delta logit| between Original and
     CoOpt paths (a tighter proxy than 4-way accuracy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.data import TrainPipeline
from repro.models import get_model
from repro.training import Trainer

from benchmarks.common import write_csv


def _score_choices(model, params, coopt, contexts, choices):
    """log p(choice | context) via prefill + teacher-forced decode steps."""
    n_items, ctx_len = contexts.shape
    _, n_choice, cho_len = choices.shape
    scores = np.zeros((n_items, n_choice))
    for c in range(n_choice):
        cache = model.init_cache(n_items, ctx_len + cho_len + 4, coopt)
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(contexts)},
                                      cache, coopt)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        tot = np.array(jnp.take_along_axis(
            lp, jnp.asarray(choices[:, c, :1]), axis=-1))[:, 0]
        for t in range(cho_len - 1):
            tok = jnp.asarray(choices[:, c, t:t + 1], jnp.int32)
            logits, cache = model.decode_step(params, {"token": tok}, cache,
                                              coopt)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            tot += np.asarray(jnp.take_along_axis(
                lp, jnp.asarray(choices[:, c, t + 1:t + 2]), axis=-1))[:, 0]
        scores[:, c] = tot
    return scores


def run(n_items: int = 24, train_steps: int = 60, quick: bool = False):
    if quick:
        n_items, train_steps = 8, 25
    cfg = get_config("llama13b-gptq-reduced").replace(vocab_size=256)
    pipe = TrainPipeline(cfg.vocab_size, batch=8, seq_len=64, seed=0)
    tr = Trainer(cfg, lr=2e-3)
    tr.fit(pipe, steps=train_steps, log=None)
    model, params = get_model(cfg), tr.params

    # 4-choice items from held-out pipeline samples
    rng = np.random.default_rng(123)
    ctx_len, cho_len = 24, 6
    rows = []
    while sum(len(r) for r in rows) < n_items:
        rows.append(pipe.next_batch()["tokens"])
    toks = np.concatenate(rows)[:n_items]
    contexts = toks[:, :ctx_len]
    true_cont = toks[:, ctx_len:ctx_len + cho_len]
    distract = rng.integers(0, cfg.vocab_size,
                            (n_items, 3, cho_len), dtype=np.int32)
    choices = np.concatenate([true_cont[:, None], distract], axis=1)
    answer = np.zeros(n_items, np.int64)

    rows, base_scores = [], None
    for mode, coopt in MODES.items():
        sc = _score_choices(model, params, coopt, contexts, choices)
        acc = float(np.mean(np.argmax(sc, -1) == answer))
        dl = (0.0 if base_scores is None
              else float(np.mean(np.abs(sc - base_scores))))
        if mode == "original":
            base_scores = sc
        rows.append([mode, round(100 * acc, 2), round(dl, 4)])
        print(f"table12 {mode:9s} accuracy={100*acc:6.2f}%  "
              f"mean|dlogprob| vs original={dl:.4f}", flush=True)
    path = write_csv("table12_accuracy.csv",
                     ["mode", "accuracy_pct", "mean_abs_dlogprob"], rows)
    return path, rows


if __name__ == "__main__":
    run()
