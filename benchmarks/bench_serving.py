"""Poisson-load serving benchmark -> experiments/BENCH_serving.json.

Replays ONE Poisson arrival process (same seed => identical prompts and
arrival offsets) through three frontends of the same engine:

  sync        — the synchronous step loop (requests injected when the wall
                clock passes their arrival offset),
  async       — ``AsyncEngine``: overlapped host/device pipeline, on-device
                sampling, AOT bucket warmup (zero steady-state traces),
  async_pack  — async + concat-prefill packing (several prompts' chunks
                per row with segment-id isolation).

Reported per config: wall-clock tokens/s and TTFT / TPOT / queue-wait
p50/p95 — all latency measured from SUBMISSION, so queue wait under load
counts. Each config's compile cost is excluded the same way (sync: one
warmup pass of the identical workload; async: AOT ``lower().compile()``
before the clock starts). All frontends are built and warmed up FIRST,
then measured in interleaved rounds (sync, async, async_pack, sync, ...)
with the best-of-rounds wall reported per cell: serving steps are
ms-scale, so single passes are OS-scheduler noise, and machine-speed
drift between cells would otherwise bias whichever ran during a slow
minute.

A second, prefill-only section (short prompts, ``max_new_tokens=1``)
isolates the packing win: packed vs unpacked prompt-prefill tokens/s on
the same arrivals — packing fewer rows per step is the whole effect, so
this is where it must show.

A third, OVERLOAD section drives arrivals well past engine capacity with
per-request deadlines and a submit-time queue-depth watermark, and
reports what the resilience layer delivers under saturation: goodput
(tokens of FINISHED requests per wall second — shed/expired work never
counts), shed rate, and deadline-hit rate. The unprotected cell (same
arrivals, no deadlines/watermarks) is reported alongside so the
trade is explicit: protection converts queue-wait collapse into
fast-rejected load.

On this CPU container wall-clock ratios are indicative (interpret-mode
kernels are emulated; the jnp path dominates); the pipeline/packing deltas
are real host-side effects and carry to TPU.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ensure_results_dir

ARCH = "qwen3-4b-reduced"
KEYS = ("wall_s", "wall_throughput_tok_s", "generated_tokens",
        "ttft_p50_s", "ttft_p95_s", "tpot_p50_s", "tpot_p95_s",
        "queue_wait_p50_s", "queue_wait_p95_s", "repeat_wall_s",
        "packed_steps", "packed_rows_saved", "aot_misses", "retraces")


def _interleaved(cells: dict, rounds: int) -> dict:
    """Build every runner, then measure in interleaved rounds; per cell
    keep the best-wall pass's metrics."""
    from repro.launch.serve import ServeRunner
    runners = {label: ServeRunner(ARCH, "coopt", **kw)
               for label, kw in cells.items()}
    best: dict = {}
    walls: dict = {label: [] for label in cells}
    for _ in range(rounds):
        for label, runner in runners.items():
            wall = runner.measure()
            walls[label].append(round(wall, 4))
            if label not in best or wall < best[label]["wall_s"]:
                best[label] = runner.metrics(wall)
    out = {}
    for label, runner in runners.items():
        cell = {k: v for k, v in best[label].items() if k in KEYS}
        cell["repeat_wall_s"] = walls[label]
        cell.update(runner.trace_report())
        out[label] = cell
        print(f"bench_serving[{label}]: "
              f"{cell['wall_throughput_tok_s']} tok/s "
              f"(walls {walls[label]}), ttft p50/p95 = "
              f"{cell['ttft_p50_s']}/{cell['ttft_p95_s']} s, "
              f"queue p50 = {cell['queue_wait_p50_s']} s", flush=True)
    return out


def _overload(quick: bool) -> dict:
    """Saturation lane: a near-burst arrival process well past the
    4-lane engine's capacity, measured with and without the resilience
    layer's protections (per-request deadlines + submit-time queue-depth
    watermark). Protection trades completed-request count for bounded
    queue wait: refused work shows up as ``shed_rate``/TIMED_OUT instead
    of unbounded TTFT."""
    from repro.launch.serve import ServeRunner
    requests = 12 if quick else 20
    base = dict(requests=requests, num_lanes=4, max_len=128,
                max_new_tokens=24, scale=0.05, seed=2,
                arrival_rate=120.0, use_async=True, warmup_pass=True)
    cells = {"unprotected": base,
             "protected": dict(base, deadline_s=4.0, max_queue_depth=6)}
    out = {}
    for label, kw in cells.items():
        runner = ServeRunner(ARCH, "coopt", **kw)
        wall = runner.measure()
        cell = {k: v for k, v in runner.metrics(wall).items() if k in KEYS}
        cell.update(runner.outcome_report(wall))
        out[label] = cell
        print(f"bench_serving[overload/{label}]: "
              f"goodput {cell['goodput_tok_s']} tok/s, "
              f"shed {cell['shed_rate']}, "
              f"deadline-hit {cell['deadline_hit_rate']}, "
              f"queue p95 = {cell['queue_wait_p95_s']} s", flush=True)
    return out


def run(quick: bool = False):
    # decode-heavy regime (short prompts, long generations): steady-state
    # decode steps dominate, where the pipeline's per-step host savings
    # show
    requests, new_toks, rate = (10, 48, 30.0) if quick else (16, 48, 24.0)
    rounds = 3
    base = dict(requests=requests, num_lanes=8, max_len=128,
                max_new_tokens=new_toks, scale=0.05, seed=0,
                arrival_rate=rate, warmup_pass=True)

    out = {"arch": ARCH, "mode": "coopt", "quick": quick,
           "arrival_rate_req_s": rate, "requests": requests,
           "rounds": rounds,
           "note": ("one Poisson arrival process, three frontends; "
                    "latency measured from submission (queue wait "
                    "included); compile excluded per config (sync warmup "
                    "pass / async AOT warmup); cells measured in "
                    "interleaved rounds, best wall per cell"),
           "poisson": {}, "prefill_pack": {}, "overload": {}}

    out["poisson"] = _interleaved(
        {"sync": base,
         "async": dict(base, use_async=True, assert_aot=True),
         "async_pack": dict(base, use_async=True, pack=True,
                            assert_aot=True)},
        rounds)

    # --- prefill-only packing isolation: short prompts, 1 token out ------
    pf_requests = 12 if quick else 24
    pf = dict(requests=pf_requests, num_lanes=8, max_len=128,
              max_new_tokens=1, scale=0.03, seed=1, arrival_rate=0.0,
              warmup_pass=True)
    out["prefill_pack"] = _interleaved(
        {"unpacked": pf, "packed": dict(pf, pack=True)}, 2)
    # prompt-prefill throughput: generated==requests (1 token each), so
    # tokens/s differences are pure prefill wall-clock differences
    up, pk = out["prefill_pack"]["unpacked"], out["prefill_pack"]["packed"]
    out["prefill_pack"]["packed_speedup"] = round(
        up["wall_s"] / max(pk["wall_s"], 1e-9), 3)

    # --- overload/resilience lane: goodput under saturation --------------
    out["overload"] = _overload(quick)

    out["async_ge_sync_tok_s"] = (
        out["poisson"]["async"]["wall_throughput_tok_s"]
        >= out["poisson"]["sync"]["wall_throughput_tok_s"])
    out["packed_ge_unpacked_prefill"] = pk["wall_s"] <= up["wall_s"]
    # the watermark actually refused load under the burst
    out["overload_protection_shed"] = (
        out["overload"]["protected"]["shed_rate"] > 0)

    path = os.path.join(ensure_results_dir(), "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"bench_serving: async>=sync {out['async_ge_sync_tok_s']}, "
          f"packed prefill speedup {out['prefill_pack']['packed_speedup']}x"
          f", overload shed {out['overload']['protected']['shed_rate']}"
          f" -> {path}", flush=True)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
