"""Poisson-load serving benchmark -> experiments/BENCH_serving.json.

Replays ONE Poisson arrival process (same seed => identical prompts and
arrival offsets) through three frontends of the same engine:

  sync        — the synchronous step loop (requests injected when the wall
                clock passes their arrival offset),
  async       — ``AsyncEngine``: overlapped host/device pipeline, on-device
                sampling, AOT bucket warmup (zero steady-state traces),
  async_pack  — async + concat-prefill packing (several prompts' chunks
                per row with segment-id isolation).

Reported per config: wall-clock tokens/s and TTFT / TPOT / queue-wait
p50/p95 — all latency measured from SUBMISSION, so queue wait under load
counts. Each config's compile cost is excluded the same way (sync: one
warmup pass of the identical workload; async: AOT ``lower().compile()``
before the clock starts). All frontends are built and warmed up FIRST,
then measured in interleaved rounds (sync, async, async_pack, sync, ...)
with the best-of-rounds wall reported per cell: serving steps are
ms-scale, so single passes are OS-scheduler noise, and machine-speed
drift between cells would otherwise bias whichever ran during a slow
minute.

A second, prefill-only section (short prompts, ``max_new_tokens=1``)
isolates the packing win: packed vs unpacked prompt-prefill tokens/s on
the same arrivals — packing fewer rows per step is the whole effect, so
this is where it must show.

A third, OVERLOAD section drives arrivals well past engine capacity with
per-request deadlines and a submit-time queue-depth watermark, and
reports what the resilience layer delivers under saturation: goodput
(tokens of FINISHED requests per wall second — shed/expired work never
counts), shed rate, and deadline-hit rate. The unprotected cell (same
arrivals, no deadlines/watermarks) is reported alongside so the
trade is explicit: protection converts queue-wait collapse into
fast-rejected load.

A fourth, CAPACITY section sizes the device pool several times SMALLER
than the shared-prefix working set and measures the host-DRAM spill tier
(``CacheConfig.host_pages``): per working-set multiple, prefix hit rate
(split device/host) and TTFT p50 with the tier on vs off. The tier turns
capacity misses into host hits — the hit-rate gap (and the TTFT gap it
buys) is the paper's hierarchical-cache effect.

On this CPU container wall-clock ratios are indicative (interpret-mode
kernels are emulated; the jnp path dominates); the pipeline/packing deltas
are real host-side effects and carry to TPU.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ensure_results_dir

ARCH = "qwen3-4b-reduced"
KEYS = ("wall_s", "wall_throughput_tok_s", "generated_tokens",
        "ttft_p50_s", "ttft_p95_s", "tpot_p50_s", "tpot_p95_s",
        "queue_wait_p50_s", "queue_wait_p95_s", "repeat_wall_s",
        "packed_steps", "packed_rows_saved", "aot_misses", "retraces")


def _interleaved(cells: dict, rounds: int) -> dict:
    """Build every runner, then measure in interleaved rounds; per cell
    keep the best-wall pass's metrics."""
    from repro.launch.serve import ServeRunner
    runners = {label: ServeRunner(ARCH, "coopt", **kw)
               for label, kw in cells.items()}
    best: dict = {}
    walls: dict = {label: [] for label in cells}
    for _ in range(rounds):
        for label, runner in runners.items():
            wall = runner.measure()
            walls[label].append(round(wall, 4))
            if label not in best or wall < best[label]["wall_s"]:
                best[label] = runner.metrics(wall)
    out = {}
    for label, runner in runners.items():
        cell = {k: v for k, v in best[label].items() if k in KEYS}
        cell["repeat_wall_s"] = walls[label]
        cell.update(runner.trace_report())
        out[label] = cell
        print(f"bench_serving[{label}]: "
              f"{cell['wall_throughput_tok_s']} tok/s "
              f"(walls {walls[label]}), ttft p50/p95 = "
              f"{cell['ttft_p50_s']}/{cell['ttft_p95_s']} s, "
              f"queue p50 = {cell['queue_wait_p50_s']} s", flush=True)
    return out


def _overload(quick: bool) -> dict:
    """Saturation lane: a near-burst arrival process well past the
    4-lane engine's capacity, measured with and without the resilience
    layer's protections (per-request deadlines + submit-time queue-depth
    watermark). Protection trades completed-request count for bounded
    queue wait: refused work shows up as ``shed_rate``/TIMED_OUT instead
    of unbounded TTFT."""
    from repro.launch.serve import ServeRunner
    requests = 12 if quick else 20
    base = dict(requests=requests, num_lanes=4, max_len=128,
                max_new_tokens=24, scale=0.05, seed=2,
                arrival_rate=120.0, use_async=True, warmup_pass=True)
    cells = {"unprotected": base,
             "protected": dict(base, deadline_s=4.0, max_queue_depth=6)}
    out = {}
    for label, kw in cells.items():
        runner = ServeRunner(ARCH, "coopt", **kw)
        wall = runner.measure()
        cell = {k: v for k, v in runner.metrics(wall).items() if k in KEYS}
        cell.update(runner.outcome_report(wall))
        out[label] = cell
        print(f"bench_serving[overload/{label}]: "
              f"goodput {cell['goodput_tok_s']} tok/s, "
              f"shed {cell['shed_rate']}, "
              f"deadline-hit {cell['deadline_hit_rate']}, "
              f"queue p95 = {cell['queue_wait_p95_s']} s", flush=True)
    return out


def _capacity(quick: bool) -> dict:
    """Hierarchical-cache capacity lane: shared-prefix working sets 2-10x
    the device pool, tier on (``host_pages``) vs off, identical greedy
    workload. Reports the residency-split prefix hit rate and TTFT p50 per
    cell; the measured pass follows one warmup pass (compile excluded,
    tier in steady state), with hit counters delta'd against a
    pre-measure snapshot of the allocator's cumulative stats."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.coopt import MODES
    from repro.serving import CacheConfig, Engine, EngineConfig

    cfg = get_config(ARCH)
    coopt = MODES["coopt"]
    ps = coopt.page_size                       # 64-token pages
    lanes, pool_pages = 2, 9                   # 8 usable device pages
    usable = pool_pages - 1

    def _prompts(mult: int):
        # k two-page prefixes -> working set ~= 2k prefix pages =
        # mult*usable, replayed in 2 rounds so every prefix recurs at a
        # reuse distance far past the device pool. A hit restores 128
        # prefill tokens from host DRAM; only the 16-token tail computes.
        k = mult * usable // 2
        rng = np.random.default_rng(3)
        prefixes = [rng.integers(10, cfg.vocab_size - 10, 2 * ps,
                                 dtype=np.int32) for _ in range(k)]
        out = []
        for _ in range(2):
            for p in prefixes:
                out.append(np.concatenate(
                    [p, rng.integers(10, cfg.vocab_size - 10, 16,
                                     dtype=np.int32)]))
        return out

    def _cell(host_pages: int, prompts):
        ecfg = EngineConfig(
            num_lanes=lanes, max_len=256, prefill_buckets=(32, 64, 128, 256),
            seed=0, cache=CacheConfig(num_pages=pool_pages,
                                      host_pages=host_pages))
        eng = Engine(cfg, coopt, ecfg)
        eng.generate(prompts, max_new_tokens=8)          # warmup pass
        mgr = eng.scheduler.manager
        snap = (mgr.prefix_queries, mgr.prefix_hits,
                mgr.prefix_device_hits, mgr.prefix_host_hits)
        eng.stats.__init__()
        outs = eng.generate(prompts, max_new_tokens=8)
        s = eng.stats
        q = max(mgr.prefix_queries - snap[0], 1)
        assert eng.scheduler.manager.audit() == []
        return {
            "prefix_hit_rate": round((mgr.prefix_hits - snap[1]) / q, 4),
            "prefix_device_hit_rate":
                round((mgr.prefix_device_hits - snap[2]) / q, 4),
            "prefix_host_hit_rate":
                round((mgr.prefix_host_hits - snap[3]) / q, 4),
            "ttft_p50_s": round(s.ttft(50), 4),
            "spilled_pages": s.spilled_pages,
            "prefetch_committed": s.prefetch_committed,
            "prefetch_aborted": s.prefetch_aborted,
            "preemptions": s.preemptions,
        }, outs

    mults = (4,) if quick else (2, 4, 10)
    out = {"device_pool_pages": usable, "host_pages": 64,
           "page_size": ps, "lanes": lanes}
    for mult in mults:
        prompts = _prompts(mult)
        on, outs_on = _cell(64, prompts)
        off, outs_off = _cell(0, prompts)
        cell = {"working_set_pages": mult * usable, "requests": len(prompts),
                "tier_on": on, "tier_off": off,
                "bit_identical": outs_on == outs_off}
        out[f"x{mult}"] = cell
        print(f"bench_serving[capacity/x{mult}]: hit rate "
              f"{on['prefix_hit_rate']} (host {on['prefix_host_hit_rate']})"
              f" vs {off['prefix_hit_rate']} off, ttft p50 "
              f"{on['ttft_p50_s']} vs {off['ttft_p50_s']} s, "
              f"bit-identical {cell['bit_identical']}", flush=True)
    return out


def run(quick: bool = False):
    # decode-heavy regime (short prompts, long generations): steady-state
    # decode steps dominate, where the pipeline's per-step host savings
    # show
    requests, new_toks, rate = (10, 48, 30.0) if quick else (16, 48, 24.0)
    rounds = 3
    base = dict(requests=requests, num_lanes=8, max_len=128,
                max_new_tokens=new_toks, scale=0.05, seed=0,
                arrival_rate=rate, warmup_pass=True)

    out = {"arch": ARCH, "mode": "coopt", "quick": quick,
           "arrival_rate_req_s": rate, "requests": requests,
           "rounds": rounds,
           "note": ("one Poisson arrival process, three frontends; "
                    "latency measured from submission (queue wait "
                    "included); compile excluded per config (sync warmup "
                    "pass / async AOT warmup); cells measured in "
                    "interleaved rounds, best wall per cell"),
           "poisson": {}, "prefill_pack": {}, "overload": {},
           "capacity": {}}

    out["poisson"] = _interleaved(
        {"sync": base,
         "async": dict(base, use_async=True, assert_aot=True),
         "async_pack": dict(base, use_async=True, pack=True,
                            assert_aot=True)},
        rounds)

    # --- prefill-only packing isolation: short prompts, 1 token out ------
    pf_requests = 12 if quick else 24
    pf = dict(requests=pf_requests, num_lanes=8, max_len=128,
              max_new_tokens=1, scale=0.03, seed=1, arrival_rate=0.0,
              warmup_pass=True)
    out["prefill_pack"] = _interleaved(
        {"unpacked": pf, "packed": dict(pf, pack=True)}, 2)
    # prompt-prefill throughput: generated==requests (1 token each), so
    # tokens/s differences are pure prefill wall-clock differences
    up, pk = out["prefill_pack"]["unpacked"], out["prefill_pack"]["packed"]
    out["prefill_pack"]["packed_speedup"] = round(
        up["wall_s"] / max(pk["wall_s"], 1e-9), 3)

    # --- overload/resilience lane: goodput under saturation --------------
    out["overload"] = _overload(quick)

    # --- capacity lane: host-DRAM spill tier under memory pressure -------
    out["capacity"] = _capacity(quick)
    cap4 = out["capacity"]["x4"]
    out["capacity_tier_hit_rate_2x"] = (
        cap4["tier_on"]["prefix_hit_rate"]
        >= 2 * cap4["tier_off"]["prefix_hit_rate"])
    out["capacity_tier_ttft_wins"] = (
        cap4["tier_on"]["ttft_p50_s"] <= cap4["tier_off"]["ttft_p50_s"])
    out["capacity_bit_identical"] = all(
        c["bit_identical"] for k, c in out["capacity"].items()
        if k.startswith("x"))

    out["async_ge_sync_tok_s"] = (
        out["poisson"]["async"]["wall_throughput_tok_s"]
        >= out["poisson"]["sync"]["wall_throughput_tok_s"])
    out["packed_ge_unpacked_prefill"] = pk["wall_s"] <= up["wall_s"]
    # the watermark actually refused load under the burst
    out["overload_protection_shed"] = (
        out["overload"]["protected"]["shed_rate"] > 0)

    path = os.path.join(ensure_results_dir(), "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"bench_serving: async>=sync {out['async_ge_sync_tok_s']}, "
          f"packed prefill speedup {out['prefill_pack']['packed_speedup']}x"
          f", overload shed {out['overload']['protected']['shed_rate']}"
          f", capacity 2x-hit-rate {out['capacity_tier_hit_rate_2x']}"
          f" -> {path}", flush=True)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
