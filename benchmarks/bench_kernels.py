"""Kernel benchmark lane -> experiments/BENCH_kernels.json.

Three sections, with wall-clock honesty as the organizing rule:

``analytic``
    The HBM-traffic model of the decode kernels (``kernel_micro``'s bytes
    model extended with cross-lane visit dedup) evaluated at ONE canonical
    shape set — 8 lanes sharing a 32-page prompt prefix plus 4 private tail
    pages each — regardless of ``--quick``. These columns are deterministic
    and are the regression surface CI gates on (``--compare-baseline``):
    a >5% increase in any ``bytes_per_token`` entry vs the committed
    baseline fails the run. The headline number is the per-lane -> visit
    grid traffic reduction, which must stay >= 4x for this scenario.

``chunk_restream``
    Tile-resident chunk streaming accounting: how many times one KV page is
    streamed per prefill chunk before (fixed 256-row query blocks) vs after
    (``resident_rows()``-sized blocks) for the dense and latent chunk
    kernels, computed from the kernels' own sizing functions.

``measured``
    What this container can honestly time. The jnp reference path is real
    compiled XLA wall-clock and gets ``tokens_per_s``/``tpot_us``. Kernel
    timings are labelled by how they ran: on a real accelerator backend
    they are ``kernel_us`` with throughput; under Pallas interpret mode
    they are recorded as ``interpret_us`` with ``tokens_per_s: null`` and
    an explanatory note — an emulator timing is NEVER reported as kernel
    wall-clock. Parity of the visit grid vs the per-lane grid on a genuinely
    shared page table is checked here too.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ensure_results_dir
from benchmarks.kernel_micro import (kernel_bytes_per_call,
                                     latent_bytes_per_call)

OUT_NAME = "BENCH_kernels.json"

# canonical shared-prefix decode scenario (acceptance: >=4x traffic drop).
# Analytic columns use these shapes ALWAYS — --quick only shrinks the
# measured section — so a quick CI run compares against the committed
# baseline one-to-one.
CANON = dict(B=8, shared_pages=32, tail_pages=4, ps=64, Hkv=2, G=4, D=128,
             R=512, dr=64)


def _analytic():
    c = CANON
    P = c["shared_pages"] + c["tail_pages"]
    cache_len = P * c["ps"]
    Hq = c["Hkv"] * c["G"]
    common = dict(ps=c["ps"], Hkv=c["Hkv"], D=c["D"], opt_kv=True,
                  opt_pa=True, opt_gqa=True, Hq=Hq, cache_len=cache_len)
    share = dict(shared_prefix_pages=c["shared_pages"], lanes_sharing=c["B"])
    B = c["B"]
    gqa_lane = kernel_bytes_per_call(B, P, **common) / B
    gqa_vis = kernel_bytes_per_call(B, P, **common, **share,
                                    share_visits=True) / B
    lat_args = dict(ps=c["ps"], R=c["R"], dr=c["dr"], fused=True,
                    opt_kv=True, cache_len=cache_len)
    lat_lane = latent_bytes_per_call(B, P, **lat_args) / B
    lat_vis = latent_bytes_per_call(B, P, **lat_args, **share,
                                    share_visits=True) / B
    return {
        "scenario": {**c, "pages_per_lane": P, "cache_len": cache_len},
        # regression-gated columns: analytic HBM bytes per generated token
        "bytes_per_token": {
            "decode-gqa-per-lane": gqa_lane,
            "decode-gqa-visits": gqa_vis,
            "decode-latent-per-lane": lat_lane,
            "decode-latent-visits": lat_vis,
        },
        "gqa_traffic_reduction_x": round(gqa_lane / gqa_vis, 3),
        "latent_traffic_reduction_x": round(lat_lane / lat_vis, 3),
    }


def _chunk_restream():
    from repro.kernels import flash_chunk_prefill as fcp
    from repro.kernels import latent_chunk_prefill as lcp
    out = {}
    G, H = CANON["G"], 16
    for name, rows, fn in (("dense", 1024, lambda r: fcp.resident_rows(r, G)),
                           ("latent", 1024,
                            lambda r: lcp.resident_rows(r, H))):
        rr = fn(rows)
        before = -(-rows // 256)            # fixed 256-row blocks (old)
        after = -(-rows // rr)              # resident-rows blocks (new)
        out[name] = {"chunk_rows": rows, "resident_rows": rr,
                     "page_streams_per_chunk_before": before,
                     "page_streams_per_chunk_after": after,
                     "restream_reduction_x": round(before / after, 3)}
    return out


def _shared_tables(B, P, shared, ps):
    """Physical/logical page tables where pages 0..shared-1 are common to
    every lane (refcount-shared prefix) and tails are lane-private."""
    phys = np.zeros((B, P), np.int32)
    for b in range(B):
        for i in range(P):
            phys[b, i] = i if i < shared else \
                shared + b * (P - shared) + (i - shared)
    log = np.broadcast_to(np.arange(P, dtype=np.int32)[None], (B, P))
    total = shared + B * (P - shared)
    return jnp.asarray(phys), jnp.asarray(np.ascontiguousarray(log)), total


def _time(fn, *args, n=10):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / n * 1e6


def _measured(quick: bool):
    from repro.cache.quant import quantize_fp8
    from repro.kernels import ops, ref

    B = 8
    shared, tail, ps = (4, 2, 16) if quick else (8, 4, 16)
    P = shared + tail
    Hkv, G, D = 1, 4, 128
    Hq = Hkv * G
    phys, log, PT = _shared_tables(B, P, shared, ps)
    cache_len = P * ps
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, D)).astype(jnp.bfloat16)
    kf = jax.random.normal(ks[1], (PT, ps, Hkv, D), jnp.float32)
    vf = jax.random.normal(ks[2], (PT, ps, Hkv, D), jnp.float32)
    kq, ksc = quantize_fp8(kf)
    vq, vsc = quantize_fp8(vf)
    kv, sc = jnp.stack([kq, vq]), jnp.stack([ksc, vsc])
    cl = jnp.full((B,), cache_len, jnp.int32)

    def kern(share):
        return ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                     opt_gqa=True, share_visits=share)

    o_lane = kern(False)
    o_vis = kern(True)
    parity = float(np.abs(np.asarray(o_vis, np.float32)
                          - np.asarray(o_lane, np.float32)).max())

    # honest compiled-XLA wall-clock: the jnp gather oracle on the SAME
    # shared page table
    jref = jax.jit(lambda q_, cl_: ref.paged_pool_decode_ref(
        q_, kv[0], kv[1], sc[0], sc[1], cl_, phys, log, opt_kv=True))
    err = float(np.abs(np.asarray(o_vis, np.float32)
                       - np.asarray(jref(q, cl), np.float32)).max())
    us_jnp = _time(jref, q, cl)
    out = {
        "shape": {"B": B, "shared_pages": shared, "tail_pages": tail,
                  "ps": ps, "Hkv": Hkv, "G": G, "D": D},
        "visit_vs_perlane_max_err": parity,
        "visit_vs_oracle_max_err": err,
        "jnp_reference": {
            "timing": "compiled-xla",
            "us_per_call": round(us_jnp, 1),
            "tpot_us": round(us_jnp, 1),       # 1 token/lane/call
            "tokens_per_s": round(B / (us_jnp * 1e-6), 1),
        },
    }
    us_lane = _time(kern, False)
    us_vis = _time(kern, True)
    if ops.INTERPRET:
        # emulator timings: recorded for completeness, never as kernel
        # wall-clock, never with a throughput number
        out["kernel"] = {
            "timing": "interpret",
            "interpret_us_per_lane_grid": round(us_lane, 1),
            "interpret_us_visit_grid": round(us_vis, 1),
            "tokens_per_s": None,
            "tpot_us": None,
            "note": ("Pallas interpret mode (no accelerator backend): "
                     "these are emulator timings — compare the analytic "
                     "bytes_per_token columns, not wall-clock."),
        }
    else:
        out["kernel"] = {
            "timing": "compiled",
            "backend": jax.default_backend(),
            "us_per_call_per_lane_grid": round(us_lane, 1),
            "us_per_call_visit_grid": round(us_vis, 1),
            "tpot_us": round(us_vis, 1),
            "tokens_per_s": round(B / (us_vis * 1e-6), 1),
        }
    return out


def run(quick: bool = False):
    analytic = _analytic()
    out = {
        "backend": jax.default_backend(),
        "analytic": analytic,
        "chunk_restream": _chunk_restream(),
        "measured": _measured(quick),
    }
    path = os.path.join(ensure_results_dir(), OUT_NAME)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    bt = analytic["bytes_per_token"]
    print(f"bench_kernels: wrote {path}\n"
          f"  gqa   bytes/token {bt['decode-gqa-per-lane']:.0f} -> "
          f"{bt['decode-gqa-visits']:.0f} "
          f"({analytic['gqa_traffic_reduction_x']}x)\n"
          f"  latent bytes/token {bt['decode-latent-per-lane']:.0f} -> "
          f"{bt['decode-latent-visits']:.0f} "
          f"({analytic['latent_traffic_reduction_x']}x)", flush=True)
    return path, out


def compare_baseline(result: dict, baseline_path: str,
                     tol: float = 0.05) -> int:
    """Gate: fail (1) if any analytic bytes/token column regressed >tol
    vs the committed baseline. Timing keys are NEVER gated — wall-clock on
    shared CI runners is noise; the analytic model is the contract."""
    with open(baseline_path) as f:
        base = json.load(f)
    new = result["analytic"]["bytes_per_token"]
    old = base["analytic"]["bytes_per_token"]
    bad = []
    for k, b in old.items():
        n = new.get(k)
        if n is None:
            bad.append(f"{k}: column disappeared")
        elif n > b * (1 + tol):
            bad.append(f"{k}: {b:.0f} -> {n:.0f} bytes/token "
                       f"(+{100 * (n / b - 1):.1f}% > {100 * tol:.0f}%)")
    if bad:
        print("bench_kernels: analytic traffic REGRESSION vs baseline:\n  "
              + "\n  ".join(bad), file=sys.stderr)
        return 1
    print(f"bench_kernels: analytic bytes/token within {100 * tol:.0f}% of "
          f"baseline ({baseline_path})", flush=True)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--compare-baseline", default=None, metavar="PATH",
                    help="committed BENCH_kernels.json to gate analytic "
                         "bytes/token columns against (>5%% fails)")
    args = ap.parse_args(argv)
    from repro.kernels import ops
    ops.configure_for_backend()
    _, out = run(quick=args.quick)
    if args.compare_baseline:
        return compare_baseline(out, args.compare_baseline)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
