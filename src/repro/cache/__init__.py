from repro.cache.quant import (FP8_DTYPE, FP8_MAX, dequantize_fp8,
                               quantize_fp8, quant_roundtrip_error)

__all__ = ["FP8_DTYPE", "FP8_MAX", "dequantize_fp8", "quantize_fp8",
           "quant_roundtrip_error"]
