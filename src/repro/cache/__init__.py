from repro.cache.quant import (FP8_DTYPE, FP8_MAX, HostPage, decode_host_page,
                               dequantize_fp8, encode_host_page, quantize_fp8,
                               quant_roundtrip_error)

__all__ = ["FP8_DTYPE", "FP8_MAX", "HostPage", "decode_host_page",
           "dequantize_fp8", "encode_host_page", "quantize_fp8",
           "quant_roundtrip_error"]
