"""vLLM-style paged KV block manager (host-side, pure Python).

XLA wants static shapes, so the device cache is a preallocated paged pool
(``repro.core.opt_kv.make_layer_cache`` / model ``init_cache``) and all
dynamic paging happens here as *indices*: each sequence owns a list of
physical pages; token slot = page_table[pos // ps] * ps + pos % ps.

This is the layer where the paper's §2 "allocator mismatch" bottleneck lives —
and where Opt-KV's SkipSet (Eq. 5) is decided: the manager emits slot indices
of -1 for tokens the policy says never to cache (padding, duplicates,
out-of-window when running the block-sparse long-context policy), so the
device-side scatter drops them without touching memory.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class OutOfBlocks(RuntimeError):
    pass


@dataclass
class SeqBlocks:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0


class BlockManager:
    """Free-list allocator over a pool of ``num_pages`` physical pages."""

    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._seqs: Dict[int, SeqBlocks] = {}

    # ------------------------------------------------------------- alloc --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_allocate(self, num_tokens: int) -> bool:
        need = (num_tokens + self.page_size - 1) // self.page_size
        return need <= self.free_pages

    def allocate(self, seq_id: int, num_tokens: int) -> List[int]:
        """Allocate pages for a new sequence of ``num_tokens`` prompt tokens."""
        assert seq_id not in self._seqs
        need = (num_tokens + self.page_size - 1) // self.page_size
        if need > self.free_pages:
            raise OutOfBlocks(f"need {need} pages, {self.free_pages} free")
        pages = [self._free.pop() for _ in range(need)]
        self._seqs[seq_id] = SeqBlocks(pages, num_tokens)
        return pages

    def append_token(self, seq_id: int) -> int:
        """Account one generated token; grows the page list on boundary.
        Returns the token's flat slot index."""
        sb = self._seqs[seq_id]
        pos = sb.num_tokens
        if pos // self.page_size >= len(sb.pages):
            if not self._free:
                raise OutOfBlocks("decode append: pool exhausted")
            sb.pages.append(self._free.pop())
        sb.num_tokens += 1
        return sb.pages[pos // self.page_size] * self.page_size + \
            pos % self.page_size

    def free(self, seq_id: int) -> None:
        sb = self._seqs.pop(seq_id, None)
        if sb:
            self._free.extend(reversed(sb.pages))

    # ------------------------------------------------------------ queries --
    def num_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def page_table(self, seq_id: int, width: Optional[int] = None) -> np.ndarray:
        """Physical page ids, padded with -1 to ``width`` (gather sentinel)."""
        pages = self._seqs[seq_id].pages
        width = width or len(pages)
        out = np.full(width, -1, np.int32)
        out[: len(pages)] = pages[:width]
        return out

    def slot_indices(self, seq_id: int, positions: np.ndarray,
                     skip: Optional[np.ndarray] = None) -> np.ndarray:
        """Map logical positions -> physical flat slots. ``skip`` marks the
        Opt-KV SkipSet (Eq. 5): those slots come back -1."""
        sb = self._seqs[seq_id]
        pages = np.asarray(sb.pages, np.int32)
        page_of = positions // self.page_size
        slots = pages[page_of] * self.page_size + positions % self.page_size
        slots = slots.astype(np.int32)
        if skip is not None:
            slots = np.where(skip, -1, slots)
        return slots

    def fragmentation(self) -> float:
        """Fraction of allocated slots that hold no token (paper Fig. 3)."""
        alloc = sum(len(s.pages) for s in self._seqs.values()) * self.page_size
        used = sum(s.num_tokens for s in self._seqs.values())
        return 1.0 - used / alloc if alloc else 0.0
