"""Sharded, refcounted paged-KV pool with prefix caching and a host-DRAM
spill tier (host-side, pure Python).

XLA wants static shapes, so the device cache is ONE preallocated paged pool
shared by every sequence (``repro.core.opt_kv.make_layer_cache`` / model
``init_cache`` — leaves shaped ``(2, P_total, ps, Hkv, D)`` with no batch
dimension) and all dynamic paging happens here as *indices*: each sequence
owns a logical-ordered list of physical pages; token slot =
page_table[pos // ps] * ps + pos % ps, a *global* flat slot.

Design (paper §2 "allocator mismatch" + Opt-KV Eq. 5 + Opt-Pa §3.3):

* **Page-range sharding** — the device leaves map the ``pages`` axis onto the
  mesh ``(pod, data)`` axes (launch/steps CACHE_RULES), so physical page p
  lives on exactly one shard. The allocator mirrors that partition: shard s
  owns the contiguous range ``shard_page_ranges(num_pages, num_shards)[s]``
  and keeps its OWN free list, LRU and prefix-hash table. A sequence is
  pinned to one shard at ``allocate`` time and only ever draws pages from
  that shard's range, so the scalar-prefetched page gather of Opt-Pa's "lazy
  memory mapping" never crosses the interconnect. ``OutOfBlocks`` carries the
  pressured shard so the scheduler can preempt *on that shard*.
* **Refcounts** — a physical page may back several sequences (shared prompt
  prefix). Writers only ever touch pages they exclusively own: the trailing
  partial page of a prompt and decode-appended pages are always fresh, so
  sharing is copy-on-write by construction (a shared page is never written).
* **Prefix caching** — full pages of a prompt are registered under a chain
  hash ``h_i = H(h_{i-1}, tokens_of_page_i)`` once their KV has actually been
  computed (``commit_prefill``), in the owning shard's table. ``allocate``
  walks the chain within the sequence's shard and reuses every leading
  full-page hit; ``preferred_shard`` exposes where a prompt's chain-hash head
  lives so the scheduler can place for shard-local CoW reuse. At least one
  prompt token is always left uncached so prefill still emits logits.
* **LRU eviction** — when the last reference to a registered page drops, the
  page parks in its shard's cached-but-unreferenced LRU list instead of the
  free list; allocation pressure evicts from its cold end (hash entry
  removed, page recycled). ``OutOfBlocks`` is raised only when the shard's
  free + evictable both run dry.
* **SkipSet** — the manager emits slot indices of -1 for tokens the policy
  says never to cache (padding, prefix-cache hits, out-of-window tokens), so
  the device-side scatter drops them without touching memory (Eq. 5).

Residency state machine (hierarchical cache, ``CacheConfig.host_pages``)
========================================================================

Every chain hash is in exactly ONE residency state (``PageResidency``)::

                  commit_prefill            LRU eviction + spill_sink
      DROPPED  ────────────────►  DEVICE  ──────────────────────────►  HOST
         ▲                          ▲                                   │
         │ spill_sink refuses /     │ commit_prefetch                   │
         │ host-LRU eviction        │ (next scheduler turn)             │
         └───────── HOST ◄──────────┴───────────── IN_FLIGHT ◄──────────┘
                     ▲           abort_prefetch        begin_prefetch
                     └─────────────────────────────────┘

* DEVICE    — registered in some shard's prefix-hash table; ``allocate``
              can reuse the page directly (refcount bump, zero recompute).
* HOST      — the page's quantized contents live in the host-DRAM store
              (``spill_sink`` slices them out of the pool at eviction);
              matched-but-not-resident, reusable only after a prefetch.
* IN_FLIGHT — ``begin_prefetch`` reserved a device staging page and the
              engine dispatched the host→HBM upload; the hash commits to
              the device table at the NEXT scheduler turn (device dispatch
              order guarantees the upload lands before any later step
              reads the page — no host sync is ever needed to "wait").
* DROPPED   — nowhere: never cached, spilled and then host-LRU-evicted,
              or the spill sink refused (fault injection / tier off).

Two-tier invariants (checked by ``audit()``):

  * the host store and the device tables are DISJOINT on hashes — a hash
    lives in at most one tier (``commit_prefill``/``commit_prefetch`` drop
    the host copy when the hash re-registers on device);
  * staging pages are a fourth page home (free / cached-LRU / referenced /
    staging): reserved in their shard's range, never registered, never
    refcounted;
  * the host store never exceeds ``host_pages`` entries (its own LRU
    evicts to DROPPED);
  * an IN_FLIGHT hash owns its payload exclusively (popped from the host
    store at ``begin_prefetch``; returned on abort, dropped on commit).
"""
from __future__ import annotations

import enum
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import CacheConfig


def padded_pool_pages(num_pages: int, num_shards: int) -> int:
    """Device page count rounded up so the ``pages`` axis tiles evenly over
    the mesh axes it is sharded on (CACHE_RULES: pages -> (pod, data)).
    Models' ``init_cache`` and the scheduler's pool sizing must agree on
    this so host page ids == device page ids."""
    s = max(int(num_shards), 1)
    return ((num_pages + s - 1) // s) * s


def shard_page_ranges(num_pages: int,
                      num_shards: int) -> List[Tuple[int, int]]:
    """Contiguous ``[lo, hi)`` page ranges owned by each shard — the host
    mirror of the device pages-axis sharding. Splits like
    ``np.array_split``: the first ``num_pages % num_shards`` shards get one
    extra page. When the device pool is ``padded_pool_pages`` wide and the
    final page is reserved (write-kernel SkipSet sentinel), the usable
    ``num_pages = P_dev - 1`` splits so every boundary coincides with a
    device shard boundary and only the LAST shard loses the sentinel page.
    """
    s = max(int(num_shards), 1)
    base, rem = divmod(num_pages, s)
    ranges, lo = [], 0
    for i in range(s):
        hi = lo + base + (1 if i < rem else 0)
        ranges.append((lo, hi))
        lo = hi
    return ranges


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be served. ``shard`` names the
    pressured shard (always set by a sharded manager) so the scheduler can
    target preemption."""

    def __init__(self, msg: str, shard: int = 0):
        super().__init__(msg)
        self.shard = shard


class PageResidency(enum.Enum):
    """Where a chain-hashed prefix page currently lives (see the module
    docstring's state machine)."""
    DEVICE = "device"
    HOST = "host"
    IN_FLIGHT = "in_flight"
    DROPPED = "dropped"


class PageHome(enum.Enum):
    """Which allocator structure owns a PHYSICAL device page right now.
    Exactly one home per page — ``audit()`` invariant 1."""
    FREE = "free"            # on its shard's free list
    CACHED = "cached"        # registered, refcount 0, parked in the LRU
    REFERENCED = "referenced"  # held by >= 1 live sequence
    STAGING = "staging"      # reserved for an IN_FLIGHT host->HBM upload


@dataclass(frozen=True)
class PageState:
    """Public page-level state record (replaces the informal tuples and the
    ``_free``/``_lru`` flat-view accessors)."""
    page: int
    shard: int
    home: PageHome
    refcount: int = 0
    hash: Optional[int] = None


@dataclass(frozen=True)
class MatchedPage:
    """One chain-hash probe result of ``match_prefix``: the page ordinal
    within the prompt, its hash, where it lives, and — when device-backed
    (DEVICE / IN_FLIGHT) — the physical page id."""
    index: int
    hash: int
    residency: PageResidency
    page: int = -1


@dataclass(frozen=True)
class PrefixMatch:
    """Residency-first prefix-match result: the longest leading run of the
    prompt's full pages that is *somewhere* (device, host, or in flight),
    gate-trimmed. ``allocate`` can only reuse the DEVICE entries directly;
    the scheduler prefetches the rest before admission."""
    shard: int
    pages: Tuple[MatchedPage, ...] = ()

    def count(self, residency: PageResidency) -> int:
        return sum(1 for p in self.pages if p.residency is residency)

    @property
    def device_pages(self) -> int:
        return self.count(PageResidency.DEVICE)

    @property
    def fetchable(self) -> Tuple[MatchedPage, ...]:
        """Pages that need a host->HBM prefetch (or are already in flight)
        before ``allocate`` on this shard could reuse them."""
        return tuple(p for p in self.pages
                     if p.residency is not PageResidency.DEVICE)


@dataclass
class SeqBlocks:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0
    cached_tokens: int = 0        # leading tokens served by the prefix cache
    committed_pages: int = 0      # full pages registered in the hash table
    committed_hash: int = 0       # running chain hash after committed_pages
                                  # (commit_prefill extends incrementally)
    shard: int = 0                # owning shard — all pages stay in its range


@dataclass
class _Staging:
    """One IN_FLIGHT prefetch: the reserved device page and the host
    payload the upload was built from (kept for retry-on-abort)."""
    page: int
    shard: int
    payload: Any


def _chain_hash(prev: int, toks: Sequence[int]) -> int:
    return hash((prev, tuple(int(t) for t in toks)))


def extend_chain_hash(h: int, token_ids: Sequence[int], from_page: int,
                      to_page: int, page_size: int) -> int:
    """Extend a running chain hash from ``from_page`` to ``to_page`` —
    incremental form so hot paths never rehash from page 0 (O(pages) per
    request instead of O(pages^2) across its chunk ends)."""
    for i in range(from_page, to_page):
        h = _chain_hash(h, token_ids[i * page_size:(i + 1) * page_size])
    return h


def chain_hash_tokens(token_ids: Sequence[int], num_pages: int,
                      page_size: int) -> int:
    """Chain hash of the first ``num_pages`` full pages of ``token_ids`` —
    the key under which those pages are registered in the prefix table.
    Engines use it to key side-band resume artifacts (e.g. recurrent-state
    snapshots at committed page boundaries) to the same identity."""
    return extend_chain_hash(0, token_ids, 0, num_pages, page_size)


class BlockManager:
    """Refcounted free-list allocator over ONE pool of ``num_pages`` pages,
    partitioned into ``num_shards`` contiguous page ranges (the host mirror
    of the device pages-axis sharding), with an optional host-DRAM spill
    tier (module docstring).

    Preferred construction is a resolved ``CacheConfig`` (``num_pages``
    here is the USABLE device page count — the caller has already padded
    the pool and reserved the write sentinel); the legacy
    ``BlockManager(num_pages, page_size, ...)`` positional form keeps
    working as a deprecation shim.
    """

    def __init__(self, num_pages=None, page_size=None,
                 enable_prefix_cache: bool = True, num_shards: int = 1,
                 cfg: Optional[CacheConfig] = None):
        if isinstance(num_pages, CacheConfig) and cfg is None:
            cfg, num_pages = num_pages, None
        if cfg is None:
            # deprecation shim: the pre-CacheConfig knob signature
            cfg = CacheConfig(num_pages=int(num_pages),
                              page_size=int(page_size),
                              num_shards=num_shards,
                              enable_prefix_cache=enable_prefix_cache)
        elif num_pages is not None or page_size is not None:
            raise TypeError("pass geometry via CacheConfig OR the legacy "
                            "positional knobs, not both")
        if cfg.num_pages <= 0 or cfg.page_size <= 0:
            raise ValueError("BlockManager needs a resolved CacheConfig "
                             f"(num_pages/page_size > 0), got {cfg}")
        self.cfg = cfg
        self.num_pages = cfg.num_pages
        self.page_size = cfg.page_size
        self.enable_prefix_cache = cfg.enable_prefix_cache
        self.num_shards = max(int(cfg.num_shards), 1)
        self.host_pages = cfg.host_pages
        self.shard_ranges: List[Tuple[int, int]] = \
            shard_page_ranges(self.num_pages, self.num_shards)
        self._shard_starts = np.asarray([lo for lo, _ in self.shard_ranges])
        # per-shard allocator state
        self._free_by_shard: List[List[int]] = [
            list(range(hi - 1, lo - 1, -1)) for lo, hi in self.shard_ranges]
        self._lru_by_shard: List["OrderedDict[int, None]"] = [
            OrderedDict() for _ in range(self.num_shards)]
        self._hash_by_shard: List[Dict[int, int]] = [
            {} for _ in range(self.num_shards)]
        self._page_to_hash: Dict[int, int] = {}
        self._seqs: Dict[int, SeqBlocks] = {}
        self._ref: Dict[int, int] = {}                 # page -> refcount
        # Optional hash -> bool veto consulted during prefix matching.
        # Recurrent-state families (griffin/rwkv6) set this to "a state
        # snapshot exists for this prefix": reusing KV pages without the
        # recurrent state at that boundary would skip tokens the state has
        # never seen, so a match requires BOTH.
        self.prefix_gate = None
        # ------------------------------------------------- host-DRAM tier --
        # hash -> payload LRU (capacity host_pages); payloads are opaque to
        # the manager — the engine's spill sink produces them and its
        # prefetch path consumes them
        self._host: "OrderedDict[int, Any]" = OrderedDict()
        self._staging: Dict[int, _Staging] = {}        # hash -> IN_FLIGHT
        # engine-provided (h, page, shard) -> payload | None; None means
        # the page could not be spilled (tier off / fault) and is DROPPED
        self.spill_sink: Optional[Callable[[int, int, int], Any]] = None
        # hashes whose device copy arrived via prefetch; consumed (once)
        # by the next allocate that prefix-hits them, splitting hit
        # attribution into device- vs host-served
        self._host_sourced: set = set()
        # ------------------------------------------------------------ stats --
        self.prefix_queries = 0       # full prompt pages looked up
        self.prefix_hits = 0          # full prompt pages served from cache
        self.prefix_device_hits = 0   # ... of which were device-resident
        self.prefix_host_hits = 0     # ... of which the host tier restored
        self.evictions = 0
        self.fresh_pages_allocated = 0  # pages handed out (not prefix hits)
        self.spilled_pages = 0        # evictions captured by the host tier
        self.host_evictions = 0       # host-LRU drops (HOST -> DROPPED)
        self.prefetch_begun = 0
        self.prefetch_committed = 0
        self.prefetch_aborted = 0

    # ------------------------------------------------------------- queries --
    @property
    def host_tier_enabled(self) -> bool:
        return self.host_pages > 0 and self.spill_sink is not None

    @property
    def free_pages(self) -> int:
        return sum(len(fl) for fl in self._free_by_shard)

    @property
    def evictable_pages(self) -> int:
        return sum(len(lru) for lru in self._lru_by_shard)

    @property
    def staging_pages(self) -> int:
        return len(self._staging)

    @property
    def host_resident_pages(self) -> int:
        return len(self._host)

    @property
    def pages_in_use(self) -> int:
        """Pages referenced by at least one live sequence."""
        return (self.num_pages - self.free_pages - self.evictable_pages
                - self.staging_pages)

    def shard_of(self, page: int) -> int:
        """Owning shard of a physical page id."""
        return int(np.searchsorted(self._shard_starts, page, "right") - 1)

    def shard_capacity(self, shard: int) -> int:
        lo, hi = self.shard_ranges[shard]
        return hi - lo

    def max_shard_capacity(self) -> int:
        return max(hi - lo for lo, hi in self.shard_ranges)

    def free_pages_in(self, shard: int) -> int:
        return len(self._free_by_shard[shard])

    def evictable_pages_in(self, shard: int) -> int:
        return len(self._lru_by_shard[shard])

    def staging_pages_in(self, shard: int) -> int:
        return sum(1 for st in self._staging.values() if st.shard == shard)

    def pages_in_use_in(self, shard: int) -> int:
        return (self.shard_capacity(shard) - self.free_pages_in(shard)
                - self.evictable_pages_in(shard)
                - self.staging_pages_in(shard))

    def seq_shard(self, seq_id: int) -> int:
        return self._seqs[seq_id].shard

    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages if self.num_pages else 0.0

    def shard_utilization(self, shard: int) -> float:
        cap = self.shard_capacity(shard)
        return self.pages_in_use_in(shard) / cap if cap else 0.0

    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_queries \
            if self.prefix_queries else 0.0

    def page_states(self) -> Dict[int, PageState]:
        """Every physical page's public state record — the ONE sanctioned
        view of the allocator's structures (the old ``_free``/``_lru``
        flat-view accessors are gone). O(pages); not for the hot path."""
        out: Dict[int, PageState] = {}
        for s in range(self.num_shards):
            for p in self._free_by_shard[s]:
                out[p] = PageState(p, s, PageHome.FREE)
            for p in self._lru_by_shard[s]:
                out[p] = PageState(p, s, PageHome.CACHED,
                                   hash=self._page_to_hash.get(p))
        for h, st in self._staging.items():
            out[st.page] = PageState(st.page, st.shard, PageHome.STAGING,
                                     hash=h)
        for p, r in self._ref.items():
            out[p] = PageState(p, self.shard_of(p), PageHome.REFERENCED,
                               refcount=r, hash=self._page_to_hash.get(p))
        return out

    def residency(self, h: int) -> PageResidency:
        """Residency of a chain hash (DEVICE takes priority — the staging /
        host records of a hash die when it re-registers on device)."""
        if any(h in t for t in self._hash_by_shard):
            return PageResidency.DEVICE
        if h in self._staging:
            return PageResidency.IN_FLIGHT
        if h in self._host:
            return PageResidency.HOST
        return PageResidency.DROPPED

    def residency_counts(self) -> Dict[PageResidency, int]:
        """Population of each residency state (DROPPED is unbounded and
        reported as 0)."""
        return {PageResidency.DEVICE: len(self._page_to_hash),
                PageResidency.HOST: len(self._host),
                PageResidency.IN_FLIGHT: len(self._staging),
                PageResidency.DROPPED: 0}

    def shared_page_counts(self) -> Dict[int, int]:
        """Physical pages held by more than one live sequence, with their
        refcounts. These are exactly the pages the cross-lane visit grid
        (kernels.visits) can batch when the holders decode in one step."""
        return {p: st.refcount for p, st in self.page_states().items()
                if st.refcount > 1}

    def sharing_histogram(self) -> Dict[int, int]:
        """Histogram refcount -> number of shared pages (refcount > 1)."""
        hist: Dict[int, int] = {}
        for r in self.shared_page_counts().values():
            hist[r] = hist.get(r, 0) + 1
        return hist

    def can_allocate(self, num_tokens: int,
                     shard: Optional[int] = None) -> bool:
        need = (num_tokens + self.page_size - 1) // self.page_size
        if shard is not None:
            return need <= (self.free_pages_in(shard)
                            + self.evictable_pages_in(shard))
        return any(need <= self.free_pages_in(s) + self.evictable_pages_in(s)
                   for s in range(self.num_shards))

    def num_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def cached_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].cached_tokens

    # ---------------------------------------------------------- placement --
    def preferred_shard(self, token_ids: Optional[Sequence[int]],
                        num_tokens: int) -> Optional[int]:
        """Shard where this prompt's chain-hash HEAD (first full page) is
        registered, or None — the scheduler's prefix-affinity placement
        hint (reuse is only possible shard-locally)."""
        if (not self.enable_prefix_cache or token_ids is None
                or num_tokens <= self.page_size):
            return None
        # restorability (prefix_gate) is deliberately NOT consulted here:
        # placement affinity only needs to know where the prompt's pages
        # LIVE; _match_prefix decides how much of them is actually reusable
        h = _chain_hash(0, token_ids[: self.page_size])
        for s in range(self.num_shards):
            if h in self._hash_by_shard[s]:
                return s
        return None

    def least_loaded_shard(self) -> int:
        """Shard with the most allocatable (free + evictable) pages; ties
        break toward the fewest live pages, then the lowest id."""
        return min(range(self.num_shards), key=self.load_key)

    def load_key(self, shard: int):
        """Sort key ordering shards least-loaded first."""
        return (-(self.free_pages_in(shard) + self.evictable_pages_in(shard)),
                self.pages_in_use_in(shard), shard)

    # ----------------------------------------------------- residency match --
    def match_prefix(self, token_ids: Optional[Sequence[int]],
                     num_tokens: int,
                     shard: Optional[int] = None) -> PrefixMatch:
        """Residency-first prefix lookup: the longest leading run of the
        prompt's full pages that exists in ANY tier, per page with its
        ``PageResidency``. Read-only — touches no stats, pins nothing —
        so the scheduler can plan prefetches for still-queued requests
        without skewing hit accounting (``allocate`` does the counting
        when reuse actually happens).

        With ``shard=None`` every shard is walked and the deepest match
        wins (ties toward more DEVICE-resident pages). Gate-trimmed the
        same way as ``allocate``'s device match; never matches the entire
        prompt (at least one token always recomputes)."""
        if not self.enable_prefix_cache or token_ids is None:
            return PrefixMatch(shard=shard if shard is not None else 0)
        shards = [shard] if shard is not None else range(self.num_shards)
        best: Optional[PrefixMatch] = None
        for s in shards:
            m = self._walk_residency(token_ids, num_tokens, s)
            if best is None or ((len(m.pages), m.device_pages)
                                > (len(best.pages), best.device_pages)):
                best = m
        return best

    def _walk_residency(self, token_ids: Sequence[int], num_tokens: int,
                        shard: int) -> PrefixMatch:
        max_match = (num_tokens - 1) // self.page_size   # full pages, < all
        table = self._hash_by_shard[shard]
        pages: List[MatchedPage] = []
        gated = 0
        h = 0
        for i in range(max_match):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            if h in table:
                mp = MatchedPage(i, h, PageResidency.DEVICE, table[h])
            elif h in self._staging and self._staging[h].shard == shard:
                mp = MatchedPage(i, h, PageResidency.IN_FLIGHT,
                                 self._staging[h].page)
            elif h in self._host:
                mp = MatchedPage(i, h, PageResidency.HOST)
            else:
                break
            pages.append(mp)
            if self.prefix_gate is None or self.prefix_gate(h):
                gated = len(pages)
        return PrefixMatch(shard=shard, pages=tuple(pages[:gated]))

    # -------------------------------------------------------------- alloc --
    def _evict_one(self, shard: int, spare_host_sourced: bool = False) -> None:
        lru = self._lru_by_shard[shard]
        # Victim selection: cold end first, but pages a prefetch just landed
        # (``_host_sourced``, not yet consumed by their requester's
        # allocate) are passed over while ANY other evictable page exists —
        # without this grace period the running lanes' page growth steals
        # freshly-prefetched pages before the gated request admits, and the
        # host tier converges to pure churn. Allocation for LIVE work
        # (admission, decode growth) may still take them as a last resort;
        # staging allocation (``spare_host_sourced``) may not — one queued
        # request's prefetch evicting another's landed pages is exactly the
        # churn the grace period exists to stop, and refusing just bounds
        # the prefetch depth to the shard's actual headroom.
        page = next((p for p in lru
                     if self._page_to_hash[p] not in self._host_sourced),
                    None)
        if page is None:
            if spare_host_sourced:
                raise OutOfBlocks(
                    f"shard {shard}: only landed-prefetch pages are "
                    f"evictable; no headroom for more staging", shard)
            # All evictable pages are landed prefetches: steal the HOT end.
            # Commits happen in queue order, so the hot end belongs to the
            # deepest-queued request — farthest from admission, with time
            # to re-prefetch. Stealing the cold end would hit the NEXT
            # request to admit, breaking its chain match and cascading the
            # steal down the whole queue (each broken admission allocates
            # fresh pages and steals its successor's prefix).
            page, _ = lru.popitem(last=True)
        else:
            del lru[page]
        h = self._page_to_hash.pop(page)
        table = self._hash_by_shard[shard]
        if table.get(h) == page:
            del table[h]
            # Hierarchical tier: capture the evicted prefix host-side
            # instead of destroying it — but only when the hash leaves the
            # DEVICE tier entirely (it may survive on another shard) and
            # is not already HOST / IN_FLIGHT.
            if (self.host_tier_enabled and h not in self._host
                    and h not in self._staging
                    and not any(h in t for t in self._hash_by_shard)):
                payload = self.spill_sink(h, page, shard)
                if payload is not None:
                    self._host_insert(h, payload)
                    self.spilled_pages += 1
        self._host_sourced.discard(h)   # an unused prefetched copy died
        self._free_by_shard[shard].append(page)
        self.evictions += 1

    def _host_insert(self, h: int, payload) -> None:
        self._host[h] = payload
        self._host.move_to_end(h)
        while len(self._host) > self.host_pages:         # host LRU: cold end
            self._host.popitem(last=False)
            self.host_evictions += 1

    def _pop_free(self, shard: int, spare_host_sourced: bool = False) -> int:
        """Pop a physical page off the shard's free list, evicting (and
        possibly spilling) the LRU cold end when it is empty."""
        if not self._free_by_shard[shard]:
            if not self._lru_by_shard[shard]:
                raise OutOfBlocks(
                    f"shard {shard} exhausted (free + cached empty)", shard)
            self._evict_one(shard, spare_host_sourced)
        return self._free_by_shard[shard].pop()

    def _take_free(self, shard: int) -> int:
        self.fresh_pages_allocated += 1
        return self._pop_free(shard)

    # ----------------------------------------------------------- prefetch --
    def begin_prefetch(self, h: int, shard: int) -> Tuple[int, Any]:
        """Reserve a staging page on ``shard`` for a host-resident hash and
        transition it HOST -> IN_FLIGHT. Returns (staging page id, host
        payload) — the engine dispatches the actual host->HBM upload.
        Raises ``OutOfBlocks`` when the shard has no page to stage into
        (the request then admits with whatever already landed)."""
        if h not in self._host:
            raise KeyError(f"hash {h} is not host-resident "
                           f"({self.residency(h).value})")
        # may evict/spill; may raise — but never steals a landed prefetch
        page = self._pop_free(shard, spare_host_sourced=True)
        payload = self._host.pop(h)
        self._staging[h] = _Staging(page, shard, payload)
        self.prefetch_begun += 1
        return page, payload

    def commit_prefetch(self, h: int) -> bool:
        """Land an IN_FLIGHT hash: register the staging page in its shard's
        prefix table (parked at the LRU's hot end, refcount 0, evictable —
        exactly like a just-freed registered page). Call only AFTER the
        upload is ordered before any step that could read the page; in this
        engine that is "the next scheduler turn" (dispatch order). Returns
        False when the fetch lost a race — the hash re-registered on device
        meanwhile — in which case the staging page is simply freed."""
        st = self._staging.pop(h, None)
        if st is None:
            return False
        table = self._hash_by_shard[st.shard]
        if h in table or st.page in self._page_to_hash \
                or any(h in t for t in self._hash_by_shard):
            # a concurrent recompute registered the same prefix: keep the
            # device copy, drop ours (each hash lives in ONE tier)
            self._free_by_shard[st.shard].append(st.page)
            self.prefetch_aborted += 1
            return False
        table[h] = st.page
        self._page_to_hash[st.page] = h
        self._lru_by_shard[st.shard][st.page] = None     # hot end
        self._host_sourced.add(h)
        self.prefetch_committed += 1
        return True

    def abort_prefetch(self, h: int) -> bool:
        """Fail an IN_FLIGHT hash (fault injection / engine drain): free
        the staging page and return the payload to the host store so the
        fetch is retriable (IN_FLIGHT -> HOST), unless the hash
        re-registered on device meanwhile (then the payload is dropped to
        keep the tiers disjoint)."""
        st = self._staging.pop(h, None)
        if st is None:
            return False
        self._free_by_shard[st.shard].append(st.page)
        self.prefetch_aborted += 1
        if not any(h in t for t in self._hash_by_shard):
            self._host_insert(h, st.payload)
        return True

    def _match_prefix(self, token_ids: Optional[Sequence[int]],
                      num_tokens: int,
                      shard: int) -> Tuple[List[int], int, int, List[int]]:
        """Leading full-page DEVICE cache hits for this prompt WITHIN
        ``shard``. Returns (hit pages, matched token count, chain hash at
        the match boundary, consumed host-sourced markers). Never matches
        the ENTIRE prompt — at least one token is recomputed so prefill
        emits logits.

        With a ``prefix_gate`` the match is TRIMMED back to the deepest
        boundary the gate accepts (not broken at the first rejection):
        recurrent-state snapshots only exist at chunk-end boundaries, so
        intermediate page hashes are registered but not restorable."""
        if not self.enable_prefix_cache or token_ids is None:
            return [], 0, 0, []
        max_match = (num_tokens - 1) // self.page_size   # full pages, < all
        table = self._hash_by_shard[shard]
        hits: List[int] = []
        hashes: List[int] = []
        gated = 0                      # deepest gate-accepted page count
        h = 0
        for i in range(max_match):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            self.prefix_queries += 1
            page = table.get(h)
            if page is None:
                break
            hits.append(page)
            hashes.append(h)
            if self.prefix_gate is None or self.prefix_gate(h):
                gated = len(hits)
        hits = hits[:gated]
        consumed: List[int] = []
        for hh in hashes[:gated]:      # device-hit vs host-restored split
            if hh in self._host_sourced:
                self._host_sourced.discard(hh)
                consumed.append(hh)
                self.prefix_host_hits += 1
            else:
                self.prefix_device_hits += 1
        self.prefix_hits += len(hits)
        return hits, len(hits) * self.page_size, \
            (hashes[gated - 1] if gated else 0), consumed

    def allocate(self, seq_id: int, num_tokens: int,
                 token_ids: Optional[Sequence[int]] = None,
                 shard: Optional[int] = None) -> Tuple[List[int], int]:
        """Allocate pages for a new sequence of ``num_tokens`` prompt tokens,
        pinned to ``shard`` (default: the least-loaded shard; with one shard
        this is the PR-1 behaviour unchanged).

        ``token_ids`` (when given) enables prefix caching: leading full pages
        whose chain hash is registered ON THIS SHARD are reused (refcount
        bumped, zero fresh pages, zero recompute). Returns
        (pages, cached_token_count).
        """
        assert seq_id not in self._seqs
        if shard is None:
            shard = self.least_loaded_shard()
        need = (num_tokens + self.page_size - 1) // self.page_size
        stats_snap = (self.prefix_queries, self.prefix_hits,
                      self.prefix_device_hits, self.prefix_host_hits)
        hits, cached, h_match, consumed = \
            self._match_prefix(token_ids, num_tokens, shard)
        for p in hits:                                  # commit the reuse
            self._ref[p] = self._ref.get(p, 0) + 1      # may come off the LRU
            self._lru_by_shard[shard].pop(p, None)
        fresh_need = need - len(hits)
        # capacity check AFTER pinning the hits — a hit sitting in the LRU
        # must not be double-counted as evictable capacity
        avail = self.free_pages_in(shard) + self.evictable_pages_in(shard)
        if fresh_need > avail:
            for p in reversed(hits):                    # unwind the pins
                self._ref[p] -= 1
                if self._ref[p] == 0:
                    del self._ref[p]
                    self._lru_by_shard[shard][p] = None  # back to the cache
            # a failed attempt reused nothing: keep the surfaced hit-rate
            # stats clean when the scheduler probes several shards (the
            # host-sourced markers it consumed come back too)
            (self.prefix_queries, self.prefix_hits,
             self.prefix_device_hits, self.prefix_host_hits) = stats_snap
            self._host_sourced.update(consumed)
            raise OutOfBlocks(
                f"shard {shard}: need {fresh_need} fresh pages, "
                f"{self.free_pages_in(shard)}+"
                f"{self.evictable_pages_in(shard)} free+cached", shard)
        pages = list(hits)
        for _ in range(fresh_need):
            p = self._take_free(shard)
            self._ref[p] = 1
            pages.append(p)
        self._seqs[seq_id] = SeqBlocks(pages, num_tokens, cached,
                                       committed_pages=len(hits),
                                       committed_hash=h_match,
                                       shard=shard)
        return pages, cached

    def commit_prefill(self, seq_id: int, computed_tokens: int,
                       token_ids: Optional[Sequence[int]] = None) -> None:
        """Register full prompt pages whose KV is now actually written, so
        later arrivals can prefix-hit them (in the owning shard's table).
        Idempotent per page. Re-registering a hash the host tier still
        holds drops the host copy — a freshly computed device page
        supersedes it (hash lives in ONE tier)."""
        if not self.enable_prefix_cache or token_ids is None:
            return
        sb = self._seqs[seq_id]
        table = self._hash_by_shard[sb.shard]
        full = computed_tokens // self.page_size
        if full <= sb.committed_pages:
            return
        h = sb.committed_hash          # resume the chain: O(new pages) only
        for i in range(sb.committed_pages, full):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            page = sb.pages[i]
            if h not in table and page not in self._page_to_hash:
                table[h] = page
                self._page_to_hash[page] = h
                self._host.pop(h, None)
        sb.committed_pages = full
        sb.committed_hash = h

    def append_token(self, seq_id: int) -> int:
        """Account one generated token; grows the page list on boundary
        (drawing ONLY from the sequence's own shard). Returns the token's
        global flat slot index."""
        sb = self._seqs[seq_id]
        pos = sb.num_tokens
        if pos // self.page_size >= len(sb.pages):
            p = self._take_free(sb.shard)               # may evict; may raise
            self._ref[p] = 1
            sb.pages.append(p)
        sb.num_tokens += 1
        return sb.pages[pos // self.page_size] * self.page_size + \
            pos % self.page_size

    def free(self, seq_id: int) -> None:
        """Drop the sequence's references. Registered pages whose refcount
        hits zero park in their shard's LRU prefix cache; others return to
        the shard free list. Used both for FINISHED requests and for
        preemption."""
        sb = self._seqs.pop(seq_id, None)
        if not sb:
            return
        for p in reversed(sb.pages):
            self._ref[p] -= 1
            if self._ref[p] > 0:
                continue
            del self._ref[p]
            if p in self._page_to_hash:
                self._lru_by_shard[sb.shard][p] = None  # cached, evictable
            else:
                self._free_by_shard[sb.shard].append(p)

    # -------------------------------------------------------------- audit --
    def audit(self) -> List[str]:
        """Invariant auditor: cross-check refcounts, free lists, LRUs, the
        prefix tables AND the host tier against the ground truth (the live
        sequences). Returns human-readable violations (empty = the pool is
        clean) — the chaos suite's oracle after every fault episode,
        O(pages), not for the hot path. Invariants:

          1. every physical page is in EXACTLY one home (``PageHome``):
             its shard's free list, its shard's LRU, referenced by a live
             sequence, or reserved as an IN_FLIGHT staging page;
          2. ``_ref[p]`` equals p's multiplicity across live sequences
             (no leaked or dangling refcounts, none <= 0);
          3. the shard prefix tables and ``_page_to_hash`` are inverse
             bijections; LRU pages are all registered, free and staging
             pages never;
          4. a sequence's pages are duplicate-free, inside its pinned
             shard's range, and exactly ``ceil(num_tokens / page_size)``;
          5. two-tier: host-store hashes are disjoint from every device
             table and from the staging ledger; the store respects its
             ``host_pages`` capacity (empty when the tier is off).
        """
        out: List[str] = []
        ps = self.page_size

        counts: Dict[int, int] = {}            # ground-truth refcounts
        for sid, sb in self._seqs.items():
            lo, hi = self.shard_ranges[sb.shard]
            if len(set(sb.pages)) != len(sb.pages):
                out.append(f"seq {sid}: duplicate page in its page list")
            need = (sb.num_tokens + ps - 1) // ps
            if len(sb.pages) != need:
                out.append(f"seq {sid}: {len(sb.pages)} pages for "
                           f"{sb.num_tokens} tokens (want {need})")
            for p in sb.pages:
                counts[p] = counts.get(p, 0) + 1
                if not lo <= p < hi:
                    out.append(f"seq {sid}: page {p} outside its shard "
                               f"{sb.shard} range [{lo},{hi})")
        if counts != self._ref:
            for p in set(counts) | set(self._ref):
                have, want = self._ref.get(p, 0), counts.get(p, 0)
                if have != want:
                    out.append(f"page {p}: refcount {have}, but "
                               f"{want} live sequence(s) hold it")

        seen: Dict[int, str] = {}              # page -> which home
        for p in self._ref:
            seen[p] = "referenced"
        for h, st in self._staging.items():
            lo, hi = self.shard_ranges[st.shard]
            if not lo <= st.page < hi:
                out.append(f"staging page {st.page} (hash {h}) outside "
                           f"shard {st.shard} range [{lo},{hi})")
            if st.page in seen:
                out.append(f"page {st.page}: staging AND {seen[st.page]}")
            seen[st.page] = "staging"
            if st.page in self._page_to_hash:
                out.append(f"staging page {st.page} is still registered "
                           "in the prefix table")
        for s in range(self.num_shards):
            lo, hi = self.shard_ranges[s]
            for home, pages in (("free", self._free_by_shard[s]),
                                ("lru", self._lru_by_shard[s])):
                for p in pages:
                    if not lo <= p < hi:
                        out.append(f"shard {s} {home} list: page {p} "
                                   f"outside range [{lo},{hi})")
                    if p in seen:
                        out.append(f"page {p}: in shard {s} {home} list "
                                   f"AND {seen[p]}")
                    seen[p] = f"shard {s} {home}"
        missing = set(range(self.num_pages)) - set(seen)
        if missing:
            out.append(f"leaked pages (no free list, LRU, staging slot, "
                       f"or live sequence holds them): {sorted(missing)}")

        # prefix tables <-> _page_to_hash must be inverse bijections
        entries = 0
        for s in range(self.num_shards):
            lo, hi = self.shard_ranges[s]
            for h, p in self._hash_by_shard[s].items():
                entries += 1
                if self._page_to_hash.get(p) != h:
                    out.append(f"shard {s} prefix table: hash {h} -> page "
                               f"{p}, but _page_to_hash says "
                               f"{self._page_to_hash.get(p)}")
                if not lo <= p < hi:
                    out.append(f"shard {s} prefix table: page {p} outside "
                               f"range [{lo},{hi})")
        if entries != len(self._page_to_hash):
            out.append(f"{len(self._page_to_hash)} pages registered but "
                       f"{entries} prefix-table entries")
        for s in range(self.num_shards):
            for p in self._lru_by_shard[s]:
                if p not in self._page_to_hash:
                    out.append(f"shard {s} LRU: page {p} unregistered "
                               "(should be on the free list)")
            for p in self._free_by_shard[s]:
                if p in self._page_to_hash:
                    out.append(f"shard {s} free list: page {p} still "
                               "registered in the prefix table")

        # two-tier invariants (5)
        if self.host_pages <= 0 and self._host:
            out.append(f"host tier disabled but the store holds "
                       f"{len(self._host)} page(s)")
        if self.host_pages > 0 and len(self._host) > self.host_pages:
            out.append(f"host store over capacity: {len(self._host)} > "
                       f"{self.host_pages}")
        for h in self._host:
            if h in self._staging:
                out.append(f"hash {h}: HOST and IN_FLIGHT simultaneously")
            for s in range(self.num_shards):
                if h in self._hash_by_shard[s]:
                    out.append(f"hash {h}: in the host store AND shard "
                               f"{s}'s device table")

        if not self._seqs and self.pages_in_use:
            out.append(f"no live sequences but pages_in_use = "
                       f"{self.pages_in_use}")
        return out

    # ------------------------------------------------------------ mapping --
    def page_table(self, seq_id: int, width: Optional[int] = None) -> np.ndarray:
        """Physical page ids in logical order, padded with -1 to ``width``
        (gather sentinel)."""
        pages = self._seqs[seq_id].pages
        width = width or len(pages)
        out = np.full(width, -1, np.int32)
        out[: len(pages)] = pages[:width]
        return out

    def slot_indices(self, seq_id: int, positions: np.ndarray,
                     skip: Optional[np.ndarray] = None) -> np.ndarray:
        """Map logical positions -> global physical flat slots. ``skip``
        marks the Opt-KV SkipSet (Eq. 5): those slots come back -1."""
        sb = self._seqs[seq_id]
        pages = np.asarray(sb.pages, np.int32)
        page_of = positions // self.page_size
        slots = pages[page_of] * self.page_size + positions % self.page_size
        slots = slots.astype(np.int32)
        if skip is not None:
            slots = np.where(skip, -1, slots)
        return slots

    def fragmentation(self) -> float:
        """Fraction of referenced slots that hold no token (paper Fig. 3).
        Shared pages are counted once — the pooled allocator's whole point."""
        live = {p for s in self._seqs.values() for p in s.pages}
        alloc = len(live) * self.page_size
        used = sum(s.num_tokens for s in self._seqs.values())
        return max(1.0 - used / alloc, 0.0) if alloc else 0.0
