"""Global refcounted paged-KV pool with prefix caching (host-side, pure
Python).

XLA wants static shapes, so the device cache is ONE preallocated paged pool
shared by every sequence (``repro.core.opt_kv.make_layer_cache`` / model
``init_cache`` — leaves shaped ``(2, P_total, ps, Hkv, D)`` with no batch
dimension) and all dynamic paging happens here as *indices*: each sequence
owns a logical-ordered list of physical pages; token slot =
page_table[pos // ps] * ps + pos % ps, now a *global* flat slot.

Design (paper §2 "allocator mismatch" + Opt-KV Eq. 5):

* **Refcounts** — a physical page may back several sequences (shared prompt
  prefix). Writers only ever touch pages they exclusively own: the trailing
  partial page of a prompt and decode-appended pages are always fresh, so
  sharing is copy-on-write by construction (a shared page is never written).
* **Prefix caching** — full pages of a prompt are registered under a chain
  hash ``h_i = H(h_{i-1}, tokens_of_page_i)`` once their KV has actually been
  computed (``commit_prefill``). ``allocate`` walks the chain and reuses every
  leading full-page hit, so a request sharing a >= 1-page prefix allocates
  fewer fresh pages and skips recomputing those tokens. At least one prompt
  token is always left uncached so prefill still emits last-token logits.
* **LRU eviction** — when the last reference to a registered page drops, the
  page parks in a cached-but-unreferenced LRU list instead of the free list;
  allocation pressure evicts from its cold end (hash entry removed, page
  recycled). ``OutOfBlocks`` is raised only when free + evictable both run
  dry — the scheduler reacts by preempting the youngest running request.
* **SkipSet** — the manager emits slot indices of -1 for tokens the policy
  says never to cache (padding, prefix-cache hits, out-of-window tokens), so
  the device-side scatter drops them without touching memory (Eq. 5).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class OutOfBlocks(RuntimeError):
    pass


@dataclass
class SeqBlocks:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0
    cached_tokens: int = 0        # leading tokens served by the prefix cache
    committed_pages: int = 0      # full pages registered in the hash table


def _chain_hash(prev: int, toks: Sequence[int]) -> int:
    return hash((prev, tuple(int(t) for t in toks)))


class BlockManager:
    """Refcounted free-list allocator over ONE pool of ``num_pages`` pages."""

    def __init__(self, num_pages: int, page_size: int,
                 enable_prefix_cache: bool = True):
        self.num_pages = num_pages
        self.page_size = page_size
        self.enable_prefix_cache = enable_prefix_cache
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._seqs: Dict[int, SeqBlocks] = {}
        self._ref: Dict[int, int] = {}                 # page -> refcount
        self._hash_to_page: Dict[int, int] = {}
        self._page_to_hash: Dict[int, int] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # cached, ref==0
        # ------------------------------------------------------------ stats --
        self.prefix_queries = 0       # full prompt pages looked up
        self.prefix_hits = 0          # full prompt pages served from cache
        self.evictions = 0
        self.fresh_pages_allocated = 0  # pages handed out (not prefix hits)

    # ------------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def evictable_pages(self) -> int:
        return len(self._lru)

    @property
    def pages_in_use(self) -> int:
        """Pages referenced by at least one live sequence."""
        return self.num_pages - len(self._free) - len(self._lru)

    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages if self.num_pages else 0.0

    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_queries \
            if self.prefix_queries else 0.0

    def can_allocate(self, num_tokens: int) -> bool:
        need = (num_tokens + self.page_size - 1) // self.page_size
        return need <= self.free_pages + self.evictable_pages

    def num_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def cached_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].cached_tokens

    # -------------------------------------------------------------- alloc --
    def _evict_one(self) -> None:
        page, _ = self._lru.popitem(last=False)        # cold end
        h = self._page_to_hash.pop(page)
        if self._hash_to_page.get(h) == page:
            del self._hash_to_page[h]
        self._free.append(page)
        self.evictions += 1

    def _take_free(self) -> int:
        if not self._free:
            if not self._lru:
                raise OutOfBlocks("pool exhausted (free + cached empty)")
            self._evict_one()
        self.fresh_pages_allocated += 1
        return self._free.pop()

    def _match_prefix(self, token_ids: Optional[Sequence[int]],
                      num_tokens: int) -> Tuple[List[int], int]:
        """Leading full-page cache hits for this prompt. Returns
        (hit pages, matched token count). Never matches the ENTIRE prompt —
        at least one token is recomputed so prefill emits logits."""
        if not self.enable_prefix_cache or token_ids is None:
            return [], 0
        max_match = (num_tokens - 1) // self.page_size   # full pages, < all
        hits: List[int] = []
        h = 0
        for i in range(max_match):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            self.prefix_queries += 1
            page = self._hash_to_page.get(h)
            if page is None:
                break
            hits.append(page)
            self.prefix_hits += 1
        return hits, len(hits) * self.page_size

    def allocate(self, seq_id: int, num_tokens: int,
                 token_ids: Optional[Sequence[int]] = None) -> Tuple[List[int], int]:
        """Allocate pages for a new sequence of ``num_tokens`` prompt tokens.

        ``token_ids`` (when given) enables prefix caching: leading full pages
        whose chain hash is registered are reused (refcount bumped, zero fresh
        pages, zero recompute). Returns (pages, cached_token_count).
        """
        assert seq_id not in self._seqs
        need = (num_tokens + self.page_size - 1) // self.page_size
        hits, cached = self._match_prefix(token_ids, num_tokens)
        for p in hits:                                  # commit the reuse
            self._ref[p] = self._ref.get(p, 0) + 1      # may come off the LRU
            self._lru.pop(p, None)
        fresh_need = need - len(hits)
        # capacity check AFTER pinning the hits — a hit sitting in the LRU
        # must not be double-counted as evictable capacity
        if fresh_need > self.free_pages + self.evictable_pages:
            for p in reversed(hits):                    # unwind the pins
                self._ref[p] -= 1
                if self._ref[p] == 0:
                    del self._ref[p]
                    self._lru[p] = None                 # back to the cache
            raise OutOfBlocks(
                f"need {fresh_need} fresh pages, "
                f"{self.free_pages}+{self.evictable_pages} free+cached")
        pages = list(hits)
        for _ in range(fresh_need):
            p = self._take_free()
            self._ref[p] = 1
            pages.append(p)
        self._seqs[seq_id] = SeqBlocks(pages, num_tokens, cached,
                                       committed_pages=len(hits))
        return pages, cached

    def commit_prefill(self, seq_id: int, computed_tokens: int,
                       token_ids: Optional[Sequence[int]] = None) -> None:
        """Register full prompt pages whose KV is now actually written, so
        later arrivals can prefix-hit them. Idempotent per page."""
        if not self.enable_prefix_cache or token_ids is None:
            return
        sb = self._seqs[seq_id]
        full = computed_tokens // self.page_size
        if full <= sb.committed_pages:
            return
        h = 0
        for i in range(full):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            if i < sb.committed_pages:
                continue                                # already registered
            page = sb.pages[i]
            if h not in self._hash_to_page and page not in self._page_to_hash:
                self._hash_to_page[h] = page
                self._page_to_hash[page] = h
        sb.committed_pages = full

    def append_token(self, seq_id: int) -> int:
        """Account one generated token; grows the page list on boundary.
        Returns the token's global flat slot index."""
        sb = self._seqs[seq_id]
        pos = sb.num_tokens
        if pos // self.page_size >= len(sb.pages):
            p = self._take_free()                       # may evict; may raise
            self._ref[p] = 1
            sb.pages.append(p)
        sb.num_tokens += 1
        return sb.pages[pos // self.page_size] * self.page_size + \
            pos % self.page_size

    def free(self, seq_id: int) -> None:
        """Drop the sequence's references. Registered pages whose refcount
        hits zero park in the LRU prefix cache; others return to the free
        list. Used both for FINISHED requests and for preemption."""
        sb = self._seqs.pop(seq_id, None)
        if not sb:
            return
        for p in reversed(sb.pages):
            self._ref[p] -= 1
            if self._ref[p] > 0:
                continue
            del self._ref[p]
            if p in self._page_to_hash:
                self._lru[p] = None                     # cached, evictable
            else:
                self._free.append(p)

    # ------------------------------------------------------------ mapping --
    def page_table(self, seq_id: int, width: Optional[int] = None) -> np.ndarray:
        """Physical page ids in logical order, padded with -1 to ``width``
        (gather sentinel)."""
        pages = self._seqs[seq_id].pages
        width = width or len(pages)
        out = np.full(width, -1, np.int32)
        out[: len(pages)] = pages[:width]
        return out

    def slot_indices(self, seq_id: int, positions: np.ndarray,
                     skip: Optional[np.ndarray] = None) -> np.ndarray:
        """Map logical positions -> global physical flat slots. ``skip``
        marks the Opt-KV SkipSet (Eq. 5): those slots come back -1."""
        sb = self._seqs[seq_id]
        pages = np.asarray(sb.pages, np.int32)
        page_of = positions // self.page_size
        slots = pages[page_of] * self.page_size + positions % self.page_size
        slots = slots.astype(np.int32)
        if skip is not None:
            slots = np.where(skip, -1, slots)
        return slots

    def fragmentation(self) -> float:
        """Fraction of referenced slots that hold no token (paper Fig. 3).
        Shared pages are counted once — the pooled allocator's whole point."""
        live = {p for s in self._seqs.values() for p in s.pages}
        alloc = len(live) * self.page_size
        used = sum(s.num_tokens for s in self._seqs.values())
        return max(1.0 - used / alloc, 0.0) if alloc else 0.0
