"""Sharded, refcounted paged-KV pool with prefix caching (host-side, pure
Python).

XLA wants static shapes, so the device cache is ONE preallocated paged pool
shared by every sequence (``repro.core.opt_kv.make_layer_cache`` / model
``init_cache`` — leaves shaped ``(2, P_total, ps, Hkv, D)`` with no batch
dimension) and all dynamic paging happens here as *indices*: each sequence
owns a logical-ordered list of physical pages; token slot =
page_table[pos // ps] * ps + pos % ps, a *global* flat slot.

Design (paper §2 "allocator mismatch" + Opt-KV Eq. 5 + Opt-Pa §3.3):

* **Page-range sharding** — the device leaves map the ``pages`` axis onto the
  mesh ``(pod, data)`` axes (launch/steps CACHE_RULES), so physical page p
  lives on exactly one shard. The allocator mirrors that partition: shard s
  owns the contiguous range ``shard_page_ranges(num_pages, num_shards)[s]``
  and keeps its OWN free list, LRU and prefix-hash table. A sequence is
  pinned to one shard at ``allocate`` time and only ever draws pages from
  that shard's range, so the scalar-prefetched page gather of Opt-Pa's "lazy
  memory mapping" never crosses the interconnect. ``OutOfBlocks`` carries the
  pressured shard so the scheduler can preempt *on that shard*.
* **Refcounts** — a physical page may back several sequences (shared prompt
  prefix). Writers only ever touch pages they exclusively own: the trailing
  partial page of a prompt and decode-appended pages are always fresh, so
  sharing is copy-on-write by construction (a shared page is never written).
* **Prefix caching** — full pages of a prompt are registered under a chain
  hash ``h_i = H(h_{i-1}, tokens_of_page_i)`` once their KV has actually been
  computed (``commit_prefill``), in the owning shard's table. ``allocate``
  walks the chain within the sequence's shard and reuses every leading
  full-page hit; ``preferred_shard`` exposes where a prompt's chain-hash head
  lives so the scheduler can place for shard-local CoW reuse. At least one
  prompt token is always left uncached so prefill still emits logits.
* **LRU eviction** — when the last reference to a registered page drops, the
  page parks in its shard's cached-but-unreferenced LRU list instead of the
  free list; allocation pressure evicts from its cold end (hash entry
  removed, page recycled). ``OutOfBlocks`` is raised only when the shard's
  free + evictable both run dry.
* **SkipSet** — the manager emits slot indices of -1 for tokens the policy
  says never to cache (padding, prefix-cache hits, out-of-window tokens), so
  the device-side scatter drops them without touching memory (Eq. 5).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def padded_pool_pages(num_pages: int, num_shards: int) -> int:
    """Device page count rounded up so the ``pages`` axis tiles evenly over
    the mesh axes it is sharded on (CACHE_RULES: pages -> (pod, data)).
    Models' ``init_cache`` and the scheduler's pool sizing must agree on
    this so host page ids == device page ids."""
    s = max(int(num_shards), 1)
    return ((num_pages + s - 1) // s) * s


def shard_page_ranges(num_pages: int,
                      num_shards: int) -> List[Tuple[int, int]]:
    """Contiguous ``[lo, hi)`` page ranges owned by each shard — the host
    mirror of the device pages-axis sharding. Splits like
    ``np.array_split``: the first ``num_pages % num_shards`` shards get one
    extra page. When the device pool is ``padded_pool_pages`` wide and the
    final page is reserved (write-kernel SkipSet sentinel), the usable
    ``num_pages = P_dev - 1`` splits so every boundary coincides with a
    device shard boundary and only the LAST shard loses the sentinel page.
    """
    s = max(int(num_shards), 1)
    base, rem = divmod(num_pages, s)
    ranges, lo = [], 0
    for i in range(s):
        hi = lo + base + (1 if i < rem else 0)
        ranges.append((lo, hi))
        lo = hi
    return ranges


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be served. ``shard`` names the
    pressured shard (always set by a sharded manager) so the scheduler can
    target preemption."""

    def __init__(self, msg: str, shard: int = 0):
        super().__init__(msg)
        self.shard = shard


@dataclass
class SeqBlocks:
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0
    cached_tokens: int = 0        # leading tokens served by the prefix cache
    committed_pages: int = 0      # full pages registered in the hash table
    committed_hash: int = 0       # running chain hash after committed_pages
                                  # (commit_prefill extends incrementally)
    shard: int = 0                # owning shard — all pages stay in its range


def _chain_hash(prev: int, toks: Sequence[int]) -> int:
    return hash((prev, tuple(int(t) for t in toks)))


def extend_chain_hash(h: int, token_ids: Sequence[int], from_page: int,
                      to_page: int, page_size: int) -> int:
    """Extend a running chain hash from ``from_page`` to ``to_page`` —
    incremental form so hot paths never rehash from page 0 (O(pages) per
    request instead of O(pages^2) across its chunk ends)."""
    for i in range(from_page, to_page):
        h = _chain_hash(h, token_ids[i * page_size:(i + 1) * page_size])
    return h


def chain_hash_tokens(token_ids: Sequence[int], num_pages: int,
                      page_size: int) -> int:
    """Chain hash of the first ``num_pages`` full pages of ``token_ids`` —
    the key under which those pages are registered in the prefix table.
    Engines use it to key side-band resume artifacts (e.g. recurrent-state
    snapshots at committed page boundaries) to the same identity."""
    return extend_chain_hash(0, token_ids, 0, num_pages, page_size)


class BlockManager:
    """Refcounted free-list allocator over ONE pool of ``num_pages`` pages,
    partitioned into ``num_shards`` contiguous page ranges (the host mirror
    of the device pages-axis sharding)."""

    def __init__(self, num_pages: int, page_size: int,
                 enable_prefix_cache: bool = True, num_shards: int = 1):
        self.num_pages = num_pages
        self.page_size = page_size
        self.enable_prefix_cache = enable_prefix_cache
        self.num_shards = max(int(num_shards), 1)
        self.shard_ranges: List[Tuple[int, int]] = \
            shard_page_ranges(num_pages, self.num_shards)
        self._shard_starts = np.asarray([lo for lo, _ in self.shard_ranges])
        # per-shard allocator state
        self._free_by_shard: List[List[int]] = [
            list(range(hi - 1, lo - 1, -1)) for lo, hi in self.shard_ranges]
        self._lru_by_shard: List["OrderedDict[int, None]"] = [
            OrderedDict() for _ in range(self.num_shards)]
        self._hash_by_shard: List[Dict[int, int]] = [
            {} for _ in range(self.num_shards)]
        self._page_to_hash: Dict[int, int] = {}
        self._seqs: Dict[int, SeqBlocks] = {}
        self._ref: Dict[int, int] = {}                 # page -> refcount
        # Optional hash -> bool veto consulted during prefix matching.
        # Recurrent-state families (griffin/rwkv6) set this to "a state
        # snapshot exists for this prefix": reusing KV pages without the
        # recurrent state at that boundary would skip tokens the state has
        # never seen, so a match requires BOTH.
        self.prefix_gate = None
        # ------------------------------------------------------------ stats --
        self.prefix_queries = 0       # full prompt pages looked up
        self.prefix_hits = 0          # full prompt pages served from cache
        self.evictions = 0
        self.fresh_pages_allocated = 0  # pages handed out (not prefix hits)

    # ------------------------------------------------------------- queries --
    @property
    def _free(self) -> List[int]:
        """Flat view of every shard's free list (read-only compat)."""
        return [p for fl in self._free_by_shard for p in fl]

    @property
    def _lru(self) -> "OrderedDict[int, None]":
        """Flat view of every shard's LRU (read-only compat)."""
        out: "OrderedDict[int, None]" = OrderedDict()
        for lru in self._lru_by_shard:
            out.update(lru)
        return out

    @property
    def free_pages(self) -> int:
        return sum(len(fl) for fl in self._free_by_shard)

    @property
    def evictable_pages(self) -> int:
        return sum(len(lru) for lru in self._lru_by_shard)

    @property
    def pages_in_use(self) -> int:
        """Pages referenced by at least one live sequence."""
        return self.num_pages - self.free_pages - self.evictable_pages

    def shard_of(self, page: int) -> int:
        """Owning shard of a physical page id."""
        return int(np.searchsorted(self._shard_starts, page, "right") - 1)

    def shard_capacity(self, shard: int) -> int:
        lo, hi = self.shard_ranges[shard]
        return hi - lo

    def max_shard_capacity(self) -> int:
        return max(hi - lo for lo, hi in self.shard_ranges)

    def free_pages_in(self, shard: int) -> int:
        return len(self._free_by_shard[shard])

    def evictable_pages_in(self, shard: int) -> int:
        return len(self._lru_by_shard[shard])

    def pages_in_use_in(self, shard: int) -> int:
        return (self.shard_capacity(shard) - self.free_pages_in(shard)
                - self.evictable_pages_in(shard))

    def seq_shard(self, seq_id: int) -> int:
        return self._seqs[seq_id].shard

    def utilization(self) -> float:
        return self.pages_in_use / self.num_pages if self.num_pages else 0.0

    def shard_utilization(self, shard: int) -> float:
        cap = self.shard_capacity(shard)
        return self.pages_in_use_in(shard) / cap if cap else 0.0

    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / self.prefix_queries \
            if self.prefix_queries else 0.0

    def shared_page_counts(self) -> Dict[int, int]:
        """Physical pages held by more than one live sequence, with their
        refcounts. These are exactly the pages the cross-lane visit grid
        (kernels.visits) can batch when the holders decode in one step."""
        return {p: r for p, r in self._ref.items() if r > 1}

    def sharing_histogram(self) -> Dict[int, int]:
        """Histogram refcount -> number of shared pages (refcount > 1)."""
        hist: Dict[int, int] = {}
        for r in self._ref.values():
            if r > 1:
                hist[r] = hist.get(r, 0) + 1
        return hist

    def can_allocate(self, num_tokens: int,
                     shard: Optional[int] = None) -> bool:
        need = (num_tokens + self.page_size - 1) // self.page_size
        if shard is not None:
            return need <= (self.free_pages_in(shard)
                            + self.evictable_pages_in(shard))
        return any(need <= self.free_pages_in(s) + self.evictable_pages_in(s)
                   for s in range(self.num_shards))

    def num_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].num_tokens

    def cached_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].cached_tokens

    # ---------------------------------------------------------- placement --
    def preferred_shard(self, token_ids: Optional[Sequence[int]],
                        num_tokens: int) -> Optional[int]:
        """Shard where this prompt's chain-hash HEAD (first full page) is
        registered, or None — the scheduler's prefix-affinity placement
        hint (reuse is only possible shard-locally)."""
        if (not self.enable_prefix_cache or token_ids is None
                or num_tokens <= self.page_size):
            return None
        # restorability (prefix_gate) is deliberately NOT consulted here:
        # placement affinity only needs to know where the prompt's pages
        # LIVE; _match_prefix decides how much of them is actually reusable
        h = _chain_hash(0, token_ids[: self.page_size])
        for s in range(self.num_shards):
            if h in self._hash_by_shard[s]:
                return s
        return None

    def least_loaded_shard(self) -> int:
        """Shard with the most allocatable (free + evictable) pages; ties
        break toward the fewest live pages, then the lowest id."""
        return min(range(self.num_shards), key=self.load_key)

    def load_key(self, shard: int):
        """Sort key ordering shards least-loaded first."""
        return (-(self.free_pages_in(shard) + self.evictable_pages_in(shard)),
                self.pages_in_use_in(shard), shard)

    # -------------------------------------------------------------- alloc --
    def _evict_one(self, shard: int) -> None:
        page, _ = self._lru_by_shard[shard].popitem(last=False)  # cold end
        h = self._page_to_hash.pop(page)
        table = self._hash_by_shard[shard]
        if table.get(h) == page:
            del table[h]
        self._free_by_shard[shard].append(page)
        self.evictions += 1

    def _take_free(self, shard: int) -> int:
        if not self._free_by_shard[shard]:
            if not self._lru_by_shard[shard]:
                raise OutOfBlocks(
                    f"shard {shard} exhausted (free + cached empty)", shard)
            self._evict_one(shard)
        self.fresh_pages_allocated += 1
        return self._free_by_shard[shard].pop()

    def _match_prefix(self, token_ids: Optional[Sequence[int]],
                      num_tokens: int,
                      shard: int) -> Tuple[List[int], int, int]:
        """Leading full-page cache hits for this prompt WITHIN ``shard``.
        Returns (hit pages, matched token count, chain hash at the match
        boundary). Never matches the ENTIRE prompt — at least one token is
        recomputed so prefill emits logits.

        With a ``prefix_gate`` the match is TRIMMED back to the deepest
        boundary the gate accepts (not broken at the first rejection):
        recurrent-state snapshots only exist at chunk-end boundaries, so
        intermediate page hashes are registered but not restorable."""
        if not self.enable_prefix_cache or token_ids is None:
            return [], 0, 0
        max_match = (num_tokens - 1) // self.page_size   # full pages, < all
        table = self._hash_by_shard[shard]
        hits: List[int] = []
        hashes: List[int] = []
        gated = 0                      # deepest gate-accepted page count
        h = 0
        for i in range(max_match):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            self.prefix_queries += 1
            page = table.get(h)
            if page is None:
                break
            hits.append(page)
            hashes.append(h)
            if self.prefix_gate is None or self.prefix_gate(h):
                gated = len(hits)
        hits = hits[:gated]
        self.prefix_hits += len(hits)
        return hits, len(hits) * self.page_size, \
            (hashes[gated - 1] if gated else 0)

    def allocate(self, seq_id: int, num_tokens: int,
                 token_ids: Optional[Sequence[int]] = None,
                 shard: Optional[int] = None) -> Tuple[List[int], int]:
        """Allocate pages for a new sequence of ``num_tokens`` prompt tokens,
        pinned to ``shard`` (default: the least-loaded shard; with one shard
        this is the PR-1 behaviour unchanged).

        ``token_ids`` (when given) enables prefix caching: leading full pages
        whose chain hash is registered ON THIS SHARD are reused (refcount
        bumped, zero fresh pages, zero recompute). Returns
        (pages, cached_token_count).
        """
        assert seq_id not in self._seqs
        if shard is None:
            shard = self.least_loaded_shard()
        need = (num_tokens + self.page_size - 1) // self.page_size
        stats_snap = (self.prefix_queries, self.prefix_hits)
        hits, cached, h_match = self._match_prefix(token_ids, num_tokens,
                                                   shard)
        for p in hits:                                  # commit the reuse
            self._ref[p] = self._ref.get(p, 0) + 1      # may come off the LRU
            self._lru_by_shard[shard].pop(p, None)
        fresh_need = need - len(hits)
        # capacity check AFTER pinning the hits — a hit sitting in the LRU
        # must not be double-counted as evictable capacity
        avail = self.free_pages_in(shard) + self.evictable_pages_in(shard)
        if fresh_need > avail:
            for p in reversed(hits):                    # unwind the pins
                self._ref[p] -= 1
                if self._ref[p] == 0:
                    del self._ref[p]
                    self._lru_by_shard[shard][p] = None  # back to the cache
            # a failed attempt reused nothing: keep the surfaced hit-rate
            # stats clean when the scheduler probes several shards
            self.prefix_queries, self.prefix_hits = stats_snap
            raise OutOfBlocks(
                f"shard {shard}: need {fresh_need} fresh pages, "
                f"{self.free_pages_in(shard)}+"
                f"{self.evictable_pages_in(shard)} free+cached", shard)
        pages = list(hits)
        for _ in range(fresh_need):
            p = self._take_free(shard)
            self._ref[p] = 1
            pages.append(p)
        self._seqs[seq_id] = SeqBlocks(pages, num_tokens, cached,
                                       committed_pages=len(hits),
                                       committed_hash=h_match,
                                       shard=shard)
        return pages, cached

    def commit_prefill(self, seq_id: int, computed_tokens: int,
                       token_ids: Optional[Sequence[int]] = None) -> None:
        """Register full prompt pages whose KV is now actually written, so
        later arrivals can prefix-hit them (in the owning shard's table).
        Idempotent per page."""
        if not self.enable_prefix_cache or token_ids is None:
            return
        sb = self._seqs[seq_id]
        table = self._hash_by_shard[sb.shard]
        full = computed_tokens // self.page_size
        if full <= sb.committed_pages:
            return
        h = sb.committed_hash          # resume the chain: O(new pages) only
        for i in range(sb.committed_pages, full):
            lo = i * self.page_size
            h = _chain_hash(h, token_ids[lo:lo + self.page_size])
            page = sb.pages[i]
            if h not in table and page not in self._page_to_hash:
                table[h] = page
                self._page_to_hash[page] = h
        sb.committed_pages = full
        sb.committed_hash = h

    def append_token(self, seq_id: int) -> int:
        """Account one generated token; grows the page list on boundary
        (drawing ONLY from the sequence's own shard). Returns the token's
        global flat slot index."""
        sb = self._seqs[seq_id]
        pos = sb.num_tokens
        if pos // self.page_size >= len(sb.pages):
            p = self._take_free(sb.shard)               # may evict; may raise
            self._ref[p] = 1
            sb.pages.append(p)
        sb.num_tokens += 1
        return sb.pages[pos // self.page_size] * self.page_size + \
            pos % self.page_size

    def free(self, seq_id: int) -> None:
        """Drop the sequence's references. Registered pages whose refcount
        hits zero park in their shard's LRU prefix cache; others return to
        the shard free list. Used both for FINISHED requests and for
        preemption."""
        sb = self._seqs.pop(seq_id, None)
        if not sb:
            return
        for p in reversed(sb.pages):
            self._ref[p] -= 1
            if self._ref[p] > 0:
                continue
            del self._ref[p]
            if p in self._page_to_hash:
                self._lru_by_shard[sb.shard][p] = None  # cached, evictable
            else:
                self._free_by_shard[sb.shard].append(p)

    # -------------------------------------------------------------- audit --
    def audit(self) -> List[str]:
        """Invariant auditor: cross-check refcounts, free lists, LRUs and
        the prefix tables against the ground truth (the live sequences).
        Returns human-readable violations (empty = the pool is clean) —
        the chaos suite's oracle after every fault episode, O(pages), not
        for the hot path. Invariants:

          1. every physical page is in EXACTLY one of {its shard's free
             list, its shard's LRU, referenced by a live sequence};
          2. ``_ref[p]`` equals p's multiplicity across live sequences
             (no leaked or dangling refcounts, none <= 0);
          3. the shard prefix tables and ``_page_to_hash`` are inverse
             bijections; LRU pages are all registered, free pages never;
          4. a sequence's pages are duplicate-free, inside its pinned
             shard's range, and exactly ``ceil(num_tokens / page_size)``.
        """
        out: List[str] = []
        ps = self.page_size

        counts: Dict[int, int] = {}            # ground-truth refcounts
        for sid, sb in self._seqs.items():
            lo, hi = self.shard_ranges[sb.shard]
            if len(set(sb.pages)) != len(sb.pages):
                out.append(f"seq {sid}: duplicate page in its page list")
            need = (sb.num_tokens + ps - 1) // ps
            if len(sb.pages) != need:
                out.append(f"seq {sid}: {len(sb.pages)} pages for "
                           f"{sb.num_tokens} tokens (want {need})")
            for p in sb.pages:
                counts[p] = counts.get(p, 0) + 1
                if not lo <= p < hi:
                    out.append(f"seq {sid}: page {p} outside its shard "
                               f"{sb.shard} range [{lo},{hi})")
        if counts != self._ref:
            for p in set(counts) | set(self._ref):
                have, want = self._ref.get(p, 0), counts.get(p, 0)
                if have != want:
                    out.append(f"page {p}: refcount {have}, but "
                               f"{want} live sequence(s) hold it")

        seen: Dict[int, str] = {}              # page -> which home
        for p in self._ref:
            seen[p] = "referenced"
        for s in range(self.num_shards):
            lo, hi = self.shard_ranges[s]
            for home, pages in (("free", self._free_by_shard[s]),
                                ("lru", self._lru_by_shard[s])):
                for p in pages:
                    if not lo <= p < hi:
                        out.append(f"shard {s} {home} list: page {p} "
                                   f"outside range [{lo},{hi})")
                    if p in seen:
                        out.append(f"page {p}: in shard {s} {home} list "
                                   f"AND {seen[p]}")
                    seen[p] = f"shard {s} {home}"
        missing = set(range(self.num_pages)) - set(seen)
        if missing:
            out.append(f"leaked pages (no free list, LRU, or live "
                       f"sequence holds them): {sorted(missing)}")

        # prefix tables <-> _page_to_hash must be inverse bijections
        entries = 0
        for s in range(self.num_shards):
            lo, hi = self.shard_ranges[s]
            for h, p in self._hash_by_shard[s].items():
                entries += 1
                if self._page_to_hash.get(p) != h:
                    out.append(f"shard {s} prefix table: hash {h} -> page "
                               f"{p}, but _page_to_hash says "
                               f"{self._page_to_hash.get(p)}")
                if not lo <= p < hi:
                    out.append(f"shard {s} prefix table: page {p} outside "
                               f"range [{lo},{hi})")
        if entries != len(self._page_to_hash):
            out.append(f"{len(self._page_to_hash)} pages registered but "
                       f"{entries} prefix-table entries")
        for s in range(self.num_shards):
            for p in self._lru_by_shard[s]:
                if p not in self._page_to_hash:
                    out.append(f"shard {s} LRU: page {p} unregistered "
                               "(should be on the free list)")
            for p in self._free_by_shard[s]:
                if p in self._page_to_hash:
                    out.append(f"shard {s} free list: page {p} still "
                               "registered in the prefix table")
        if not self._seqs and self.pages_in_use:
            out.append(f"no live sequences but pages_in_use = "
                       f"{self.pages_in_use}")
        return out

    # ------------------------------------------------------------ mapping --
    def page_table(self, seq_id: int, width: Optional[int] = None) -> np.ndarray:
        """Physical page ids in logical order, padded with -1 to ``width``
        (gather sentinel)."""
        pages = self._seqs[seq_id].pages
        width = width or len(pages)
        out = np.full(width, -1, np.int32)
        out[: len(pages)] = pages[:width]
        return out

    def slot_indices(self, seq_id: int, positions: np.ndarray,
                     skip: Optional[np.ndarray] = None) -> np.ndarray:
        """Map logical positions -> global physical flat slots. ``skip``
        marks the Opt-KV SkipSet (Eq. 5): those slots come back -1."""
        sb = self._seqs[seq_id]
        pages = np.asarray(sb.pages, np.int32)
        page_of = positions // self.page_size
        slots = pages[page_of] * self.page_size + positions % self.page_size
        slots = slots.astype(np.int32)
        if skip is not None:
            slots = np.where(skip, -1, slots)
        return slots

    def fragmentation(self) -> float:
        """Fraction of referenced slots that hold no token (paper Fig. 3).
        Shared pages are counted once — the pooled allocator's whole point."""
        live = {p for s in self._seqs.values() for p in s.pages}
        alloc = len(live) * self.page_size
        used = sum(s.num_tokens for s in self._seqs.values())
        return max(1.0 - used / alloc, 0.0) if alloc else 0.0
