"""FP8 (e4m3) quantization for the KV cache — Opt-KV's storage format.

The paper emulates FP8 via INT8 SIMD on the DCU; on TPU we use native
``float8_e4m3fn`` storage with bf16/f32 compute (DESIGN.md §3). Scales are
per-(token, head) — one f32 per head vector — which keeps the dequant fused
multiply cheap while tracking the "varying dynamic ranges of different
tensors" the paper calls out (§1, ref [9-11]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = 448.0  # e4m3fn finite max
_EPS = 1e-12


def quantize_fp8(x: jax.Array, axis: int = -1):
    """x (..., D) -> (q fp8 (..., D), scale f32 (...,) reduced over ``axis``)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis)
    scale = jnp.maximum(amax, _EPS) / FP8_MAX
    q = (xf / jnp.expand_dims(scale, axis)).astype(FP8_DTYPE)
    return q, scale


def dequantize_fp8(q: jax.Array, scale: jax.Array, axis: int = -1,
                   dtype=jnp.bfloat16) -> jax.Array:
    """Eq. 6: k~ = dequant(k_fp8)."""
    return (q.astype(jnp.float32) * jnp.expand_dims(scale, axis)).astype(dtype)


# ------------------------------------------------- MLA latent (dual-scale) --
def quantize_latent(latent: jax.Array, lora_rank: int):
    """MLA latent cache entry ``[c_kv | k_rope]`` (..., R+dr) -> FP8 with
    DUAL per-token scales (..., 2): column 0 scales the c_kv segment,
    column 1 the k_rope segment. The two segments come from different
    projections with different dynamic ranges — a shared scale would crush
    the smaller segment's mantissa."""
    qc, sc = quantize_fp8(latent[..., :lora_rank], axis=-1)
    qr, sr = quantize_fp8(latent[..., lora_rank:], axis=-1)
    return jnp.concatenate([qc, qr], axis=-1), jnp.stack([sc, sr], axis=-1)


def dequantize_latent(q: jax.Array, scales: jax.Array, lora_rank: int,
                      dtype=jnp.float32) -> jax.Array:
    """Eq. 6 read path for the latent layout: (..., R+dr) fp8 + (..., 2)
    dual scales -> dequantized latent (c_kv and k_rope segments scaled
    separately)."""
    c = dequantize_fp8(q[..., :lora_rank], scales[..., 0], axis=-1,
                       dtype=dtype)
    r = dequantize_fp8(q[..., lora_rank:], scales[..., 1], axis=-1,
                       dtype=dtype)
    return jnp.concatenate([c, r], axis=-1)


def quant_roundtrip_error(x: jax.Array, axis: int = -1) -> jax.Array:
    """Max relative error of the fp8 roundtrip (accuracy-proxy benchmarks)."""
    q, s = quantize_fp8(x, axis)
    back = dequantize_fp8(q, s, axis, jnp.float32)
    denom = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                                keepdims=True), _EPS)
    return jnp.max(jnp.abs(back - x.astype(jnp.float32)) / denom)
