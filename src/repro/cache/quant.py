"""FP8 (e4m3) quantization for the KV cache — Opt-KV's storage format.

The paper emulates FP8 via INT8 SIMD on the DCU; on TPU we use native
``float8_e4m3fn`` storage with bf16/f32 compute (DESIGN.md §3). Scales are
per-(token, head) — one f32 per head vector — which keeps the dequant fused
multiply cheap while tracking the "varying dynamic ranges of different
tensors" the paper calls out (§1, ref [9-11]).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = 448.0  # e4m3fn finite max
_EPS = 1e-12


def quantize_fp8(x: jax.Array, axis: int = -1):
    """x (..., D) -> (q fp8 (..., D), scale f32 (...,) reduced over ``axis``)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis)
    scale = jnp.maximum(amax, _EPS) / FP8_MAX
    q = (xf / jnp.expand_dims(scale, axis)).astype(FP8_DTYPE)
    return q, scale


def dequantize_fp8(q: jax.Array, scale: jax.Array, axis: int = -1,
                   dtype=jnp.bfloat16) -> jax.Array:
    """Eq. 6: k~ = dequant(k_fp8)."""
    return (q.astype(jnp.float32) * jnp.expand_dims(scale, axis)).astype(dtype)


# ------------------------------------------------- MLA latent (dual-scale) --
def quantize_latent(latent: jax.Array, lora_rank: int):
    """MLA latent cache entry ``[c_kv | k_rope]`` (..., R+dr) -> FP8 with
    DUAL per-token scales (..., 2): column 0 scales the c_kv segment,
    column 1 the k_rope segment. The two segments come from different
    projections with different dynamic ranges — a shared scale would crush
    the smaller segment's mantissa."""
    qc, sc = quantize_fp8(latent[..., :lora_rank], axis=-1)
    qr, sr = quantize_fp8(latent[..., lora_rank:], axis=-1)
    return jnp.concatenate([qc, qr], axis=-1), jnp.stack([sc, sr], axis=-1)


def dequantize_latent(q: jax.Array, scales: jax.Array, lora_rank: int,
                      dtype=jnp.float32) -> jax.Array:
    """Eq. 6 read path for the latent layout: (..., R+dr) fp8 + (..., 2)
    dual scales -> dequantized latent (c_kv and k_rope segments scaled
    separately)."""
    c = dequantize_fp8(q[..., :lora_rank], scales[..., 0], axis=-1,
                       dtype=dtype)
    r = dequantize_fp8(q[..., lora_rank:], scales[..., 1], axis=-1,
                       dtype=dtype)
    return jnp.concatenate([c, r], axis=-1)


# --------------------------------------------- host-DRAM spill page codec --
@dataclasses.dataclass
class HostPage:
    """One spilled prefix page: a per-pool-leaf slice of the device pool,
    moved host-side by the hierarchical cache's spill sink.

    ``leaves`` holds one array per pool leaf (the page slice with the
    ``pages`` axis removed). When ``encoded`` is set, bf16 leaves were
    fp8-quantized on spill and ``scales[name]`` carries the per-vector f32
    scales needed to dequantize on prefetch; fp8 / f32 leaves (opt_kv pools
    and their scale leaves) are always carried verbatim so the spill →
    prefetch roundtrip stays byte-lossless for them.
    """
    leaves: Dict[str, jax.Array]
    scales: Dict[str, jax.Array]
    encoded: bool

    @property
    def nbytes(self) -> int:
        # shape/dtype metadata only — never forces a device->host sync
        arrs = list(self.leaves.values()) + list(self.scales.values())
        return sum(a.size * a.dtype.itemsize for a in arrs)

    def to_device(self, device) -> "HostPage":
        """Asynchronously move every leaf to ``device`` (``jax.device_put``
        does not block; ordering against later pool writes is guaranteed
        by dispatch order)."""
        put = lambda d: {k: jax.device_put(v, device) for k, v in d.items()}
        return HostPage(put(self.leaves), put(self.scales), self.encoded)


def encode_host_page(leaves: Dict[str, jax.Array],
                     quantize: bool = False) -> HostPage:
    """Pack pool-page slices for the host store.

    Pass-through by default (byte-lossless). With ``quantize`` every
    bfloat16 leaf is fp8(e4m3)-encoded with per-vector scales over the last
    axis — the Opt-KV storage format applied at spill time — while
    narrower / non-bf16 leaves (already-fp8 kv, f32 scales, int metadata)
    stay verbatim.
    """
    out: Dict[str, jax.Array] = {}
    scales: Dict[str, jax.Array] = {}
    encoded = False
    for name, arr in leaves.items():
        if quantize and arr.dtype == jnp.bfloat16:
            out[name], scales[name] = quantize_fp8(arr, axis=-1)
            encoded = True
        else:
            out[name] = arr
    return HostPage(out, scales, encoded)


def decode_host_page(page: HostPage, name: str,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Decode one leaf of a host page back to its pool dtype."""
    arr = page.leaves[name]
    if name in page.scales:
        return dequantize_fp8(arr, page.scales[name], axis=-1, dtype=dtype)
    return arr


def quant_roundtrip_error(x: jax.Array, axis: int = -1) -> jax.Array:
    """Max relative error of the fp8 roundtrip (accuracy-proxy benchmarks)."""
    q, s = quantize_fp8(x, axis)
    back = dequantize_fp8(q, s, axis, jnp.float32)
    denom = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                                keepdims=True), _EPS)
    return jnp.max(jnp.abs(back - x.astype(jnp.float32)) / denom)
