from repro.cache.block_manager import PageResidency, PrefixMatch
from repro.configs.base import CacheConfig
from repro.serving.engine import Engine, EngineConfig
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.frontend import (AsyncEngine, PipelineStallError,
                                    TokenStream, WorkerKilled)
from repro.serving.request import FinishReason, Request, RequestState
from repro.serving.sampler import SamplingParams

__all__ = ["AsyncEngine", "CacheConfig", "Engine", "EngineConfig",
           "FaultInjector", "FaultPlan", "FinishReason", "PageResidency",
           "PipelineStallError", "PrefixMatch", "Request", "RequestState",
           "SamplingParams", "TokenStream", "WorkerKilled"]
