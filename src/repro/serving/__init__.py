from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams

__all__ = ["Engine", "EngineConfig", "Request", "RequestState",
           "SamplingParams"]
