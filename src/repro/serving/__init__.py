from repro.serving.engine import Engine, EngineConfig
from repro.serving.frontend import AsyncEngine, TokenStream
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams

__all__ = ["AsyncEngine", "Engine", "EngineConfig", "Request",
           "RequestState", "SamplingParams", "TokenStream"]
