from repro.serving.engine import Engine, EngineConfig
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.frontend import (AsyncEngine, PipelineStallError,
                                    TokenStream, WorkerKilled)
from repro.serving.request import FinishReason, Request, RequestState
from repro.serving.sampler import SamplingParams

__all__ = ["AsyncEngine", "Engine", "EngineConfig", "FaultInjector",
           "FaultPlan", "FinishReason", "PipelineStallError", "Request",
           "RequestState", "SamplingParams", "TokenStream", "WorkerKilled"]
