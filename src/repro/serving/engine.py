"""LLM-CoOpt serving engine: continuous batching over ONE shared, refcounted,
prefix-cached paged-KV pool, with the paper's three techniques selected by a
``CoOptConfig``.

The engine is the "vLLM migration target" of the paper: the Original mode
reproduces unmodified-vLLM semantics (bf16 cache, every allocated page
loaded, per-head KV expansion) and each Opt-* flag turns on one technique,
so Figs. 6-7's five modes are one constructor argument apart.

Design (hardware adaptation, DESIGN.md §3): the device cache is a GLOBAL
paged pool — per-layer leaves ``(2, P_total, ps, Hkv, D)`` with no batch
dimension, ``P_total = num_lanes * pages(max_len)`` padded to tile evenly
over ``num_shards`` KV shards (the final page reserved as the write
kernel's SkipSet sentinel). The pool's page range is partitioned along the
mesh ``(pod, data)`` axes — the axes CACHE_RULES shard the pages axis over —
and every request is pinned to ONE shard at admission, so its page gathers
stay shard-local. All dynamic paging state (per-shard free lists, refcounts,
per-shard prefix-cache hash tables, slot indices, SkipSets) lives host-side
in the Scheduler/BlockManager; the device sees only static-shape index
arrays: global ``slot_idx``, per-lane ``page_table``, per-lane
``cache_len``. Lane isolation is enforced by slot disjointness — a lane can
only write pages it exclusively owns (shared prefix pages are read-only by
refcount construction) — so cache updates need no batch masking; only
batch-major leaves (per-lane lengths, recurrent state, whisper cross-KV) are
masked with the admitted-lane mask.

Scheduling (Sarathi-style): each step is composed under a token budget,
mixing decode tokens and chunked-prefill chunks. For chunk-capable families
(dense/moe) the whole step is ONE device call through the continuation
prefill path (a decode lane is a chunk of length 1); other families run one
bucketed prefill + one decode call per step. Admission is shard-affine
(prefix-affinity first, least-loaded fallback). Shard exhaustion preempts
the youngest running request ON THE PRESSURED SHARD (freed pages,
front-of-queue requeue, greedy-exact resume) instead of crashing;
impossible requests are REJECTED and surfaced.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.models import get_model
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams, sample
from repro.serving.scheduler import (DecodeItem, PrefillChunk, Scheduler,
                                     StepPlan, bucket_len)


@dataclass(frozen=True)
class EngineConfig:
    num_lanes: int = 4
    max_len: int = 512
    prefill_buckets: Tuple[int, ...] = (64, 128, 256, 512)
    long_window: int = 0            # >0: block-sparse long-context decode
    sampling: SamplingParams = SamplingParams()
    seed: int = 0
    token_budget: int = 0           # 0 => max(prefill_buckets)
    enable_prefix_cache: bool = True
    num_shards: int = 1             # KV-pool page-range shards; matches the
                                    # mesh (pod, data) extent the cache
                                    # pages axis is sharded over
                                    # (launch.mesh.kv_shard_count)


@dataclass
class EngineStats:
    prefill_calls: int = 0
    decode_steps: int = 0
    mixed_steps: int = 0            # decode + prefill fused in one call
    generated_tokens: int = 0
    prefill_time: float = 0.0
    decode_time: float = 0.0
    # ----------------------------------------------------- pool health ----
    pool_pages: int = 0
    pages_in_use: int = 0           # referenced by live sequences (now)
    peak_pages_in_use: int = 0
    fresh_pages_allocated: int = 0  # pages handed out over the run
    prefix_cache_queries: int = 0
    prefix_cache_hits: int = 0      # full prompt pages reused, not recomputed
    preemptions: int = 0
    rejected: int = 0
    # --------------------------------------------------- sharded pool ----
    num_shards: int = 1
    shard_pages: Tuple[int, ...] = ()          # page-range size per shard
    shard_pages_in_use: Tuple[int, ...] = ()
    peak_shard_pages_in_use: Tuple[int, ...] = ()
    shard_preemptions: Tuple[int, ...] = ()    # per-shard pressure evictions
    placement_prefix_hits: int = 0  # admitted on the prefix-affine shard
    placement_misses: int = 0       # prefix lived on an unusable shard ->
                                    # cross-shard CoW reuse lost

    @property
    def total_time(self) -> float:
        return self.prefill_time + self.decode_time

    def throughput(self) -> float:
        """Paper Eq. 12: generated tokens / generation time."""
        return self.generated_tokens / self.decode_time \
            if self.decode_time else 0.0

    def pool_utilization(self) -> float:
        return self.pages_in_use / self.pool_pages if self.pool_pages else 0.0

    def shard_utilization(self) -> Tuple[float, ...]:
        return tuple(u / p if p else 0.0
                     for u, p in zip(self.shard_pages_in_use,
                                     self.shard_pages))

    def prefix_hit_rate(self) -> float:
        return self.prefix_cache_hits / self.prefix_cache_queries \
            if self.prefix_cache_queries else 0.0


class Engine:
    def __init__(self, model_cfg: ModelConfig, coopt: CoOptConfig = COOPT,
                 engine_cfg: EngineConfig = EngineConfig(),
                 params=None):
        self.cfg = model_cfg
        self.coopt = coopt
        self.ecfg = engine_cfg
        self.model = get_model(model_cfg)
        if params is None:
            params = self.model.init(jax.random.PRNGKey(engine_cfg.seed))
        self.params = params
        self.key = jax.random.PRNGKey(engine_cfg.seed + 1)

        B, M = engine_cfg.num_lanes, engine_cfg.max_len
        # the device pool's pages axis is padded so it tiles evenly over the
        # KV shards (host page ids == device page ids, see opt_kv helpers)
        self.cache = self.model.init_cache(B, M, coopt,
                                           num_shards=engine_cfg.num_shards)
        self._patch_offset = (model_cfg.num_patches
                              if model_cfg.family == "vlm" else 0)
        # chunked continuation prefill (and therefore mixed steps + prefix
        # caching): attention families able to attend over the gathered
        # cache with true positions (see TransformerModel.prefill)
        self._chunked = model_cfg.family in ("dense", "moe")
        self.scheduler = Scheduler(
            B, M, coopt.page_size, list(engine_cfg.prefill_buckets),
            extra_tokens=self._patch_offset,
            allow_chunked=self._chunked,
            token_budget=engine_cfg.token_budget or None,
            enable_prefix_cache=engine_cfg.enable_prefix_cache,
            num_shards=engine_cfg.num_shards)
        self.stats = EngineStats()
        self.stats.pool_pages = self.scheduler.manager.num_pages

        # only batch-major leaves (length, recurrent state, whisper x-KV)
        # need lane masking; global-pool leaves are isolated by slot
        # disjointness.
        shapes = self.model.cache_shape(B, M, coopt)
        self._batch_axis = {k: axes.index("batch")
                            for k, (_, _, axes) in shapes.items()
                            if "batch" in axes}

        self._prefill_fn = jax.jit(self._prefill_impl)
        self._decode_fn = jax.jit(self._decode_impl)

    # ---------------------------------------------------------- jit bodies --
    def _mask_lanes(self, new_cache, old_cache, lane_mask):
        out = {}
        for name, leaf in new_cache.items():
            ax = self._batch_axis.get(name)
            if ax is None:
                out[name] = leaf
                continue
            m = lane_mask.reshape((1,) * ax + (-1,) +
                                  (1,) * (leaf.ndim - ax - 1))
            out[name] = jnp.where(m, leaf, old_cache[name])
        return out

    def _prefill_impl(self, params, batch, cache, lane_mask):
        logits, new_cache = self.model.prefill(params, batch, cache,
                                               self.coopt)
        return logits, self._mask_lanes(new_cache, cache, lane_mask)

    def _decode_impl(self, params, batch, cache, lane_mask):
        logits, new_cache = self.model.decode_step(
            params, batch, cache, self.coopt,
            long_window=self.ecfg.long_window)
        return logits, self._mask_lanes(new_cache, cache, lane_mask)

    # -------------------------------------------------------------- common --
    def _sample(self, logits) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        sp = self.ecfg.sampling
        return np.asarray(sample(logits, sub, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p))

    def _emit(self, req: Request, tok: int, now: float,
              first: bool) -> None:
        req.output.append(tok)
        self.stats.generated_tokens += 1
        if first:
            req.prefill_time = now

    def _finish_done(self, reqs: List[Request]) -> None:
        done = [r for r in reqs if r.done()]
        now = time.perf_counter()
        for r in done:
            r.finish_time = now
            self.scheduler.finish(r)

    def _update_pool_stats(self) -> None:
        mgr = self.scheduler.manager
        s = self.stats
        s.pool_pages = mgr.num_pages
        s.pages_in_use = mgr.pages_in_use
        s.peak_pages_in_use = max(s.peak_pages_in_use, mgr.pages_in_use)
        s.fresh_pages_allocated = mgr.fresh_pages_allocated
        s.prefix_cache_queries = mgr.prefix_queries
        s.prefix_cache_hits = mgr.prefix_hits
        s.preemptions = self.scheduler.preemptions
        s.rejected = len(self.scheduler.rejected)
        # per-shard health (page-range ownership along the mesh data/pod axes)
        n = mgr.num_shards
        s.num_shards = n
        s.shard_pages = tuple(mgr.shard_capacity(i) for i in range(n))
        s.shard_pages_in_use = tuple(mgr.pages_in_use_in(i)
                                     for i in range(n))
        peak = s.peak_shard_pages_in_use or (0,) * n
        s.peak_shard_pages_in_use = tuple(
            max(p, u) for p, u in zip(peak, s.shard_pages_in_use))
        s.shard_preemptions = tuple(self.scheduler.preemptions_by_shard)
        s.placement_prefix_hits = self.scheduler.placement_prefix_hits
        s.placement_misses = self.scheduler.placement_misses

    # -------------------------------------------------- mixed (dense/moe) --
    def _run_mixed(self, plan: StepPlan) -> None:
        """One device call for the whole step: prefill chunks + decode
        tokens through the chunked-continuation path (a decode lane is a
        chunk of length 1)."""
        B = self.ecfg.num_lanes
        NP = self.scheduler.pages_per_lane
        mgr = self.scheduler.manager
        S = bucket_len(max([c.n for c in plan.prefill] or [1]),
                       self.scheduler.prefill_buckets) or \
            max(c.n for c in plan.prefill)

        tokens = np.zeros((B, S), np.int32)
        positions = np.zeros((B, S), np.int32)
        slot_idx = np.full((B, S), -1, np.int32)     # Eq. 5 SkipSet: pads
        page_table = np.full((B, NP), -1, np.int32)
        cache_len = np.zeros(B, np.int32)
        last_pos = np.zeros(B, np.int32)
        lane_mask = np.zeros(B, bool)

        for c in plan.prefill:
            lane, n = c.req.lane, c.n
            tokens[lane, :n] = c.tokens
            positions[lane] = np.minimum(c.start + np.arange(S),
                                         c.start + n - 1)
            slot_idx[lane, :n] = mgr.slot_indices(
                c.req.pool_id, np.arange(c.start, c.start + n))
            page_table[lane] = self.scheduler.page_table(c.req)
            cache_len[lane] = c.start + n
            last_pos[lane] = n - 1
            lane_mask[lane] = True
        for d in plan.decode:
            lane = d.req.lane
            tokens[lane, 0] = d.req.output[-1]
            positions[lane] = d.pos
            slot_idx[lane, 0] = d.slot
            page_table[lane] = self.scheduler.page_table(d.req)
            cache_len[lane] = d.pos + 1
            last_pos[lane] = 0
            lane_mask[lane] = True

        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "slot_idx": jnp.asarray(slot_idx),
                 "page_table": jnp.asarray(page_table),
                 "cache_len": jnp.asarray(cache_len),
                 "last_pos": jnp.asarray(last_pos)}
        t0 = time.perf_counter()
        logits, self.cache = self._prefill_fn(self.params, batch, self.cache,
                                              jnp.asarray(lane_mask))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if plan.decode:
            self.stats.decode_time += dt
            self.stats.decode_steps += 1
            if plan.prefill:
                self.stats.mixed_steps += 1
        else:
            self.stats.prefill_time += dt
        if plan.prefill:
            self.stats.prefill_calls += 1

        toks = self._sample(logits)
        now = time.perf_counter()
        for c in plan.prefill:
            self.scheduler.note_prefilled(c.req, c.n)
            if c.final:
                self._emit(c.req, int(toks[c.req.lane]), now, first=True)
        for d in plan.decode:
            self._emit(d.req, int(toks[d.req.lane]), now, first=False)
        self._finish_done([c.req for c in plan.prefill if c.final] +
                          [d.req for d in plan.decode])

    # --------------------------------------- monolithic prefill (others) --
    def _run_prefill(self, chunks: List[PrefillChunk]) -> None:
        """Bucketed whole-prompt prefill for families without the chunked
        continuation path (mla/vlm/whisper/rwkv6/griffin)."""
        B = self.ecfg.num_lanes
        off = self._patch_offset
        mgr = self.scheduler.manager
        bucket = max(bucket_len(c.req.prompt_len + c.req.num_generated,
                                self.scheduler.prefill_buckets)
                     for c in chunks)
        S = off + bucket
        tokens = np.zeros((B, bucket), np.int32)
        slot_idx = np.full((B, S), -1, np.int32)       # Eq. 5 SkipSet: pads
        pad_mask = np.zeros((B, S), bool)
        cache_len = np.zeros(B, np.int32)
        last_pos = np.zeros(B, np.int32)
        lane_mask = np.zeros(B, bool)
        for c in chunks:
            r = c.req
            eff = r.effective_prompt()
            plen = len(eff)
            tokens[r.lane, :plen] = eff
            # lane pages -> global slots for positions [0, off + plen)
            # (vlm: patch embeddings occupy the leading ``off`` positions)
            pos = np.arange(off + plen)
            slot_idx[r.lane, :off + plen] = mgr.slot_indices(r.pool_id, pos)
            pad_mask[r.lane, :off + plen] = True
            cache_len[r.lane] = off + plen
            last_pos[r.lane] = off + plen - 1
            lane_mask[r.lane] = True

        batch = {"tokens": jnp.asarray(tokens),
                 "slot_idx": jnp.asarray(slot_idx),
                 "pad_mask": jnp.asarray(pad_mask),
                 "cache_len": jnp.asarray(cache_len),
                 "last_pos": jnp.asarray(last_pos)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros((B, off, self.cfg.d_model),
                                         jnp.bfloat16)
        if self.cfg.family == "whisper":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.num_frames, self.cfg.d_model), jnp.bfloat16)

        t0 = time.perf_counter()
        logits, self.cache = self._prefill_fn(self.params, batch, self.cache,
                                              jnp.asarray(lane_mask))
        logits.block_until_ready()
        self.stats.prefill_time += time.perf_counter() - t0
        self.stats.prefill_calls += 1

        toks = self._sample(logits)
        now = time.perf_counter()
        for c in chunks:
            # monolithic prefill covers the modality-stub prefix too — the
            # chunk carries only text tokens, but ``off`` patch positions
            # were written as well
            self.scheduler.note_prefilled(c.req, off + c.n)
            self._emit(c.req, int(toks[c.req.lane]), now, first=True)
        self._finish_done([c.req for c in chunks])

    # -------------------------------------------------------------- decode --
    def _run_decode(self, items: List[DecodeItem]) -> None:
        B = self.ecfg.num_lanes
        NP = self.scheduler.pages_per_lane
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B, 1), np.int32)
        slots = np.full((B, 1), -1, np.int32)
        page_table = np.full((B, NP), -1, np.int32)
        cache_len = np.zeros(B, np.int32)
        lane_mask = np.zeros(B, bool)
        for d in items:
            lane = d.req.lane
            tokens[lane, 0] = d.req.output[-1]
            positions[lane, 0] = d.pos
            slots[lane, 0] = d.slot
            page_table[lane] = self.scheduler.page_table(d.req)
            cache_len[lane] = d.pos + 1
            lane_mask[lane] = True

        batch = {"token": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "slot_idx": jnp.asarray(slots),
                 "page_table": jnp.asarray(page_table),
                 "cache_len": jnp.asarray(cache_len)}
        t0 = time.perf_counter()
        logits, self.cache = self._decode_fn(self.params, batch, self.cache,
                                             jnp.asarray(lane_mask))
        logits.block_until_ready()
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decode_steps += 1

        toks = self._sample(logits)
        now = time.perf_counter()
        for d in items:
            self._emit(d.req, int(toks[d.req.lane]), now, first=False)
        self._finish_done([d.req for d in items])

    # ---------------------------------------------------------------- API --
    def add_request(self, req: Request) -> None:
        self.scheduler.add_request(req)

    def step(self) -> None:
        plan = self.scheduler.schedule_step()
        if plan.empty:
            self._update_pool_stats()       # rejections still count
            return
        if self._chunked and plan.prefill:
            self._run_mixed(plan)           # decode + prefill, one call
        else:
            if plan.prefill:
                self._run_prefill(plan.prefill)
            if plan.decode:
                self._run_decode(plan.decode)
        self._update_pool_stats()

    def run(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 eos_token: Optional[int] = None,
                 return_requests: bool = False):
        """Serve ``prompts`` to completion. Returns the per-prompt output
        token lists (or the full Request objects with ``return_requests`` —
        inspect ``state`` to distinguish FINISHED from REJECTED; rejected
        requests surface with empty output and are counted in
        ``stats.rejected``)."""
        reqs = [Request(req_id=1000 + i, prompt=np.asarray(p, np.int32),
                        max_new_tokens=max_new_tokens, eos_token=eos_token,
                        arrival_time=float(i))
                for i, p in enumerate(prompts)]
        for r in reqs:
            self.add_request(r)
        self.run()
        if return_requests:
            return reqs
        return [r.output for r in reqs]
