"""LLM-CoOpt serving engine: continuous batching over ONE shared, refcounted,
prefix-cached paged-KV pool, with the paper's three techniques selected by a
``CoOptConfig``.

The engine is the "vLLM migration target" of the paper: the Original mode
reproduces unmodified-vLLM semantics (bf16 cache, every allocated page
loaded, per-head KV expansion) and each Opt-* flag turns on one technique,
so Figs. 6-7's five modes are one constructor argument apart.

Design (hardware adaptation, DESIGN.md §3): the device cache is a GLOBAL
paged pool — per-layer leaves ``(2, P_total, ps, Hkv, D)`` with no batch
dimension, ``P_total = num_lanes * pages(max_len)`` padded to tile evenly
over ``num_shards`` KV shards (the final page reserved as the write
kernel's SkipSet sentinel). The pool's page range is partitioned along the
mesh ``(pod, data)`` axes — the axes CACHE_RULES shard the pages axis over —
and every request is pinned to ONE shard at admission, so its page gathers
stay shard-local. All dynamic paging state (per-shard free lists, refcounts,
per-shard prefix-cache hash tables, slot indices, SkipSets) lives host-side
in the Scheduler/BlockManager; the device sees only static-shape index
arrays: global ``slot_idx``, per-lane ``page_table``, per-lane
``cache_len``. Lane isolation is enforced by slot disjointness — a lane can
only write pages it exclusively owns (shared prefix pages are read-only by
refcount construction) — so cache updates need no batch masking; only
batch-major leaves (per-lane lengths, recurrent state, whisper cross-KV) are
masked with the admitted-lane mask.

Scheduling (Sarathi-style): each step is composed under a token budget,
mixing decode tokens and chunked-prefill chunks, and EVERY family executes
the whole step as ONE device call through the chunked-continuation prefill
path (a decode lane is a chunk of length 1; a step with only decode lanes
takes the one-token decode kernel). The Opt-Pa two-step strategy — "segment
long sequences into manageable chunks, then apply lazy memory mapping and
computation" (paper §3.3) — therefore applies uniformly: dense/moe/vlm
attend the gathered paged history with true positions, MLA in absorbed
latent form, whisper over its decoder self-KV (cross-KV computed once, on
the first chunk), and griffin/rwkv6 thread their recurrent state across
chunks (the state after chunk k is the input state of chunk k+1), with
state snapshots at committed page boundaries backing their prefix cache.
Admission is shard-affine (prefix-affinity first, least-loaded fallback).
Shard exhaustion preempts the youngest running request ON THE PRESSURED
SHARD (freed pages, front-of-queue requeue, greedy-exact resume) instead of
crashing; impossible requests are REJECTED and surfaced.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.cache.block_manager import (OutOfBlocks, PageResidency,
                                       PrefixMatch, chain_hash_tokens,
                                       extend_chain_hash)
from repro.cache.quant import (HostPage, dequantize_fp8, encode_host_page)
from repro.kernels.visits import sharing_stats
from repro.configs.base import CacheConfig, ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.models import get_model
from repro.serving.request import FinishReason, Request, RequestState
from repro.serving.sampler import SamplingParams, sample
from repro.serving.scheduler import (DecodeItem, PrefillChunk, Scheduler,
                                     StepPlan, bucket_len, chunk_pages,
                                     pack_rows)


# --------------------------------------------- host-tier page transfers ----
# One compiled executable per (leaf shape, axis): the page index is a TRACED
# scalar (lax.dynamic_*_in_dim), so spilling/prefetching page 7 vs page 900
# never recompiles. Both directions are fully asynchronous — dispatch-order
# execution on the device stream sequences them against the surrounding
# steps without any host sync (COOPT001 stays clean).
@partial(jax.jit, static_argnames=("axis",))
def _read_pool_page(leaf, page, axis: int):
    return lax.dynamic_index_in_dim(leaf, page, axis, keepdims=False)


@partial(jax.jit, static_argnames=("axis",), donate_argnums=(0,))
def _write_pool_page(leaf, data, page, axis: int):
    return lax.dynamic_update_index_in_dim(
        leaf, data.astype(leaf.dtype), page, axis)


@partial(jax.jit, static_argnames=("axis",), donate_argnums=(0,))
def _write_pool_page_q(leaf, q, scale, page, axis: int):
    """fp8-encoded host page (CacheConfig.host_quant): dequantize on device
    during the staging write."""
    data = dequantize_fp8(q, scale, axis=-1, dtype=leaf.dtype)
    return lax.dynamic_update_index_in_dim(leaf, data, page, axis)


@dataclass
class _Flight:
    """One dispatched host->HBM prefetch upload, committed to the device
    prefix table once the scheduler turn counter passes ``lands`` (dispatch
    order already sequences the upload before any step planned after the
    commit — the turn delay models the overlap window, it is not a wait)."""
    hash: int
    turn: int                      # dispatch turn
    lands: int                     # first turn the commit may happen
    ok: bool = True                # fault injection: False -> abort instead


@dataclass(frozen=True)
class EngineConfig:
    num_lanes: int = 4
    max_len: int = 512
    prefill_buckets: Tuple[int, ...] = (64, 128, 256, 512)
    long_window: int = 0            # >0: block-sparse long-context decode
    sampling: SamplingParams = SamplingParams()
    seed: int = 0
    token_budget: int = 0           # 0 => max(prefill_buckets)
    enable_prefix_cache: bool = True
    num_shards: int = 1             # KV-pool page-range shards; matches the
                                    # mesh (pod, data) extent the cache
                                    # pages axis is sharded over
                                    # (launch.mesh.kv_shard_count)
    state_cache_entries: int = 128  # recurrent-state snapshots retained
                                    # (griffin/rwkv6 prefix-cache resume)
    pack_prefill: bool = False      # concat-prefill packing: several
                                    # prompts' chunks share one row through
                                    # the segment-aware chunk kernels
                                    # (dense/moe/mla families)
    pack_slots: int = 4             # sampled-logit slots per packed row
                                    # (max final chunks packed together)
    max_preemptions: int = 32       # preemption bound per request: past it
                                    # the request is rejected
                                    # (PREEMPTION_LIMIT) instead of
                                    # livelocking the pool
    cache: Optional[CacheConfig] = None
                                    # consolidated cache knobs (pool size,
                                    # shards, prefix cache, host-DRAM spill
                                    # tier). None = derive a CacheConfig
                                    # from the legacy enable_prefix_cache /
                                    # num_shards fields above.

    def cache_config(self, page_size: int) -> CacheConfig:
        """Resolve the effective :class:`CacheConfig`.

        Legacy knobs (``num_shards`` / ``enable_prefix_cache``) remain the
        deprecation shim: with ``cache=None`` they are folded into a fresh
        CacheConfig; with an explicit ``cache`` they must not CONFLICT
        (non-default values in both places raise)."""
        cc = self.cache
        if cc is None:
            cc = CacheConfig(num_shards=self.num_shards,
                             enable_prefix_cache=self.enable_prefix_cache)
        else:
            if self.num_shards != 1 and self.num_shards != cc.num_shards:
                raise ValueError(
                    f"EngineConfig.num_shards={self.num_shards} conflicts "
                    f"with EngineConfig.cache.num_shards={cc.num_shards}; "
                    "set the shard count in ONE place (CacheConfig "
                    "preferred)")
            if not self.enable_prefix_cache and cc.enable_prefix_cache:
                cc = cc.replace(enable_prefix_cache=False)
        ps = cc.page_size or page_size
        pages_per_lane = -(-self.max_len // ps)
        return cc.resolve(page_size=ps,
                          num_pages=self.num_lanes * pages_per_lane)


@dataclass
class EngineStats:
    prefill_calls: int = 0
    decode_steps: int = 0
    mixed_steps: int = 0            # decode + prefill fused in one call
    generated_tokens: int = 0
    prefill_time: float = 0.0       # mixed-step wall time is split by
    decode_time: float = 0.0        # planned token share (Eq. 12 fairness)
    packed_steps: int = 0           # steps run through the packed row path
    packed_rows_saved: int = 0      # lane-rows eliminated by packing
    # ------------------------------------- cross-lane prefix sharing -----
    # Accounted per decode step from the step's page table (the same array
    # kernels.visits.plan_visits dedups on-device), so the numbers describe
    # exactly what the visit grid batches: a (slot, page) entry held by k>1
    # lanes streams once instead of k times.
    shared_page_visits: int = 0     # deduped visits with >1 member lane
    dup_page_streams_saved: int = 0 # per-lane page streams eliminated:
                                    # sum over shared visits of (k - 1)
    lanes_per_shared_page: Dict[int, int] = field(default_factory=dict)
                                    # histogram: k lanes -> visit count
    # ------------------------------------------------ per-request latency --
    ttft_s: List[float] = field(default_factory=list)   # submit->1st token
                                                        # (queue wait incl.)
    tpot_s: List[float] = field(default_factory=list)   # mean s/token after
    queue_wait_s: List[float] = field(default_factory=list)  # submit->admit
    # ----------------------------------------------------- pool health ----
    pool_pages: int = 0
    pages_in_use: int = 0           # referenced by live sequences (now)
    peak_pages_in_use: int = 0
    fresh_pages_allocated: int = 0  # pages handed out over the run
    prefix_cache_queries: int = 0
    prefix_cache_hits: int = 0      # pages reused, not recomputed
                                    # (= device + host hits; legacy total)
    prefix_device_hits: int = 0     # hit pages that were HBM-resident
    prefix_host_hits: int = 0       # hit pages restored from the host tier
                                    # (spilled, then prefetched back)
    preemptions: int = 0
    rejected: int = 0
    # ------------------------------------------------ host-DRAM KV tier ----
    host_pages: int = 0             # host tier capacity (0 = tier off)
    host_pages_resident: int = 0    # spilled pages currently host-resident
    spilled_pages: int = 0          # device evictions rescued to host DRAM
    host_evictions: int = 0         # pages dropped off the host LRU (gone)
    prefetch_begun: int = 0         # host->HBM uploads dispatched
    prefetch_committed: int = 0     # ..that landed and re-registered
    prefetch_aborted: int = 0       # ..that failed / lost a registration race
    prefetches_planned: int = 0     # queued requests the scheduler planned
                                    # prefetch for
    prefetch_held_turns: int = 0    # admission turns spent gated on an
                                    # IN_FLIGHT upload (overlap window)
    prefetch_replans: int = 0       # landed prefixes stolen by allocation
                                    # pressure pre-admission, fetched again
    # ----------------------------------------------------- resilience ----
    shed: int = 0                   # fast-rejected at submit (overload
                                    # watermark; AsyncEngine only)
    deadline_shed: int = 0          # queued requests shed TIMED_OUT
    preemption_limit_rejects: int = 0  # rejected past max_preemptions
    errors: int = 0                 # requests terminated by a pipeline
                                    # fault (step exception, worker death,
                                    # stall watchdog)
    # --------------------------------------------------- sharded pool ----
    num_shards: int = 1
    shard_pages: Tuple[int, ...] = ()          # page-range size per shard
    shard_pages_in_use: Tuple[int, ...] = ()
    peak_shard_pages_in_use: Tuple[int, ...] = ()
    shard_preemptions: Tuple[int, ...] = ()    # per-shard pressure evictions
    placement_prefix_hits: int = 0  # admitted on the prefix-affine shard
    placement_misses: int = 0       # prefix lived on an unusable shard ->
                                    # cross-shard CoW reuse lost

    @property
    def total_time(self) -> float:
        return self.prefill_time + self.decode_time

    def throughput(self) -> float:
        """Paper Eq. 12: generated tokens / generation time (decode's
        token-share of mixed steps, not whole mixed-step wall clock)."""
        return self.generated_tokens / self.decode_time \
            if self.decode_time else 0.0

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        # xs is a host-side Python list of floats — no device value is
        # synced here, the pattern just looks like one to the linter
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0  # coopt: allow[COOPT001]

    def ttft(self, q: float = 50.0) -> float:
        """Time-to-first-token percentile (s) over finished requests,
        measured from SUBMISSION — queue wait included."""
        return self._pct(self.ttft_s, q)

    def tpot(self, q: float = 50.0) -> float:
        """Per-request mean time-per-output-token percentile (s)."""
        return self._pct(self.tpot_s, q)

    def queue_wait(self, q: float = 50.0) -> float:
        """Submission -> first lane admission percentile (s)."""
        return self._pct(self.queue_wait_s, q)

    def latency_summary(self) -> Dict[str, float]:
        return {"ttft_p50_s": round(self.ttft(50), 4),
                "ttft_p95_s": round(self.ttft(95), 4),
                "tpot_p50_s": round(self.tpot(50), 4),
                "tpot_p95_s": round(self.tpot(95), 4),
                "queue_wait_p50_s": round(self.queue_wait(50), 4),
                "queue_wait_p95_s": round(self.queue_wait(95), 4),
                # host-side Python int counters, not device values
                "shared_page_visits":
                    float(self.shared_page_visits),  # coopt: allow[COOPT001]
                "dup_page_streams_saved":
                    float(self.dup_page_streams_saved),  # coopt: allow[COOPT001]
                "shed":
                    float(self.shed),  # coopt: allow[COOPT001]
                "deadline_shed":
                    float(self.deadline_shed),  # coopt: allow[COOPT001]
                "preemption_limit_rejects":
                    float(self.preemption_limit_rejects),  # coopt: allow[COOPT001]
                "errors":
                    float(self.errors),  # coopt: allow[COOPT001]
                "prefix_device_hits":
                    float(self.prefix_device_hits),  # coopt: allow[COOPT001]
                "prefix_host_hits":
                    float(self.prefix_host_hits),  # coopt: allow[COOPT001]
                "prefix_misses":
                    float(self.prefix_cache_queries  # coopt: allow[COOPT001]
                          - self.prefix_cache_hits),
                "spilled_pages":
                    float(self.spilled_pages),  # coopt: allow[COOPT001]
                "prefetch_committed":
                    float(self.prefetch_committed),  # coopt: allow[COOPT001]
                }

    def pool_utilization(self) -> float:
        return self.pages_in_use / self.pool_pages if self.pool_pages else 0.0

    def shard_utilization(self) -> Tuple[float, ...]:
        return tuple(u / p if p else 0.0
                     for u, p in zip(self.shard_pages_in_use,
                                     self.shard_pages))

    def prefix_hit_rate(self) -> float:
        return self.prefix_cache_hits / self.prefix_cache_queries \
            if self.prefix_cache_queries else 0.0

    def prefix_device_hit_rate(self) -> float:
        return self.prefix_device_hits / self.prefix_cache_queries \
            if self.prefix_cache_queries else 0.0

    def prefix_host_hit_rate(self) -> float:
        return self.prefix_host_hits / self.prefix_cache_queries \
            if self.prefix_cache_queries else 0.0

    def prefix_miss_rate(self) -> float:
        return 1.0 - self.prefix_hit_rate() \
            if self.prefix_cache_queries else 0.0


@dataclass
class StepBatch:
    """One fully-built device step: the static-shape arrays plus the host
    metadata needed to route the sampled tokens back to requests. Built by
    ``Engine._build_step`` and consumed by BOTH the synchronous loop and
    the async pipeline (``serving.frontend``) — one step-construction path.

    ``samples`` maps each sampled logit slot to (request, is_first_token,
    index into the sampled-token array) — ``(lane,)`` for the per-lane
    kinds, ``(row, slot)`` for the packed kind. ``feed``/``row_lane``/
    ``scatter_lane`` carry the async device-token plumbing: column 0 of a
    decode row can take its input token from the device-resident per-lane
    ``lane_tok`` feed (-1) instead of a host value (-2 = keep the host
    token), and every sampled token is scattered back into ``lane_tok`` at
    ``scatter_lane`` (``num_lanes`` = drop), so planning step N+1 never
    waits on step N's host sync."""
    kind: str                      # "prefill" | "decode" | "packed"
    batch: Dict[str, jnp.ndarray]
    lane_mask: np.ndarray          # (num_lanes,) bool; unused for packed
    plan: StepPlan
    samples: List[Tuple[Request, bool, Tuple[int, ...]]]
    tp: int                        # planned prefill tokens
    td: int                        # planned decode tokens
    feed: np.ndarray               # (R,) int32 col-0 token source
    row_lane: np.ndarray           # (R,) int32 lane backing each row
    scatter_lane: np.ndarray       # (n_slots,) int32 lane per sample slot


class Engine:
    def __init__(self, model_cfg: ModelConfig, coopt: CoOptConfig = COOPT,
                 engine_cfg: EngineConfig = EngineConfig(),
                 params=None, mesh=None):
        """``mesh``: optional ``jax.sharding.Mesh``. When given, the KV-pool
        shard count is DERIVED from the mesh's pages axes
        (``launch.mesh.kv_shard_count``) — a default ``num_shards=1`` config
        is upgraded to match, and a conflicting explicit value raises (the
        host page ranges and the device pages-axis partition must coincide).
        The cache leaves are placed on the mesh, and with
        ``coopt.use_kernel`` the pooled Pallas kernels run through the
        ``kernels.sharded`` shard_map layer — one kernel hot path, single-
        host and distributed."""
        self.cfg = model_cfg
        self.coopt = coopt
        ccfg = engine_cfg.cache_config(coopt.page_size)
        if mesh is not None:
            from repro.launch.mesh import kv_shard_count
            ns = kv_shard_count(mesh)
            if ccfg.num_shards == 1:
                # config built before the mesh: derive the shard count
                ccfg = ccfg.replace(num_shards=ns)
            elif ccfg.num_shards != ns:
                raise ValueError(
                    f"EngineConfig.num_shards={ccfg.num_shards} "
                    f"disagrees with the mesh's KV shard count {ns} "
                    f"(pages axes {tuple(mesh.shape)}); build the config "
                    "from launch.mesh.kv_shard_count(mesh) or leave it at "
                    "the default to derive it")
        # keep the legacy EngineConfig mirrors in sync with the resolved
        # CacheConfig — downstream code reads either
        if (engine_cfg.num_shards != ccfg.num_shards
                or engine_cfg.enable_prefix_cache != ccfg.enable_prefix_cache):
            engine_cfg = dataclasses.replace(
                engine_cfg, num_shards=ccfg.num_shards,
                enable_prefix_cache=ccfg.enable_prefix_cache)
        self.ccfg = ccfg
        self.mesh = mesh
        self.ecfg = engine_cfg
        self.model = get_model(model_cfg)
        if params is None:
            params = self.model.init(jax.random.PRNGKey(engine_cfg.seed))
        self.params = params
        self.key = jax.random.PRNGKey(engine_cfg.seed + 1)

        B, M = engine_cfg.num_lanes, engine_cfg.max_len
        # the device pool's pages axis is padded so it tiles evenly over the
        # KV shards (host page ids == device page ids, see opt_kv helpers)
        self.cache = self.model.init_cache(B, M, coopt,
                                           num_shards=engine_cfg.num_shards,
                                           cache_cfg=ccfg)
        # pages-axis shard_map dispatch for the pooled kernels (None for no
        # mesh / an unsharded mesh: identical single-host code path)
        from repro.kernels import ops
        self._kernel_ctx = (ops.make_mesh_ctx(mesh)
                            if coopt.use_kernel else None)
        if mesh is not None:
            self.cache = self._place_cache(self.cache, mesh)
        self._patch_offset = (model_cfg.num_patches
                              if model_cfg.family == "vlm" else 0)
        # recurrent-state families: chunk boundaries land on page boundaries
        # so the cross-chunk state can be snapshotted as the prefix cache's
        # resume artifact (KV pages alone cannot resume a recurrence)
        self._rec_leaves = tuple(getattr(self.model, "recurrent_leaves", ()))
        self.scheduler = Scheduler(
            B, M, coopt.page_size, list(engine_cfg.prefill_buckets),
            extra_tokens=self._patch_offset,
            token_budget=engine_cfg.token_budget or None,
            page_aligned=bool(self._rec_leaves),
            max_preemptions=engine_cfg.max_preemptions,
            cache_cfg=ccfg)
        # deterministic fault-injection hook layer (serving.faults); None in
        # production — the chaos suite installs a seeded FaultInjector here
        self.faults = None
        # chain-hash(prefix pages) -> per-lane state slices; the manager's
        # prefix_gate makes page matching stop at the last boundary we can
        # actually restore
        self._state_cache: "OrderedDict[int, Dict[str, np.ndarray]]" = \
            OrderedDict()
        if self._rec_leaves:
            self.scheduler.manager.prefix_gate = self._state_cache.__contains__
        self.stats = EngineStats()
        self.stats.pool_pages = self.scheduler.manager.num_pages

        # only batch-major leaves (length, recurrent state, whisper x-KV)
        # need lane masking; global-pool leaves are isolated by slot
        # disjointness.
        shapes = self.model.cache_shape(B, M, coopt, cache_cfg=ccfg)
        self._batch_axis = {k: axes.index("batch")
                            for k, (_, _, axes) in shapes.items()
                            if "batch" in axes}

        # ---------------------------------------- host-DRAM KV spill tier --
        # Pool leaves are addressed page-wise along their "pages" axis; the
        # batch-major leaves (recurrent state, whisper cross-KV) have no
        # page identity and never spill.
        self._pool_axis = {k: axes.index("pages")
                           for k, (_, _, axes) in shapes.items()
                           if "pages" in axes}
        self._prefetch_flights: List[_Flight] = []
        self._sched_turn = 0
        self._host_dev = None
        if ccfg.host_pages > 0 and self._pool_axis:
            try:
                self._host_dev = jax.devices("cpu")[0]
            except RuntimeError:
                self._host_dev = None   # no CPU backend: keep pages where
                                        # device_put default places them
            mgr = self.scheduler.manager
            mgr.spill_sink = self._spill_page
            self.scheduler.prefetcher = self._start_prefetch
            self.scheduler.prefetch_tick = self._tick_prefetch
        self.stats.host_pages = ccfg.host_pages

        # cache donation (argnum 2 of every step impl): the pool is
        # threaded through each step and immediately rebound to the
        # output, so XLA may update pages in place instead of copying the
        # whole pool per step
        self._prefill_fn = jax.jit(self._prefill_impl, donate_argnums=(2,))
        self._decode_fn = jax.jit(self._decode_impl, donate_argnums=(2,))
        self._packed_fn = jax.jit(self._prefill_packed_impl,
                                  donate_argnums=(2,))
        # async two-stage pipeline step (sample-on-device); lazily traced,
        # AOT-compiled by AsyncEngine.warmup over the step-shape lattice.
        # lane_tok (argnum 4) is donated too — it is device-resident state
        # owned by the pipeline, rebound on every dispatch.
        self._async_fn = jax.jit(self._async_step_impl,
                                 static_argnames=("kind",),
                                 donate_argnums=(2, 4))
        self._aot: Dict[tuple, object] = {}   # shape key -> Compiled
        self._dev_cache: Dict[tuple, jnp.ndarray] = {}  # small recurring
        # host arrays (lane masks, token-feed plumbing) memoized on device
        # — steady-state decode reuses them every step, skipping the
        # per-step device_put that would otherwise eat the pipeline win
        self.aot_misses = 0                   # async steps that re-traced
        self.trace_counts: Dict[str, int] = {}  # impl traces (trace-time
                                                # side effect — retraces
                                                # show up here)
        # concat-prefill packing works where "length" is the ONLY
        # batch-major leaf (rows decouple from lanes; the packed impl
        # restores it): dense/moe/mla. vlm (patch stubs), whisper
        # (cross-KV) and the recurrent families keep per-lane state.
        self._pack_ok = (model_cfg.family in ("dense", "moe", "mla")
                         and not self._rec_leaves)
        if engine_cfg.pack_prefill and not self._pack_ok:
            raise ValueError(
                f"pack_prefill unsupported for family {model_cfg.family!r}"
                " (per-lane batch-major cache state)")

    # ------------------------------------------------------- mesh placement --
    def _place_cache(self, cache, mesh):
        """Shard the device cache leaves onto the mesh: the kernel path
        partitions the pool ONLY along its pages axes (the shard_map
        layer's layout — heads/latent replicated); the jnp reference path
        uses the full CACHE_RULES (GSPMD handles the rest)."""
        from jax.sharding import NamedSharding
        from repro.launch.steps import (CACHE_RULES, KERNEL_CACHE_RULES,
                                        axes_pspec)
        rules = (KERNEL_CACHE_RULES if self.coopt.use_kernel
                 else CACHE_RULES)
        shapes = self.model.cache_shape(self.ecfg.num_lanes,
                                        self.ecfg.max_len, self.coopt,
                                        num_shards=self.ecfg.num_shards,
                                        cache_cfg=self.ccfg)
        return {k: jax.device_put(
                    leaf, NamedSharding(mesh, axes_pspec(
                        shapes[k][0], shapes[k][2], mesh, rules)))
                for k, leaf in cache.items()}

    # ---------------------------------------------------------- jit bodies --
    def _mask_lanes(self, new_cache, old_cache, lane_mask):
        out = {}
        for name, leaf in new_cache.items():
            ax = self._batch_axis.get(name)
            if ax is None:
                out[name] = leaf
                continue
            m = lane_mask.reshape((1,) * ax + (-1,) +
                                  (1,) * (leaf.ndim - ax - 1))
            out[name] = jnp.where(m, leaf, old_cache[name])
        return out

    def _count_trace(self, kind: str) -> None:
        # runs at TRACE time only: steady-state (cached or AOT-compiled)
        # steps never touch it, so any increment after warmup IS a retrace
        self.trace_counts[kind] = self.trace_counts.get(kind, 0) + 1

    def _prefill_impl(self, params, batch, cache, lane_mask):
        from repro.kernels import ops
        self._count_trace("prefill")
        with ops.mesh_ctx_scope(self._kernel_ctx):   # trace-scoped
            logits, new_cache = self.model.prefill(
                params, batch, cache, self.coopt,
                long_window=self.ecfg.long_window)
            return logits, self._mask_lanes(new_cache, cache, lane_mask)

    def _decode_impl(self, params, batch, cache, lane_mask):
        from repro.kernels import ops
        self._count_trace("decode")
        with ops.mesh_ctx_scope(self._kernel_ctx):   # trace-scoped
            logits, new_cache = self.model.decode_step(
                params, batch, cache, self.coopt,
                long_window=self.ecfg.long_window)
            return logits, self._mask_lanes(new_cache, cache, lane_mask)

    def _prefill_packed_impl(self, params, batch, cache, lane_mask):
        """Packed rows are DECOUPLED from lanes (R != num_lanes), so no
        lane-shaped masking applies. Pool leaves are isolated by slot
        disjointness as ever; the only batch-major leaf in the packable
        families is ``length``, which is restored from the input cache
        (the engine passes explicit ``cache_len`` every step, so the leaf
        is bookkeeping only)."""
        from repro.kernels import ops
        self._count_trace("packed")
        with ops.mesh_ctx_scope(self._kernel_ctx):   # trace-scoped
            logits, new_cache = self.model.prefill(
                params, batch, cache, self.coopt,
                long_window=self.ecfg.long_window)
            new_cache["length"] = cache["length"]
            return logits, new_cache

    def _async_step_impl(self, params, batch, cache, lane_mask, lane_tok,
                         key, feed, row_lane, scatter_lane, *, kind: str):
        """One async-pipeline device step: substitute device-resident input
        tokens, run the model, SAMPLE ON DEVICE, and scatter the sampled
        tokens back into the per-lane ``lane_tok`` feed — so the host can
        build and dispatch step N+1 from metadata alone while step N
        executes, deferring the host sync to the emit worker."""
        self._count_trace("async_" + kind)
        tok_key = "token" if kind == "decode" else "tokens"
        batch = dict(batch)
        if "dmeta" in batch:
            # decode fast path: per-step metadata shipped as ONE (3, B)
            # host->device transfer instead of three
            dm = batch.pop("dmeta")
            batch["positions"] = dm[0][:, None]
            batch["slot_idx"] = dm[1][:, None]
            batch["cache_len"] = dm[2]
        t0 = batch[tok_key][:, 0]
        t0 = jnp.where(feed == -1, lane_tok[row_lane],
                       jnp.where(feed >= 0, feed, t0))
        batch[tok_key] = batch[tok_key].at[:, 0].set(t0)
        if kind == "decode":
            logits, new_cache = self._decode_impl(params, batch, cache,
                                                  lane_mask)
        elif kind == "packed":
            logits, new_cache = self._prefill_packed_impl(
                params, batch, cache, lane_mask)
        else:
            logits, new_cache = self._prefill_impl(params, batch, cache,
                                                   lane_mask)
        sp = self.ecfg.sampling
        toks = sample(logits, key, temperature=sp.temperature,
                      top_k=sp.top_k, top_p=sp.top_p)
        lane_tok = lane_tok.at[scatter_lane].set(
            toks.reshape(-1).astype(jnp.int32), mode="drop")
        return toks, new_cache, lane_tok

    # -------------------------------------------------------------- common --
    def _sample(self, logits) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        sp = self.ecfg.sampling
        return np.asarray(sample(logits, sub, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p))

    def _emit(self, req: Request, tok: int, now: float,
              first: bool) -> bool:
        """Deliver one sampled token. Returns False when the token is
        DROPPED: the request already terminated (cancelled, rejected, shed,
        errored) or is done (the async pipeline's <= 1-step EOS overrun)."""
        if req.inflight > 0:
            req.inflight -= 1
        if req.is_terminal or req.done():
            return False
        req.output.append(tok)
        self.stats.generated_tokens += 1
        if first and req.prefill_time < 0:
            req.prefill_time = now          # TTFT anchor survives preemption
        return True

    @staticmethod
    def _anchor(req: Request) -> float:
        """TTFT / queue-wait anchor: client submission when stamped, else
        scheduler-queue arrival."""
        return req.submit_time if req.submit_time >= 0 else req.enqueue_time

    def _finish_done(self, reqs: List[Request]) -> None:
        now = time.perf_counter()
        for r in reqs:
            if not r.done():
                continue
            if r.state is RequestState.PREEMPTED:
                # async pipeline edge: preempted while its LAST tokens were
                # still in flight — their emission just completed it, so it
                # must never re-admit. Its pages were already freed.
                if r in self.scheduler.waiting:
                    self.scheduler.waiting.remove(r)
                r.state = RequestState.FINISHED
                r.finish(FinishReason.FINISHED)
            elif r.state is RequestState.RUNNING:
                self.scheduler.finish(r)
            else:
                continue
            r.finish_time = now
            t0 = self._anchor(r)
            if r.prefill_time >= 0 and t0 >= 0:
                self.stats.ttft_s.append(r.prefill_time - t0)
                if r.num_generated > 1:
                    self.stats.tpot_s.append(
                        (r.finish_time - r.prefill_time)
                        / (r.num_generated - 1))
            if r.admit_time >= 0 and t0 >= 0:
                self.stats.queue_wait_s.append(r.admit_time - t0)

    def _update_pool_stats(self) -> None:
        mgr = self.scheduler.manager
        s = self.stats
        s.pool_pages = mgr.num_pages
        s.pages_in_use = mgr.pages_in_use
        s.peak_pages_in_use = max(s.peak_pages_in_use, mgr.pages_in_use)
        s.fresh_pages_allocated = mgr.fresh_pages_allocated
        s.prefix_cache_queries = mgr.prefix_queries
        s.prefix_cache_hits = mgr.prefix_hits
        s.preemptions = self.scheduler.preemptions
        s.rejected = len(self.scheduler.rejected)
        s.deadline_shed = self.scheduler.deadline_shed
        s.preemption_limit_rejects = self.scheduler.preemption_limit_rejects
        # per-shard health (page-range ownership along the mesh data/pod axes)
        n = mgr.num_shards
        s.num_shards = n
        s.shard_pages = tuple(mgr.shard_capacity(i) for i in range(n))
        s.shard_pages_in_use = tuple(mgr.pages_in_use_in(i)
                                     for i in range(n))
        peak = s.peak_shard_pages_in_use or (0,) * n
        s.peak_shard_pages_in_use = tuple(
            max(p, u) for p, u in zip(peak, s.shard_pages_in_use))
        s.shard_preemptions = tuple(self.scheduler.preemptions_by_shard)
        s.placement_prefix_hits = self.scheduler.placement_prefix_hits
        s.placement_misses = self.scheduler.placement_misses
        # host-DRAM tier
        s.prefix_device_hits = mgr.prefix_device_hits
        s.prefix_host_hits = mgr.prefix_host_hits
        s.host_pages = mgr.host_pages
        s.host_pages_resident = mgr.host_resident_pages
        s.spilled_pages = mgr.spilled_pages
        s.host_evictions = mgr.host_evictions
        s.prefetch_begun = mgr.prefetch_begun
        s.prefetch_committed = mgr.prefetch_committed
        s.prefetch_aborted = mgr.prefetch_aborted
        s.prefetches_planned = self.scheduler.prefetches_planned
        s.prefetch_held_turns = self.scheduler.prefetch_held_turns
        s.prefetch_replans = self.scheduler.prefetch_replans

    # ----------------------------------------------- host-DRAM spill tier --
    def _spill_page(self, h: int, page: int, shard: int):
        """BlockManager spill sink: rescue an LRU-evicted prefix page to the
        host store. Returns the host payload (or None to let the page die —
        fault injection). The pool slice is dispatched BEFORE any later step
        that could reuse ``page``, so device-order execution reads the old
        contents even though the pool leaves are donated per step; the
        ``device_put`` to the CPU backend is asynchronous — no host sync."""
        hook = (getattr(self.faults, "on_spill", None)
                if self.faults is not None else None)
        if hook is not None and not hook():
            return None
        leaves = {k: _read_pool_page(self.cache[k], page, axis=ax)
                  for k, ax in self._pool_axis.items()}
        hp = encode_host_page(leaves, quantize=self.ccfg.host_quant)
        if self._host_dev is not None:
            hp = hp.to_device(self._host_dev)
        return hp

    def _upload_page(self, hp: HostPage, page: int) -> None:
        """Write a host payload into reserved staging page ``page`` via the
        donated dynamic-update jit (rebind-at-call, pages updated in place)."""
        for k, ax in self._pool_axis.items():
            if k in hp.scales:
                self.cache[k] = _write_pool_page_q(
                    self.cache[k], hp.leaves[k], hp.scales[k], page, axis=ax)
            else:
                self.cache[k] = _write_pool_page(
                    self.cache[k], hp.leaves[k], page, axis=ax)

    def _start_prefetch(self, req: Request, match: PrefixMatch) -> List[int]:
        """Scheduler prefetcher hook: start host->HBM uploads for the
        non-device-resident pages of a queued request's matched prefix.
        Returns the chain hashes whose landing gates the request's
        admission (existing IN_FLIGHT uploads are ridden, not repeated)."""
        mgr = self.scheduler.manager
        keys: List[int] = []
        for mp in match.pages:
            if mp.residency is PageResidency.DEVICE:
                continue
            if mp.residency is PageResidency.IN_FLIGHT:
                keys.append(mp.hash)      # ride the existing upload
                continue
            try:
                page, payload = mgr.begin_prefetch(mp.hash, match.shard)
            except OutOfBlocks:
                break   # no staging page free: admit on what already landed
            except KeyError:
                break   # raced off the host store since match_prefix
            ok, delay = True, 0
            hook = (getattr(self.faults, "on_prefetch", None)
                    if self.faults is not None else None)
            if hook is not None:
                ok, delay = hook()
            self._upload_page(payload, page)
            self._prefetch_flights.append(_Flight(
                hash=mp.hash, turn=self._sched_turn,
                lands=self._sched_turn + 1 + max(int(delay), 0), ok=ok))
            keys.append(mp.hash)
        return keys

    def _tick_prefetch(self) -> None:
        """Scheduler prefetch_tick hook, called at the top of every
        schedule_step: advance the turn clock and settle landed flights.
        A flight dispatched on turn T commits no earlier than turn T+1 —
        the upload overlaps the step(s) dispatched in between; dispatch
        order guarantees it has executed before any step planned AFTER the
        commit can read the staged page."""
        self._sched_turn += 1
        if not self._prefetch_flights:
            return
        mgr = self.scheduler.manager
        still: List[_Flight] = []
        for f in self._prefetch_flights:
            if self._sched_turn < f.lands:
                still.append(f)
                continue
            if f.ok:
                mgr.commit_prefetch(f.hash)
            else:
                mgr.abort_prefetch(f.hash)
        self._prefetch_flights = still

    def _abort_prefetch_flights(self) -> None:
        """Return every in-flight staging page to the free list (payloads
        go back to the host store — the upload is abandoned, not lost)."""
        mgr = self.scheduler.manager
        for f in self._prefetch_flights:
            mgr.abort_prefetch(f.hash)
        self._prefetch_flights = []

    # ------------------------------------------------- recurrent snapshots --
    def _lane_index(self, leaf: str, lane: int):
        ax = self._batch_axis[leaf]
        return (slice(None),) * ax + (lane,)

    def _reset_or_restore_state(self, chunks: List[PrefillChunk]) -> None:
        """First chunk of a (re)admitted request on a recurrent-state
        family: the lane's state leaves hold the PREVIOUS occupant's state —
        zero them, or restore the snapshot matching the prefix-cache hit
        (``start > 0`` implies the manager's prefix_gate verified one)."""
        ps = self.coopt.page_size
        for c in chunks:
            if not c.first:
                continue
            lane = c.req.lane
            snap = None
            # (re)seed the request's running chain hash at its resume point
            c.req.prefix_hash_pages = c.start // ps
            c.req.prefix_hash = chain_hash_tokens(
                c.req.effective_prompt(), c.req.prefix_hash_pages, ps)
            if c.start > 0:
                snap = self._state_cache[c.req.prefix_hash]
                self._state_cache.move_to_end(c.req.prefix_hash)
            for leaf in self._rec_leaves:
                idx = self._lane_index(leaf, lane)
                cur = self.cache[leaf]
                val = 0 if snap is None else jnp.asarray(snap[leaf],
                                                         cur.dtype)
                self.cache[leaf] = cur.at[idx].set(val)

    def _snapshot_state(self, c: PrefillChunk) -> None:
        """A chunk that ended exactly on a page boundary leaves the lane's
        recurrent state at a committed-prefix resume point: snapshot it
        under the same chain hash the pages were registered with."""
        ps = self.coopt.page_size
        end = c.start + c.n
        if end % ps or not self.ecfg.enable_prefix_cache:
            return
        # extend the request's running hash — never rehash from page 0
        key = extend_chain_hash(c.req.prefix_hash, c.req.effective_prompt(),
                                c.req.prefix_hash_pages, end // ps, ps)
        c.req.prefix_hash, c.req.prefix_hash_pages = key, end // ps
        if key in self._state_cache:
            self._state_cache.move_to_end(key)
            return
        self._state_cache[key] = {
            leaf: np.asarray(self.cache[leaf][self._lane_index(leaf,
                                                               c.req.lane)])
            for leaf in self._rec_leaves}
        while len(self._state_cache) > self.ecfg.state_cache_entries:
            self._state_cache.popitem(last=False)

    # --------------------------------------------------- the ONE step path --
    def _should_pack(self, plan: StepPlan) -> bool:
        return (self.ecfg.pack_prefill and self._pack_ok
                and bool(plan.prefill))

    def _note_sharing(self, rows: np.ndarray) -> None:
        """Accumulate cross-lane prefix-sharing stats for one decode step
        from the decode lanes' page-table rows (the exact dedup the visit
        grid performs on-device, counted host-side for observability)."""
        st = sharing_stats(rows)
        self.stats.shared_page_visits += st["shared_page_visits"]
        self.stats.dup_page_streams_saved += st["dup_page_streams_saved"]
        hist = self.stats.lanes_per_shared_page
        for k, n in st["lanes_per_shared_page"].items():
            hist[k] = hist.get(k, 0) + n

    def _build_step(self, plan: StepPlan,
                    device_feed: bool = False) -> StepBatch:
        """Build the whole step's static-shape arrays from the plan — ONE
        construction path shared by the sync loop and the async pipeline.
        With ``device_feed`` decode rows take their input token from the
        device-resident lane feed (-1) instead of a host value, so the
        plan can be built before the previous step's tokens reach the
        host."""
        if self._rec_leaves and plan.prefill:
            self._reset_or_restore_state(plan.prefill)
        if self._should_pack(plan):
            return self._build_packed(plan, device_feed)

        B = self.ecfg.num_lanes
        NP = self.scheduler.pages_per_lane
        mgr = self.scheduler.manager
        off = self._patch_offset

        page_table = np.full((B, NP), -1, np.int32)
        cache_len = np.zeros(B, np.int32)
        lane_mask = np.zeros(B, bool)
        S = (bucket_len(max(c.n for c in plan.prefill),
                        self.scheduler.prefill_buckets) or
             max(c.n for c in plan.prefill)) if plan.prefill else 1
        tokens = np.zeros((B, S), np.int32)
        positions = np.zeros((B, S), np.int32)
        slot_idx = np.full((B, S), -1, np.int32)      # Eq. 5 SkipSet: pads
        pad_mask = np.zeros((B, S), bool)
        last_pos = np.zeros(B, np.int32)
        feed = np.full(B, -2, np.int32)
        scatter_lane = np.full(B, B, np.int32)        # B = drop
        samples: List[Tuple[Request, bool, Tuple[int, ...]]] = []

        for c in plan.prefill:
            lane, n = c.req.lane, c.n
            # token column j holds position start+j; columns inside the
            # vlm patch-stub prefix carry a placeholder id (the model
            # swaps in the patch embedding by position)
            pcols = min(max(off - c.start, 0), n)
            tokens[lane, pcols:pcols + len(c.tokens)] = c.tokens
            positions[lane] = np.minimum(c.start + np.arange(S),
                                         c.start + n - 1)
            slot_idx[lane, :n] = mgr.slot_indices(
                c.req.pool_id, np.arange(c.start, c.start + n))
            page_table[lane] = self.scheduler.page_table(c.req)
            cache_len[lane] = c.start + n
            pad_mask[lane, :n] = True
            last_pos[lane] = n - 1
            lane_mask[lane] = True
            if c.final:
                samples.append((c.req, True, (lane,)))
                scatter_lane[lane] = lane
        for d in plan.decode:                          # a chunk of length 1
            lane = d.req.lane
            tokens[lane, 0] = d.req.output[-1] if d.req.output else 0
            positions[lane] = d.pos
            slot_idx[lane, 0] = d.slot
            page_table[lane] = self.scheduler.page_table(d.req)
            cache_len[lane] = d.pos + 1
            pad_mask[lane, 0] = True
            last_pos[lane] = 0
            lane_mask[lane] = True
            samples.append((d.req, False, (lane,)))
            scatter_lane[lane] = lane
            if device_feed:
                feed[lane] = -1        # device lane feed, never host-sync
        if len(plan.decode) > 1:
            self._note_sharing(page_table[[d.req.lane
                                           for d in plan.decode]])

        if device_feed and not plan.prefill:
            # decode fast path: one fused metadata upload (unpacked in
            # _async_step_impl) + constant zero tokens (device lane feed)
            batch = {"dmeta": jnp.asarray(np.stack(
                         [positions[:, 0], slot_idx[:, 0], cache_len])),
                     "page_table": self._dev_const(page_table),
                     "token": self._dev_const(np.zeros_like(tokens))}
            return StepBatch(kind="decode", batch=batch,
                             lane_mask=lane_mask, plan=plan,
                             samples=samples, tp=0, td=len(plan.decode),
                             feed=feed,
                             row_lane=np.arange(B, dtype=np.int32),
                             scatter_lane=scatter_lane)
        batch = {"positions": jnp.asarray(positions),
                 "slot_idx": jnp.asarray(slot_idx),
                 "page_table": self._dev_const(page_table),
                 "cache_len": jnp.asarray(cache_len)}
        if plan.prefill:
            batch.update(tokens=jnp.asarray(tokens),
                         pad_mask=jnp.asarray(pad_mask),
                         last_pos=jnp.asarray(last_pos))
            if self.cfg.family == "vlm":
                batch["patches"] = jnp.zeros((B, off, self.cfg.d_model),
                                             jnp.bfloat16)
            if self.cfg.family == "whisper":
                firsts = np.zeros(B, bool)
                for c in plan.prefill:
                    firsts[c.req.lane] |= c.first
                if firsts.any():
                    # cross-KV is computed ONCE per request, on its first
                    # chunk; steps without one skip the encoder entirely
                    batch["frames"] = jnp.zeros(
                        (B, self.cfg.num_frames, self.cfg.d_model),
                        jnp.bfloat16)
                    batch["cross_mask"] = jnp.asarray(firsts)
            kind = "prefill"
        else:
            batch["token"] = jnp.asarray(tokens)
            kind = "decode"

        return StepBatch(kind=kind, batch=batch, lane_mask=lane_mask,
                         plan=plan, samples=samples,
                         tp=sum(c.n for c in plan.prefill),
                         td=len(plan.decode), feed=feed,
                         row_lane=np.arange(B, dtype=np.int32),
                         scatter_lane=scatter_lane)

    def _build_packed(self, plan: StepPlan,
                      device_feed: bool = False) -> StepBatch:
        """Concat-prefill packing: several prompts' chunks share one row as
        SEGMENTS, with per-row segment ids (``seg_q``/``page_seg``) and
        per-segment logical page indices (``page_base``) threaded to the
        segment-aware chunk kernels so attention cannot leak across packed
        prompts. Decode items keep one row each (their token feeds the
        async lane plumbing); rows are padded to a power-of-two bucket, so
        short-prompt steps run with FEWER rows than lanes — the packed
        win."""
        ps = self.coopt.page_size
        NP = self.scheduler.pages_per_lane
        G = self.ecfg.pack_slots
        mgr = self.scheduler.manager

        S = (bucket_len(max(c.n for c in plan.prefill),
                        self.scheduler.prefill_buckets) or
             max(c.n for c in plan.prefill))
        rows = pack_rows(plan.prefill, S, G, NP, ps)
        n_rows = len(plan.decode) + len(rows)
        R = 1
        while R < n_rows:
            R *= 2
        R = min(R, max(self.ecfg.num_lanes, n_rows))
        B = self.ecfg.num_lanes

        tokens = np.zeros((R, S), np.int32)
        positions = np.zeros((R, S), np.int32)
        seg_q = np.full((R, S), -1, np.int32)        # -1 matches no page
        slot_idx = np.full((R, S), -1, np.int32)
        page_table = np.full((R, NP), -1, np.int32)
        page_seg = np.zeros((R, NP), np.int32)
        page_base = np.zeros((R, NP), np.int32)
        cache_len = np.zeros(R, np.int32)
        pad_mask = np.zeros((R, S), bool)
        last_pos = np.zeros((R, G), np.int32)
        feed = np.full(R, -2, np.int32)
        row_lane = np.zeros(R, np.int32)
        scatter_lane = np.full(R * G, B, np.int32)   # num_lanes = drop
        samples: List[Tuple[Request, bool, Tuple[int, ...]]] = []

        for i, d in enumerate(plan.decode):          # one row per decode
            tokens[i, 0] = d.req.output[-1] if d.req.output else 0
            positions[i] = d.pos
            seg_q[i, 0] = 0
            slot_idx[i, 0] = d.slot
            pt = self.scheduler.page_table(d.req)
            page_table[i] = pt
            page_base[i] = np.arange(NP)
            cache_len[i] = d.pos + 1
            pad_mask[i, 0] = True
            row_lane[i] = d.req.lane
            scatter_lane[i * G] = d.req.lane
            samples.append((d.req, False, (i, 0)))
            if device_feed:
                feed[i] = -1
        if len(plan.decode) > 1:
            self._note_sharing(page_table[:len(plan.decode)])

        for j, row in enumerate(rows):
            r = len(plan.decode) + j
            t = pcur = g = 0
            for k, c in enumerate(row.chunks):
                n, npg = c.n, chunk_pages(c, ps)
                tokens[r, t:t + n] = c.tokens
                positions[r, t:t + n] = c.start + np.arange(n)
                seg_q[r, t:t + n] = k
                slot_idx[r, t:t + n] = mgr.slot_indices(
                    c.req.pool_id, np.arange(c.start, c.start + n))
                page_table[r, pcur:pcur + npg] = \
                    self.scheduler.page_table(c.req)[:npg]
                page_seg[r, pcur:pcur + npg] = k
                page_base[r, pcur:pcur + npg] = np.arange(npg)
                pad_mask[r, t:t + n] = True
                if c.final:
                    last_pos[r, g] = t + n - 1
                    scatter_lane[r * G + g] = c.req.lane
                    samples.insert(g + sum(x.finals for x in rows[:j]),
                                   (c.req, True, (r, g)))
                    g += 1
                t += n
                pcur += npg
            cache_len[r] = t
            row_lane[r] = row.chunks[0].req.lane

        # prefill finals emit BEFORE decode tokens (matches the unpacked
        # emission order exactly)
        samples.sort(key=lambda s: not s[1])

        batch = {"positions": jnp.asarray(positions),
                 "slot_idx": jnp.asarray(slot_idx),
                 "page_table": jnp.asarray(page_table),
                 "cache_len": jnp.asarray(cache_len),
                 "tokens": jnp.asarray(tokens),
                 "pad_mask": jnp.asarray(pad_mask),
                 "last_pos": jnp.asarray(last_pos),
                 "seg_q": jnp.asarray(seg_q),
                 "page_seg": jnp.asarray(page_seg),
                 "page_base": jnp.asarray(page_base)}
        self.stats.packed_steps += 1
        self.stats.packed_rows_saved += max(
            len(plan.decode) + len(plan.prefill) - R, 0)
        return StepBatch(kind="packed", batch=batch,
                         lane_mask=np.ones(B, bool), plan=plan,
                         samples=samples,
                         tp=sum(c.n for c in plan.prefill),
                         td=len(plan.decode), feed=feed, row_lane=row_lane,
                         scatter_lane=scatter_lane)

    def _execute(self, sb: StepBatch):
        """Synchronous dispatch: run the step, block, attribute wall time
        by planned token share (a prefill-heavy mixed step must not book
        its whole wall time under decode — Eq. 12)."""
        if self.faults is not None:
            self.faults.before_execute(sb)
        fn = {"prefill": self._prefill_fn, "decode": self._decode_fn,
              "packed": self._packed_fn}[sb.kind]
        t0 = time.perf_counter()
        logits, self.cache = fn(self.params, sb.batch, self.cache,
                                self._dev_const(sb.lane_mask))
        logits.block_until_ready()
        self._book_time(sb, time.perf_counter() - t0)
        return logits

    def _book_time(self, sb: StepBatch, dt: float) -> None:
        share = dt / max(sb.tp + sb.td, 1)
        if sb.tp:
            self.stats.prefill_time += share * sb.tp
            self.stats.prefill_calls += 1
        if sb.td:
            self.stats.decode_time += share * sb.td
            self.stats.decode_steps += 1
        if sb.tp and sb.td:
            self.stats.mixed_steps += 1

    def _note_executed(self, sb: StepBatch) -> None:
        """Host metadata updates that must land before the NEXT plan is
        built (they do not depend on sampled token VALUES): advance
        prefill progress, register prefix pages, snapshot recurrent
        state."""
        for c in sb.plan.prefill:
            self.scheduler.note_prefilled(c.req, c.n)
            if self._rec_leaves:
                self._snapshot_state(c)

    def _postprocess(self, sb: StepBatch, toks: np.ndarray,
                     now: float) -> None:
        """Route host-visible sampled tokens back to their requests and
        retire the finished ones."""
        for req, first, idx in sb.samples:
            self._emit(req, int(toks[idx]), now, first=first)
        self._finish_done([req for req, _, _ in sb.samples])

    def _run_mixed(self, plan: StepPlan) -> None:
        """One device call for the whole step, for EVERY model family:
        prefill chunks + decode tokens through the chunked-continuation
        path (a decode lane is a chunk of length 1). A step with only
        decode lanes takes the one-token decode kernel — same composition,
        S == 1, with the block-sparse ``long_window`` policy available.
        With ``pack_prefill`` the prefill chunks run through the packed
        concat-prefill layout instead."""
        sb = self._build_step(plan)
        logits = self._execute(sb)
        toks = self._sample(logits)
        self._note_executed(sb)
        self._postprocess(sb, toks, time.perf_counter())

    # ------------------------------------------------- async step dispatch --
    def _async_key(self, kind: str, batch: Dict[str, jnp.ndarray]) -> tuple:
        """AOT executable key: the step kind plus every batch array's
        (name, shape, dtype). ``lane_tok``/``feed``/``row_lane``/
        ``scatter_lane`` shapes are functions of these, and params/cache
        shapes are fixed per engine, so this pins the whole signature."""
        return (kind,) + tuple(sorted(
            (k, tuple(v.shape), str(v.dtype)) for k, v in batch.items()))

    def _dev_const(self, arr: np.ndarray) -> jnp.ndarray:
        """Device-memoized small host array (recurs across steps)."""
        k = (arr.dtype.str, arr.shape, arr.tobytes())
        v = self._dev_cache.get(k)
        if v is None:
            if len(self._dev_cache) > 512:
                self._dev_cache.clear()
            v = self._dev_cache[k] = jnp.asarray(arr)
        return v

    def _async_args(self, sb: StepBatch, lane_tok, key):
        return (self.params, sb.batch, self.cache,
                self._dev_const(sb.lane_mask), lane_tok, key,
                self._dev_const(sb.feed), self._dev_const(sb.row_lane),
                self._dev_const(sb.scatter_lane))

    def _dispatch_async(self, sb: StepBatch, lane_tok):
        """Dispatch one pipeline step WITHOUT blocking: prefer the AOT
        executable warmed up for this shape (zero traces in steady state);
        fall back to the jit path and count the miss."""
        if self.faults is not None:
            self.faults.before_execute(sb)
        if self.ecfg.sampling.temperature > 0:
            self.key, sub = jax.random.split(self.key)
        else:
            sub = self.key               # greedy: argmax ignores the key
        args = self._async_args(sb, lane_tok, sub)
        fn = self._aot.get(self._async_key(sb.kind, sb.batch))
        if fn is not None:
            toks, self.cache, lane_tok = fn(*args)
        else:
            self.aot_misses += 1
            toks, self.cache, lane_tok = self._async_fn(*args, kind=sb.kind)
        self._book_time(sb, 0.0)      # step counters; async wall time is
        return toks, lane_tok         # booked end-to-end by the caller

    # ------------------------------------------------------- AOT warmup ----
    def _dummy_batch(self, kind: str, R: int, S: int,
                     whisper_first: bool = True) -> Dict[str, jnp.ndarray]:
        """A shape-exact stand-in for one step's batch (values never run —
        ``lower().compile()`` only reads shapes/dtypes)."""
        B = self.ecfg.num_lanes
        NP = self.scheduler.pages_per_lane
        if kind == "decode":       # fused-dmeta schema (device-feed path)
            return {"dmeta": jnp.zeros((3, R), jnp.int32),
                    "page_table": jnp.full((R, NP), -1, jnp.int32),
                    "token": jnp.zeros((R, S), jnp.int32)}
        batch = {"positions": jnp.zeros((R, S), jnp.int32),
                 "slot_idx": jnp.full((R, S), -1, jnp.int32),
                 "page_table": jnp.full((R, NP), -1, jnp.int32),
                 "cache_len": jnp.zeros((R,), jnp.int32)}
        batch.update(tokens=jnp.zeros((R, S), jnp.int32),
                     pad_mask=jnp.zeros((R, S), bool))
        if kind == "packed":
            G = self.ecfg.pack_slots
            batch.update(last_pos=jnp.zeros((R, G), jnp.int32),
                         seg_q=jnp.full((R, S), -1, jnp.int32),
                         page_seg=jnp.zeros((R, NP), jnp.int32),
                         page_base=jnp.zeros((R, NP), jnp.int32))
            return batch
        batch["last_pos"] = jnp.zeros((R,), jnp.int32)
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros((B, self._patch_offset,
                                          self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "whisper" and whisper_first:
            batch["frames"] = jnp.zeros(
                (B, self.cfg.num_frames, self.cfg.d_model), jnp.bfloat16)
            batch["cross_mask"] = jnp.zeros((B,), bool)
        return batch

    def _warmup_lattice(self) -> List[Tuple[str, Dict[str, jnp.ndarray]]]:
        """Every steady-state step shape the async pipeline can dispatch:
        one decode shape, one prefill shape per bucket (whisper: with and
        without the first-chunk encoder), and — when packing — every
        (row-bucket x prefill-bucket) packed shape."""
        B = self.ecfg.num_lanes
        buckets = self.scheduler.prefill_buckets
        lattice = [("decode", self._dummy_batch("decode", B, 1))]
        for S in buckets:
            lattice.append(("prefill", self._dummy_batch("prefill", B, S)))
            if self.cfg.family == "whisper":
                lattice.append(("prefill", self._dummy_batch(
                    "prefill", B, S, whisper_first=False)))
        if self.ecfg.pack_prefill and self._pack_ok:
            row_buckets = []
            r = 1
            while r < B:
                row_buckets.append(r)
                r *= 2
            row_buckets.append(B)
            for R in row_buckets:
                for S in buckets:
                    lattice.append(("packed",
                                    self._dummy_batch("packed", R, S)))
        return lattice

    def warmup(self) -> int:
        """AOT-compile (``lower().compile()``) the async step executable
        for EVERY shape in the bucket lattice, so steady-state serving
        never traces or compiles. Returns the number of executables built.
        Compiled executables bypass the jit call cache entirely — dispatch
        looks them up by shape key (``_dispatch_async``)."""
        B = self.ecfg.num_lanes
        lane_tok = jnp.zeros((B,), jnp.int32)
        key = jax.random.PRNGKey(0)
        built = 0
        for kind, batch in self._warmup_lattice():
            akey = self._async_key(kind, batch)
            if akey in self._aot:
                continue
            R = batch["page_table"].shape[0]
            n_slots = batch["last_pos"].size if kind == "packed" else R
            sb = StepBatch(kind=kind, batch=batch,
                           lane_mask=np.ones(B, bool), plan=StepPlan(),
                           samples=[], tp=0, td=0,
                           feed=np.full(R, -2, np.int32),
                           row_lane=np.zeros(R, np.int32),
                           scatter_lane=np.full(n_slots, B, np.int32))
            args = self._async_args(sb, lane_tok, key)
            self._aot[akey] = self._async_fn.lower(
                *args, kind=kind).compile()
            built += 1
        return built

    # ---------------------------------------------------------------- API --
    def add_request(self, req: Request) -> None:
        req.enqueue_time = time.perf_counter()
        self.scheduler.add_request(req)

    def abort_all(self, exc: Optional[BaseException] = None
                  ) -> List[Request]:
        """Fault drain: terminate every live request with ERROR, returning
        the pool to zero pages in use. Returns the drained requests so the
        caller (sync loop re-raise, async ``_fail``) can surface the fault
        per stream."""
        drained = self.scheduler.abort_all(FinishReason.ERROR, exc)
        self.stats.errors += len(drained)
        self._abort_prefetch_flights()
        self._update_pool_stats()
        return drained

    def step(self) -> None:
        plan = self.scheduler.schedule_step()
        if plan.empty:
            self._update_pool_stats()       # rejections still count
            return
        try:
            self._run_mixed(plan)
        except Exception as exc:
            # a step fault must not leak pool pages or strand requests:
            # drain everything as ERROR, then surface the fault
            self.abort_all(exc)
            raise
        self._update_pool_stats()

    def run(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 eos_token: Optional[int] = None,
                 return_requests: bool = False):
        """Serve ``prompts`` to completion. Returns the per-prompt output
        token lists (or the full Request objects with ``return_requests`` —
        inspect ``state`` to distinguish FINISHED from REJECTED; rejected
        requests surface with empty output and are counted in
        ``stats.rejected``). Requests are stamped with REAL submission
        times (monotonic clock, submission order preserved by ``req_id``
        tie-break), so ``stats.latency_summary()`` reports TTFT and queue
        wait measured from submission."""
        reqs = []
        for i, p in enumerate(prompts):
            now = time.perf_counter()
            reqs.append(Request(req_id=1000 + i,
                                prompt=np.asarray(p, np.int32),
                                max_new_tokens=max_new_tokens,
                                eos_token=eos_token,
                                arrival_time=now, submit_time=now))
        for r in reqs:
            self.add_request(r)
        self.run()
        if return_requests:
            return reqs
        return [r.output for r in reqs]
