"""LLM-CoOpt serving engine: continuous batching over ONE shared, refcounted,
prefix-cached paged-KV pool, with the paper's three techniques selected by a
``CoOptConfig``.

The engine is the "vLLM migration target" of the paper: the Original mode
reproduces unmodified-vLLM semantics (bf16 cache, every allocated page
loaded, per-head KV expansion) and each Opt-* flag turns on one technique,
so Figs. 6-7's five modes are one constructor argument apart.

Design (hardware adaptation, DESIGN.md §3): the device cache is a GLOBAL
paged pool — per-layer leaves ``(2, P_total, ps, Hkv, D)`` with no batch
dimension, ``P_total = num_lanes * pages(max_len)`` padded to tile evenly
over ``num_shards`` KV shards (the final page reserved as the write
kernel's SkipSet sentinel). The pool's page range is partitioned along the
mesh ``(pod, data)`` axes — the axes CACHE_RULES shard the pages axis over —
and every request is pinned to ONE shard at admission, so its page gathers
stay shard-local. All dynamic paging state (per-shard free lists, refcounts,
per-shard prefix-cache hash tables, slot indices, SkipSets) lives host-side
in the Scheduler/BlockManager; the device sees only static-shape index
arrays: global ``slot_idx``, per-lane ``page_table``, per-lane
``cache_len``. Lane isolation is enforced by slot disjointness — a lane can
only write pages it exclusively owns (shared prefix pages are read-only by
refcount construction) — so cache updates need no batch masking; only
batch-major leaves (per-lane lengths, recurrent state, whisper cross-KV) are
masked with the admitted-lane mask.

Scheduling (Sarathi-style): each step is composed under a token budget,
mixing decode tokens and chunked-prefill chunks, and EVERY family executes
the whole step as ONE device call through the chunked-continuation prefill
path (a decode lane is a chunk of length 1; a step with only decode lanes
takes the one-token decode kernel). The Opt-Pa two-step strategy — "segment
long sequences into manageable chunks, then apply lazy memory mapping and
computation" (paper §3.3) — therefore applies uniformly: dense/moe/vlm
attend the gathered paged history with true positions, MLA in absorbed
latent form, whisper over its decoder self-KV (cross-KV computed once, on
the first chunk), and griffin/rwkv6 thread their recurrent state across
chunks (the state after chunk k is the input state of chunk k+1), with
state snapshots at committed page boundaries backing their prefix cache.
Admission is shard-affine (prefix-affinity first, least-loaded fallback).
Shard exhaustion preempts the youngest running request ON THE PRESSURED
SHARD (freed pages, front-of-queue requeue, greedy-exact resume) instead of
crashing; impossible requests are REJECTED and surfaced.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.block_manager import chain_hash_tokens, extend_chain_hash
from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.models import get_model
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams, sample
from repro.serving.scheduler import (DecodeItem, PrefillChunk, Scheduler,
                                     StepPlan, bucket_len)


@dataclass(frozen=True)
class EngineConfig:
    num_lanes: int = 4
    max_len: int = 512
    prefill_buckets: Tuple[int, ...] = (64, 128, 256, 512)
    long_window: int = 0            # >0: block-sparse long-context decode
    sampling: SamplingParams = SamplingParams()
    seed: int = 0
    token_budget: int = 0           # 0 => max(prefill_buckets)
    enable_prefix_cache: bool = True
    num_shards: int = 1             # KV-pool page-range shards; matches the
                                    # mesh (pod, data) extent the cache
                                    # pages axis is sharded over
                                    # (launch.mesh.kv_shard_count)
    state_cache_entries: int = 128  # recurrent-state snapshots retained
                                    # (griffin/rwkv6 prefix-cache resume)


@dataclass
class EngineStats:
    prefill_calls: int = 0
    decode_steps: int = 0
    mixed_steps: int = 0            # decode + prefill fused in one call
    generated_tokens: int = 0
    prefill_time: float = 0.0       # mixed-step wall time is split by
    decode_time: float = 0.0        # planned token share (Eq. 12 fairness)
    # ------------------------------------------------ per-request latency --
    ttft_s: List[float] = field(default_factory=list)   # enqueue->1st token
    tpot_s: List[float] = field(default_factory=list)   # mean s/token after
    # ----------------------------------------------------- pool health ----
    pool_pages: int = 0
    pages_in_use: int = 0           # referenced by live sequences (now)
    peak_pages_in_use: int = 0
    fresh_pages_allocated: int = 0  # pages handed out over the run
    prefix_cache_queries: int = 0
    prefix_cache_hits: int = 0      # full prompt pages reused, not recomputed
    preemptions: int = 0
    rejected: int = 0
    # --------------------------------------------------- sharded pool ----
    num_shards: int = 1
    shard_pages: Tuple[int, ...] = ()          # page-range size per shard
    shard_pages_in_use: Tuple[int, ...] = ()
    peak_shard_pages_in_use: Tuple[int, ...] = ()
    shard_preemptions: Tuple[int, ...] = ()    # per-shard pressure evictions
    placement_prefix_hits: int = 0  # admitted on the prefix-affine shard
    placement_misses: int = 0       # prefix lived on an unusable shard ->
                                    # cross-shard CoW reuse lost

    @property
    def total_time(self) -> float:
        return self.prefill_time + self.decode_time

    def throughput(self) -> float:
        """Paper Eq. 12: generated tokens / generation time (decode's
        token-share of mixed steps, not whole mixed-step wall clock)."""
        return self.generated_tokens / self.decode_time \
            if self.decode_time else 0.0

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def ttft(self, q: float = 50.0) -> float:
        """Time-to-first-token percentile (s) over finished requests."""
        return self._pct(self.ttft_s, q)

    def tpot(self, q: float = 50.0) -> float:
        """Per-request mean time-per-output-token percentile (s)."""
        return self._pct(self.tpot_s, q)

    def latency_summary(self) -> Dict[str, float]:
        return {"ttft_p50_s": round(self.ttft(50), 4),
                "ttft_p95_s": round(self.ttft(95), 4),
                "tpot_p50_s": round(self.tpot(50), 4),
                "tpot_p95_s": round(self.tpot(95), 4)}

    def pool_utilization(self) -> float:
        return self.pages_in_use / self.pool_pages if self.pool_pages else 0.0

    def shard_utilization(self) -> Tuple[float, ...]:
        return tuple(u / p if p else 0.0
                     for u, p in zip(self.shard_pages_in_use,
                                     self.shard_pages))

    def prefix_hit_rate(self) -> float:
        return self.prefix_cache_hits / self.prefix_cache_queries \
            if self.prefix_cache_queries else 0.0


class Engine:
    def __init__(self, model_cfg: ModelConfig, coopt: CoOptConfig = COOPT,
                 engine_cfg: EngineConfig = EngineConfig(),
                 params=None, mesh=None):
        """``mesh``: optional ``jax.sharding.Mesh``. When given, the KV-pool
        shard count is DERIVED from the mesh's pages axes
        (``launch.mesh.kv_shard_count``) — a default ``num_shards=1`` config
        is upgraded to match, and a conflicting explicit value raises (the
        host page ranges and the device pages-axis partition must coincide).
        The cache leaves are placed on the mesh, and with
        ``coopt.use_kernel`` the pooled Pallas kernels run through the
        ``kernels.sharded`` shard_map layer — one kernel hot path, single-
        host and distributed."""
        self.cfg = model_cfg
        self.coopt = coopt
        if mesh is not None:
            from repro.launch.mesh import kv_shard_count
            ns = kv_shard_count(mesh)
            if engine_cfg.num_shards == 1:
                # config built before the mesh: derive the shard count
                engine_cfg = dataclasses.replace(engine_cfg, num_shards=ns)
            elif engine_cfg.num_shards != ns:
                raise ValueError(
                    f"EngineConfig.num_shards={engine_cfg.num_shards} "
                    f"disagrees with the mesh's KV shard count {ns} "
                    f"(pages axes {tuple(mesh.shape)}); build the config "
                    "from launch.mesh.kv_shard_count(mesh) or leave it at "
                    "the default to derive it")
        self.mesh = mesh
        self.ecfg = engine_cfg
        self.model = get_model(model_cfg)
        if params is None:
            params = self.model.init(jax.random.PRNGKey(engine_cfg.seed))
        self.params = params
        self.key = jax.random.PRNGKey(engine_cfg.seed + 1)

        B, M = engine_cfg.num_lanes, engine_cfg.max_len
        # the device pool's pages axis is padded so it tiles evenly over the
        # KV shards (host page ids == device page ids, see opt_kv helpers)
        self.cache = self.model.init_cache(B, M, coopt,
                                           num_shards=engine_cfg.num_shards)
        # pages-axis shard_map dispatch for the pooled kernels (None for no
        # mesh / an unsharded mesh: identical single-host code path)
        from repro.kernels import ops
        self._kernel_ctx = (ops.make_mesh_ctx(mesh)
                            if coopt.use_kernel else None)
        if mesh is not None:
            self.cache = self._place_cache(self.cache, mesh)
        self._patch_offset = (model_cfg.num_patches
                              if model_cfg.family == "vlm" else 0)
        # recurrent-state families: chunk boundaries land on page boundaries
        # so the cross-chunk state can be snapshotted as the prefix cache's
        # resume artifact (KV pages alone cannot resume a recurrence)
        self._rec_leaves = tuple(getattr(self.model, "recurrent_leaves", ()))
        self.scheduler = Scheduler(
            B, M, coopt.page_size, list(engine_cfg.prefill_buckets),
            extra_tokens=self._patch_offset,
            token_budget=engine_cfg.token_budget or None,
            enable_prefix_cache=engine_cfg.enable_prefix_cache,
            num_shards=engine_cfg.num_shards,
            page_aligned=bool(self._rec_leaves))
        # chain-hash(prefix pages) -> per-lane state slices; the manager's
        # prefix_gate makes page matching stop at the last boundary we can
        # actually restore
        self._state_cache: "OrderedDict[int, Dict[str, np.ndarray]]" = \
            OrderedDict()
        if self._rec_leaves:
            self.scheduler.manager.prefix_gate = self._state_cache.__contains__
        self.stats = EngineStats()
        self.stats.pool_pages = self.scheduler.manager.num_pages

        # only batch-major leaves (length, recurrent state, whisper x-KV)
        # need lane masking; global-pool leaves are isolated by slot
        # disjointness.
        shapes = self.model.cache_shape(B, M, coopt)
        self._batch_axis = {k: axes.index("batch")
                            for k, (_, _, axes) in shapes.items()
                            if "batch" in axes}

        self._prefill_fn = jax.jit(self._prefill_impl)
        self._decode_fn = jax.jit(self._decode_impl)

    # ------------------------------------------------------- mesh placement --
    def _place_cache(self, cache, mesh):
        """Shard the device cache leaves onto the mesh: the kernel path
        partitions the pool ONLY along its pages axes (the shard_map
        layer's layout — heads/latent replicated); the jnp reference path
        uses the full CACHE_RULES (GSPMD handles the rest)."""
        from jax.sharding import NamedSharding
        from repro.launch.steps import (CACHE_RULES, KERNEL_CACHE_RULES,
                                        axes_pspec)
        rules = (KERNEL_CACHE_RULES if self.coopt.use_kernel
                 else CACHE_RULES)
        shapes = self.model.cache_shape(self.ecfg.num_lanes,
                                        self.ecfg.max_len, self.coopt,
                                        num_shards=self.ecfg.num_shards)
        return {k: jax.device_put(
                    leaf, NamedSharding(mesh, axes_pspec(
                        shapes[k][0], shapes[k][2], mesh, rules)))
                for k, leaf in cache.items()}

    # ---------------------------------------------------------- jit bodies --
    def _mask_lanes(self, new_cache, old_cache, lane_mask):
        out = {}
        for name, leaf in new_cache.items():
            ax = self._batch_axis.get(name)
            if ax is None:
                out[name] = leaf
                continue
            m = lane_mask.reshape((1,) * ax + (-1,) +
                                  (1,) * (leaf.ndim - ax - 1))
            out[name] = jnp.where(m, leaf, old_cache[name])
        return out

    def _prefill_impl(self, params, batch, cache, lane_mask):
        from repro.kernels import ops
        with ops.mesh_ctx_scope(self._kernel_ctx):   # trace-scoped
            logits, new_cache = self.model.prefill(
                params, batch, cache, self.coopt,
                long_window=self.ecfg.long_window)
            return logits, self._mask_lanes(new_cache, cache, lane_mask)

    def _decode_impl(self, params, batch, cache, lane_mask):
        from repro.kernels import ops
        with ops.mesh_ctx_scope(self._kernel_ctx):   # trace-scoped
            logits, new_cache = self.model.decode_step(
                params, batch, cache, self.coopt,
                long_window=self.ecfg.long_window)
            return logits, self._mask_lanes(new_cache, cache, lane_mask)

    # -------------------------------------------------------------- common --
    def _sample(self, logits) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        sp = self.ecfg.sampling
        return np.asarray(sample(logits, sub, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p))

    def _emit(self, req: Request, tok: int, now: float,
              first: bool) -> None:
        req.output.append(tok)
        self.stats.generated_tokens += 1
        if first and req.prefill_time < 0:
            req.prefill_time = now          # TTFT anchor survives preemption

    def _finish_done(self, reqs: List[Request]) -> None:
        done = [r for r in reqs if r.done()]
        now = time.perf_counter()
        for r in done:
            r.finish_time = now
            if r.prefill_time >= 0 and r.enqueue_time >= 0:
                self.stats.ttft_s.append(r.prefill_time - r.enqueue_time)
                if r.num_generated > 1:
                    self.stats.tpot_s.append(
                        (r.finish_time - r.prefill_time)
                        / (r.num_generated - 1))
            self.scheduler.finish(r)

    def _update_pool_stats(self) -> None:
        mgr = self.scheduler.manager
        s = self.stats
        s.pool_pages = mgr.num_pages
        s.pages_in_use = mgr.pages_in_use
        s.peak_pages_in_use = max(s.peak_pages_in_use, mgr.pages_in_use)
        s.fresh_pages_allocated = mgr.fresh_pages_allocated
        s.prefix_cache_queries = mgr.prefix_queries
        s.prefix_cache_hits = mgr.prefix_hits
        s.preemptions = self.scheduler.preemptions
        s.rejected = len(self.scheduler.rejected)
        # per-shard health (page-range ownership along the mesh data/pod axes)
        n = mgr.num_shards
        s.num_shards = n
        s.shard_pages = tuple(mgr.shard_capacity(i) for i in range(n))
        s.shard_pages_in_use = tuple(mgr.pages_in_use_in(i)
                                     for i in range(n))
        peak = s.peak_shard_pages_in_use or (0,) * n
        s.peak_shard_pages_in_use = tuple(
            max(p, u) for p, u in zip(peak, s.shard_pages_in_use))
        s.shard_preemptions = tuple(self.scheduler.preemptions_by_shard)
        s.placement_prefix_hits = self.scheduler.placement_prefix_hits
        s.placement_misses = self.scheduler.placement_misses

    # ------------------------------------------------- recurrent snapshots --
    def _lane_index(self, leaf: str, lane: int):
        ax = self._batch_axis[leaf]
        return (slice(None),) * ax + (lane,)

    def _reset_or_restore_state(self, chunks: List[PrefillChunk]) -> None:
        """First chunk of a (re)admitted request on a recurrent-state
        family: the lane's state leaves hold the PREVIOUS occupant's state —
        zero them, or restore the snapshot matching the prefix-cache hit
        (``start > 0`` implies the manager's prefix_gate verified one)."""
        ps = self.coopt.page_size
        for c in chunks:
            if not c.first:
                continue
            lane = c.req.lane
            snap = None
            # (re)seed the request's running chain hash at its resume point
            c.req.prefix_hash_pages = c.start // ps
            c.req.prefix_hash = chain_hash_tokens(
                c.req.effective_prompt(), c.req.prefix_hash_pages, ps)
            if c.start > 0:
                snap = self._state_cache[c.req.prefix_hash]
                self._state_cache.move_to_end(c.req.prefix_hash)
            for leaf in self._rec_leaves:
                idx = self._lane_index(leaf, lane)
                cur = self.cache[leaf]
                val = 0 if snap is None else jnp.asarray(snap[leaf],
                                                         cur.dtype)
                self.cache[leaf] = cur.at[idx].set(val)

    def _snapshot_state(self, c: PrefillChunk) -> None:
        """A chunk that ended exactly on a page boundary leaves the lane's
        recurrent state at a committed-prefix resume point: snapshot it
        under the same chain hash the pages were registered with."""
        ps = self.coopt.page_size
        end = c.start + c.n
        if end % ps or not self.ecfg.enable_prefix_cache:
            return
        # extend the request's running hash — never rehash from page 0
        key = extend_chain_hash(c.req.prefix_hash, c.req.effective_prompt(),
                                c.req.prefix_hash_pages, end // ps, ps)
        c.req.prefix_hash, c.req.prefix_hash_pages = key, end // ps
        if key in self._state_cache:
            self._state_cache.move_to_end(key)
            return
        self._state_cache[key] = {
            leaf: np.asarray(self.cache[leaf][self._lane_index(leaf,
                                                               c.req.lane)])
            for leaf in self._rec_leaves}
        while len(self._state_cache) > self.ecfg.state_cache_entries:
            self._state_cache.popitem(last=False)

    # --------------------------------------------------- the ONE step path --
    def _run_mixed(self, plan: StepPlan) -> None:
        """One device call for the whole step, for EVERY model family:
        prefill chunks + decode tokens through the chunked-continuation
        path (a decode lane is a chunk of length 1). A step with only
        decode lanes takes the one-token decode kernel — same composition,
        S == 1, with the block-sparse ``long_window`` policy available."""
        B = self.ecfg.num_lanes
        NP = self.scheduler.pages_per_lane
        mgr = self.scheduler.manager
        off = self._patch_offset

        if self._rec_leaves and plan.prefill:
            self._reset_or_restore_state(plan.prefill)

        page_table = np.full((B, NP), -1, np.int32)
        cache_len = np.zeros(B, np.int32)
        lane_mask = np.zeros(B, bool)
        S = (bucket_len(max(c.n for c in plan.prefill),
                        self.scheduler.prefill_buckets) or
             max(c.n for c in plan.prefill)) if plan.prefill else 1
        tokens = np.zeros((B, S), np.int32)
        positions = np.zeros((B, S), np.int32)
        slot_idx = np.full((B, S), -1, np.int32)      # Eq. 5 SkipSet: pads
        pad_mask = np.zeros((B, S), bool)
        last_pos = np.zeros(B, np.int32)

        for c in plan.prefill:
            lane, n = c.req.lane, c.n
            # token column j holds position start+j; columns inside the
            # vlm patch-stub prefix carry a placeholder id (the model
            # swaps in the patch embedding by position)
            pcols = min(max(off - c.start, 0), n)
            tokens[lane, pcols:pcols + len(c.tokens)] = c.tokens
            positions[lane] = np.minimum(c.start + np.arange(S),
                                         c.start + n - 1)
            slot_idx[lane, :n] = mgr.slot_indices(
                c.req.pool_id, np.arange(c.start, c.start + n))
            page_table[lane] = self.scheduler.page_table(c.req)
            cache_len[lane] = c.start + n
            pad_mask[lane, :n] = True
            last_pos[lane] = n - 1
            lane_mask[lane] = True
        for d in plan.decode:                          # a chunk of length 1
            lane = d.req.lane
            tokens[lane, 0] = d.req.output[-1]
            positions[lane] = d.pos
            slot_idx[lane, 0] = d.slot
            page_table[lane] = self.scheduler.page_table(d.req)
            cache_len[lane] = d.pos + 1
            pad_mask[lane, 0] = True
            last_pos[lane] = 0
            lane_mask[lane] = True

        batch = {"positions": jnp.asarray(positions),
                 "slot_idx": jnp.asarray(slot_idx),
                 "page_table": jnp.asarray(page_table),
                 "cache_len": jnp.asarray(cache_len)}
        if plan.prefill:
            batch.update(tokens=jnp.asarray(tokens),
                         pad_mask=jnp.asarray(pad_mask),
                         last_pos=jnp.asarray(last_pos))
            if self.cfg.family == "vlm":
                batch["patches"] = jnp.zeros((B, off, self.cfg.d_model),
                                             jnp.bfloat16)
            if self.cfg.family == "whisper":
                firsts = np.zeros(B, bool)
                for c in plan.prefill:
                    firsts[c.req.lane] |= c.first
                if firsts.any():
                    # cross-KV is computed ONCE per request, on its first
                    # chunk; steps without one skip the encoder entirely
                    batch["frames"] = jnp.zeros(
                        (B, self.cfg.num_frames, self.cfg.d_model),
                        jnp.bfloat16)
                    batch["cross_mask"] = jnp.asarray(firsts)
            fn = self._prefill_fn
        else:
            batch["token"] = jnp.asarray(tokens)
            fn = self._decode_fn

        t0 = time.perf_counter()
        logits, self.cache = fn(self.params, batch, self.cache,
                                jnp.asarray(lane_mask))
        logits.block_until_ready()
        dt = time.perf_counter() - t0

        # timing attribution by planned token share: a prefill-heavy mixed
        # step must not book its whole wall time under decode (Eq. 12)
        tp = sum(c.n for c in plan.prefill)
        td = len(plan.decode)
        share = dt / max(tp + td, 1)
        if tp:
            self.stats.prefill_time += share * tp
            self.stats.prefill_calls += 1
        if td:
            self.stats.decode_time += share * td
            self.stats.decode_steps += 1
        if tp and td:
            self.stats.mixed_steps += 1

        toks = self._sample(logits)
        now = time.perf_counter()
        for c in plan.prefill:
            self.scheduler.note_prefilled(c.req, c.n)
            if self._rec_leaves:
                self._snapshot_state(c)
            if c.final:
                self._emit(c.req, int(toks[c.req.lane]), now, first=True)
        for d in plan.decode:
            self._emit(d.req, int(toks[d.req.lane]), now, first=False)
        self._finish_done([c.req for c in plan.prefill if c.final] +
                          [d.req for d in plan.decode])

    # ---------------------------------------------------------------- API --
    def add_request(self, req: Request) -> None:
        req.enqueue_time = time.perf_counter()
        self.scheduler.add_request(req)

    def step(self) -> None:
        plan = self.scheduler.schedule_step()
        if plan.empty:
            self._update_pool_stats()       # rejections still count
            return
        self._run_mixed(plan)
        self._update_pool_stats()

    def run(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 eos_token: Optional[int] = None,
                 return_requests: bool = False):
        """Serve ``prompts`` to completion. Returns the per-prompt output
        token lists (or the full Request objects with ``return_requests`` —
        inspect ``state`` to distinguish FINISHED from REJECTED; rejected
        requests surface with empty output and are counted in
        ``stats.rejected``)."""
        reqs = [Request(req_id=1000 + i, prompt=np.asarray(p, np.int32),
                        max_new_tokens=max_new_tokens, eos_token=eos_token,
                        arrival_time=float(i))
                for i, p in enumerate(prompts)]
        for r in reqs:
            self.add_request(r)
        self.run()
        if return_requests:
            return reqs
        return [r.output for r in reqs]
