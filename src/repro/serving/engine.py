"""LLM-CoOpt serving engine: continuous batching over a paged, quantizable
KV cache, with the paper's three techniques selected by a ``CoOptConfig``.

The engine is the "vLLM migration target" of the paper: the Original mode
reproduces unmodified-vLLM semantics (bf16 cache, every allocated page
loaded, per-head KV expansion) and each Opt-* flag turns on one technique,
so Figs. 6-7's five modes are one constructor argument apart.

Design (hardware adaptation, DESIGN.md §3): ``num_lanes`` batch lanes with
static per-lane page pools; all dynamic paging state (free lists, slot
indices, SkipSets) lives host-side in the Scheduler/BlockManager; device
steps are two jit'd functions (bucketed prefill, lockstep decode). Lane
isolation is enforced by masking cache updates with the admitted-lane mask —
idle lanes' state is bit-identical across steps (asserted by tests).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.models import get_model
from repro.serving.request import Request, RequestState
from repro.serving.sampler import SamplingParams, sample
from repro.serving.scheduler import Scheduler, bucket_len


@dataclass(frozen=True)
class EngineConfig:
    num_lanes: int = 4
    max_len: int = 512
    prefill_buckets: Tuple[int, ...] = (64, 128, 256, 512)
    long_window: int = 0            # >0: block-sparse long-context decode
    sampling: SamplingParams = SamplingParams()
    seed: int = 0


@dataclass
class EngineStats:
    prefill_calls: int = 0
    decode_steps: int = 0
    generated_tokens: int = 0
    prefill_time: float = 0.0
    decode_time: float = 0.0

    @property
    def total_time(self) -> float:
        return self.prefill_time + self.decode_time

    def throughput(self) -> float:
        """Paper Eq. 12: generated tokens / generation time."""
        return self.generated_tokens / self.decode_time \
            if self.decode_time else 0.0


class Engine:
    def __init__(self, model_cfg: ModelConfig, coopt: CoOptConfig = COOPT,
                 engine_cfg: EngineConfig = EngineConfig(),
                 params=None):
        self.cfg = model_cfg
        self.coopt = coopt
        self.ecfg = engine_cfg
        self.model = get_model(model_cfg)
        if params is None:
            params = self.model.init(jax.random.PRNGKey(engine_cfg.seed))
        self.params = params
        self.key = jax.random.PRNGKey(engine_cfg.seed + 1)

        B, M = engine_cfg.num_lanes, engine_cfg.max_len
        self.cache = self.model.init_cache(B, M, coopt)
        self._patch_offset = (model_cfg.num_patches
                              if model_cfg.family == "vlm" else 0)
        self.scheduler = Scheduler(
            B, M, coopt.page_size, list(engine_cfg.prefill_buckets),
            extra_tokens=self._patch_offset,
            # chunked continuation prefill: attention families with
            # identity slot mapping (see TransformerModel.prefill)
            allow_chunked=model_cfg.family in ("dense", "moe"))
        self.stats = EngineStats()

        shapes = self.model.cache_shape(B, M, coopt)
        self._batch_axis = {k: axes.index("batch")
                            for k, (_, _, axes) in shapes.items()}

        self._prefill_fn = jax.jit(self._prefill_impl)
        self._decode_fn = jax.jit(self._decode_impl)

    # ---------------------------------------------------------- jit bodies --
    def _mask_lanes(self, new_cache, old_cache, lane_mask):
        out = {}
        for name, leaf in new_cache.items():
            ax = self._batch_axis[name]
            m = lane_mask.reshape((1,) * ax + (-1,) +
                                  (1,) * (leaf.ndim - ax - 1))
            out[name] = jnp.where(m, leaf, old_cache[name])
        return out

    def _prefill_impl(self, params, batch, cache, lane_mask):
        logits, new_cache = self.model.prefill(params, batch, cache,
                                               self.coopt)
        return logits, self._mask_lanes(new_cache, cache, lane_mask)

    def _decode_impl(self, params, batch, cache, lane_mask):
        logits, new_cache = self.model.decode_step(
            params, batch, cache, self.coopt,
            long_window=self.ecfg.long_window)
        return logits, self._mask_lanes(new_cache, cache, lane_mask)

    # ------------------------------------------------------------- prefill --
    def _run_prefill(self, admitted: List[Request]) -> None:
        # oversized prompts (no bucket) go through chunked prefill alone
        big = [r for r in admitted
               if bucket_len(r.prompt_len, self.scheduler.prefill_buckets)
               is None]
        for r in big:
            self._run_chunked_prefill(r)
        admitted = [r for r in admitted if r not in big]
        if not admitted:
            return
        B = self.ecfg.num_lanes
        off = self._patch_offset
        bucket = max(bucket_len(r.prompt_len, self.scheduler.prefill_buckets)
                     for r in admitted)
        S = off + bucket
        tokens = np.zeros((B, bucket), np.int32)
        slot_idx = np.full((B, S), -1, np.int32)       # Eq. 5 SkipSet: pads
        pad_mask = np.zeros((B, S), bool)
        last_pos = np.zeros(B, np.int32)
        lane_mask = np.zeros(B, bool)
        for r in admitted:
            plen = r.prompt_len
            tokens[r.lane, :plen] = r.prompt
            mgr = self.scheduler.managers[r.lane]
            # lane-local physical slots for positions [0, off + plen)
            # (vlm: patch embeddings occupy the leading ``off`` positions)
            pos = np.arange(off + plen)
            slot_idx[r.lane, :off + plen] = mgr.slot_indices(r.req_id, pos)
            pad_mask[r.lane, :off + plen] = True
            last_pos[r.lane] = off + plen - 1
            lane_mask[r.lane] = True

        batch = {"tokens": jnp.asarray(tokens),
                 "slot_idx": jnp.asarray(slot_idx),
                 "pad_mask": jnp.asarray(pad_mask),
                 "last_pos": jnp.asarray(last_pos)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros((B, off, self.cfg.d_model),
                                         jnp.bfloat16)
        if self.cfg.family == "whisper":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.num_frames, self.cfg.d_model), jnp.bfloat16)

        t0 = time.perf_counter()
        logits, self.cache = self._prefill_fn(self.params, batch, self.cache,
                                              jnp.asarray(lane_mask))
        logits.block_until_ready()
        self.stats.prefill_time += time.perf_counter() - t0
        self.stats.prefill_calls += 1

        self.key, sub = jax.random.split(self.key)
        sp = self.ecfg.sampling
        toks = np.asarray(sample(logits, sub, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p))
        now = time.perf_counter()
        for r in admitted:
            r.output.append(int(toks[r.lane]))
            r.prefill_time = now
            self.stats.generated_tokens += 1

    def _run_chunked_prefill(self, r: Request) -> None:
        """Sarathi-style continuation prefill for prompts longer than the
        largest bucket: fixed-size chunks with absolute positions, each
        chunk attending over the whole cache (dense/moe families)."""
        B = self.ecfg.num_lanes
        C = self.scheduler.prefill_buckets[-1]
        plen = r.prompt_len
        mgr = self.scheduler.managers[r.lane]
        lane_mask = np.zeros(B, bool)
        lane_mask[r.lane] = True
        nchunk = (plen + C - 1) // C
        t0 = time.perf_counter()
        for ci in range(nchunk):
            lo = ci * C
            valid = min(C, plen - lo)
            tokens = np.zeros((B, C), np.int32)
            tokens[r.lane, :valid] = r.prompt[lo:lo + valid]
            slot_idx = np.full((B, C), -1, np.int32)
            slot_idx[r.lane, :valid] = mgr.slot_indices(
                r.req_id, np.arange(lo, lo + valid))
            positions = np.broadcast_to(np.arange(lo, lo + C),
                                        (B, C)).astype(np.int32)
            batch = {"tokens": jnp.asarray(tokens),
                     "slot_idx": jnp.asarray(slot_idx),
                     "positions": jnp.asarray(positions),
                     "last_pos": jnp.full((B,), valid - 1, jnp.int32)}
            logits, self.cache = self._prefill_fn(
                self.params, batch, self.cache, jnp.asarray(lane_mask))
        logits.block_until_ready()
        self.stats.prefill_time += time.perf_counter() - t0
        self.stats.prefill_calls += 1

        self.key, sub = jax.random.split(self.key)
        sp = self.ecfg.sampling
        toks = np.asarray(sample(logits, sub, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p))
        r.output.append(int(toks[r.lane]))
        r.prefill_time = time.perf_counter()
        self.stats.generated_tokens += 1

    # -------------------------------------------------------------- decode --
    def _run_decode(self) -> None:
        B = self.ecfg.num_lanes
        tokens = np.zeros((B, 1), np.int32)
        lane_mask = np.zeros(B, bool)
        for lane, r in self.scheduler.running.items():
            tokens[lane, 0] = r.output[-1]
            lane_mask[lane] = True
        slots = self.scheduler.decode_slots()[:, None]   # (B,1), -1 idle

        batch = {"token": jnp.asarray(tokens),
                 "slot_idx": jnp.asarray(slots)}
        t0 = time.perf_counter()
        logits, self.cache = self._decode_fn(self.params, batch, self.cache,
                                             jnp.asarray(lane_mask))
        logits.block_until_ready()
        self.stats.decode_time += time.perf_counter() - t0
        self.stats.decode_steps += 1

        self.key, sub = jax.random.split(self.key)
        sp = self.ecfg.sampling
        toks = np.asarray(sample(logits, sub, temperature=sp.temperature,
                                 top_k=sp.top_k, top_p=sp.top_p))
        finished = []
        for lane, r in self.scheduler.running.items():
            r.output.append(int(toks[lane]))
            self.stats.generated_tokens += 1
            if r.done():
                r.finish_time = time.perf_counter()
                finished.append(r)
        for r in finished:
            self.scheduler.finish(r)

    # ---------------------------------------------------------------- API --
    def add_request(self, req: Request) -> None:
        self.scheduler.add_request(req)

    def step(self) -> None:
        admitted = self.scheduler.schedule_prefills()
        if admitted:
            self._run_prefill(admitted)
        elif self.scheduler.running:
            self._run_decode()

    def run(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 32,
                 eos_token: Optional[int] = None) -> List[List[int]]:
        reqs = [Request(req_id=1000 + i, prompt=np.asarray(p, np.int32),
                        max_new_tokens=max_new_tokens, eos_token=eos_token)
                for i, p in enumerate(prompts)]
        for r in reqs:
            self.add_request(r)
        self.run()
        return [r.output for r in reqs]
