"""Inference request lifecycle (vLLM-style)."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                       # (prompt_len,) int32 token ids
    max_new_tokens: int = 64
    eos_token: Optional[int] = None
    arrival_time: float = 0.0

    # runtime state
    state: RequestState = RequestState.WAITING
    lane: int = -1                           # engine batch lane
    output: List[int] = field(default_factory=list)
    prefill_time: float = -1.0               # first-token timestamp
    finish_time: float = -1.0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def num_generated(self) -> int:
        return len(self.output)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.num_generated

    def done(self) -> bool:
        if self.num_generated >= self.max_new_tokens:
            return True
        return (self.eos_token is not None and self.output
                and self.output[-1] == self.eos_token)
