"""Inference request lifecycle (vLLM-style).

States: WAITING -> RUNNING -> FINISHED, plus
  REJECTED  — can never be served (prompt + generation budget exceeds the
              per-request cap). Surfaced by ``Engine.generate`` instead of
              silently returning an empty output. (The old "no prefill
              bucket for a non-chunkable family" rejection is gone: every
              family is served via chunked continuation prefill.)
  PREEMPTED — evicted mid-flight by the token-budget scheduler to relieve
              pool pressure (OutOfBlocks); its non-shared pages were freed
              and it waits at the FRONT of the queue. On re-admission the
              effective prompt is ``prompt + output`` (everything generated
              so far is re-prefilled — possibly straight from the prefix
              cache), so greedy decoding resumes token-for-token.
  CANCELLED — the client gave up (``AsyncEngine.cancel``): pool pages are
              released, the lane freed, and any still-in-flight sampled
              tokens are dropped at emission.

Latency anchors: ``submit_time`` is stamped when the CLIENT hands the
request over (Engine.generate / AsyncEngine.submit — the TTFT anchor, so
queue wait counts); ``enqueue_time`` when the scheduler queue receives it;
``admit_time`` at first lane admission (queue_wait = admit - submit);
``prefill_time`` at first-token emission.

Terminal status: every request ends with a ``FinishReason`` — the
STRUCTURED terminal status clients observe (``TokenStream.finish_reason``
after the stream closes, or ``Request.finish_reason`` from
``Engine.generate(return_requests=True)``). It is set exactly once, at the
moment the terminal event happens (``Request.finish``), never at an
idle-sweep. ``RequestState`` stays the engine-internal lifecycle;
``FinishReason`` is the client-facing WHY.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    REJECTED = "rejected"
    PREEMPTED = "preempted"
    CANCELLED = "cancelled"


class FinishReason(enum.Enum):
    """Why a request's stream terminated (set once, at the terminal event).

    FINISHED          — ran to completion (EOS or ``max_new_tokens``).
    REJECTED          — can never be served (prompt + generation budget over
                        the per-request cap); surfaced at admission time.
    CANCELLED         — the client gave up (``AsyncEngine.cancel``).
    TIMED_OUT         — ``deadline_s`` expired while the request was still
                        QUEUED; the scheduler shed it instead of serving
                        work nobody is waiting for.
    SHED              — fast-rejected at ``AsyncEngine.submit`` because the
                        queue was past its depth/token watermark (overload
                        degrades to bounded queueing, not unbounded
                        latency).
    PREEMPTION_LIMIT  — preempted more than ``max_preemptions`` times; the
                        pool is thrashing and this request will never make
                        progress, so it is rejected instead of livelocking.
    ERROR             — a pipeline fault (emit-worker death, step
                        exception, stall watchdog) terminated it; the
                        exception rides on ``Request.error`` / the stream.
    """
    FINISHED = "finished"
    REJECTED = "rejected"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    SHED = "shed"
    PREEMPTION_LIMIT = "preemption_limit"
    ERROR = "error"


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                       # (prompt_len,) int32 token ids
    max_new_tokens: int = 64
    eos_token: Optional[int] = None
    arrival_time: float = 0.0
    deadline_s: float = 0.0                  # client latency budget from
                                             # submission (0 = none); the
                                             # scheduler sheds QUEUED work
                                             # whose deadline passed
                                             # (TIMED_OUT)

    # runtime state
    state: RequestState = RequestState.WAITING
    lane: int = -1                           # engine batch lane
    output: List[int] = field(default_factory=list)
    num_computed: int = 0                    # prompt tokens with KV in cache
    prefill_target: int = 0                  # prompt tokens to compute (set
                                             # at admission; fixed until
                                             # preemption re-admits)
    num_preemptions: int = 0
    pool_id: int = -1                        # BlockManager key (engine-unique,
                                             # reassigned on re-admission)
    shard: int = -1                          # KV-pool shard the request is
                                             # pinned to (placement hint at
                                             # admission; all its pages stay
                                             # in that shard's page range)
    prefix_hash: int = 0                     # running chain hash after
    prefix_hash_pages: int = 0               # ..this many pages (engine's
                                             # incremental snapshot keying,
                                             # recurrent families)
    enqueue_time: float = -1.0               # perf_counter at add_request
    submit_time: float = -1.0                # perf_counter at client submit
                                             # (TTFT / queue-wait anchor;
                                             # falls back to enqueue_time)
    admit_time: float = -1.0                 # first lane admission
    prefill_time: float = -1.0               # first-token timestamp (kept
                                             # across preemptions)
    finish_time: float = -1.0
    inflight: int = 0                        # tokens sampled on device but
                                             # not yet host-emitted (async
                                             # pipeline; 0 in the sync loop)
    prefetch_keys: List[int] = field(default_factory=list)
                                             # chain hashes whose host->HBM
                                             # prefetch gates admission: the
                                             # request holds the queue head
                                             # while any is IN_FLIGHT
    prefetch_shard: int = -1                 # shard the prefetch landed the
                                             # prefix on (placement hint)
    prefetch_replans: int = 0                # landed pages stolen before
                                             # admission -> fetch re-planned
                                             # (bounded; then admit as miss)
    finish_reason: Optional[FinishReason] = None   # structured terminal
                                             # status, set ONCE via finish()
    error: Optional[BaseException] = None    # the fault behind ERROR

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def num_generated(self) -> int:
        return len(self.output)

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.num_generated

    def effective_prompt(self) -> np.ndarray:
        """What prefill must (re)compute: the prompt plus everything already
        generated — identical greedy continuation after preemption."""
        if not self.output:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.output, np.int32)])

    def done(self) -> bool:
        if self.num_generated >= self.max_new_tokens:
            return True
        return (self.eos_token is not None and self.output
                and self.output[-1] == self.eos_token)

    @property
    def deadline(self) -> Optional[float]:
        """Absolute ``perf_counter`` deadline, anchored at submission (else
        scheduler-queue arrival); None when the request carries none."""
        if self.deadline_s <= 0:
            return None
        t0 = self.submit_time if self.submit_time >= 0 else self.enqueue_time
        return t0 + self.deadline_s if t0 >= 0 else None

    @property
    def is_terminal(self) -> bool:
        return self.finish_reason is not None

    def finish(self, reason: FinishReason,
               error: Optional[BaseException] = None) -> bool:
        """Record the terminal status. First writer wins — a request that
        already terminated (e.g. cancelled while its rejection was in
        flight) keeps its original reason. Returns True if this call set
        it."""
        if self.finish_reason is not None:
            return False
        self.finish_reason = reason
        self.error = error
        return True
