"""Token-budget continuous-batching scheduler over ONE shared paged-KV pool,
sharded into per-mesh-shard page ranges — ONE step-composition path for every
model family.

The engine exposes ``num_lanes`` batch lanes, but — unlike the old
JetStream-style static partition — lanes do NOT own private page pools: all
lanes draw pages from a single refcounted ``BlockManager`` (prefix-cached,
LRU-evicted), so memory follows actual sequence lengths instead of reserving
``max_len`` per lane (the paper §2 allocator-fragmentation bottleneck).
The pool's page range is partitioned along the mesh ``(pod, data)`` axes
(``num_shards``); each request is pinned to one shard at admission so its
page gathers stay shard-local (Opt-Pa "lazy memory mapping" never crosses
the interconnect).

Each engine step is composed under a TOKEN BUDGET (Sarathi-style):

  * every running, prefill-complete request contributes one decode token;
  * the remaining budget is filled with prefill work — continuation chunks
    of partially-prefilled prompts first, then new admissions (possibly
    only the first chunk of a long prompt). EVERY family takes this path:
    the engine executes decode tokens and prefill chunks in ONE device call
    through the chunked-continuation prefill (a decode lane is a chunk of
    length 1). The legacy monolithic bucketed-prefill tier — and its
    "no bucket -> REJECT" admission rule — is gone.
  * recurrent-state families (griffin/rwkv6) get PAGE-ALIGNED chunk
    boundaries so the engine can snapshot the recurrent state at committed
    page boundaries (the prefix cache's resume points for those families);
  * admission is SHARD-AFFINE: a prompt whose chain-hash head is registered
    on shard s is placed on s (prefix-affinity — CoW reuse is only possible
    shard-locally); otherwise the least-loaded shard wins. If the preferred
    shard lacks capacity the request falls back to another shard and the
    lost reuse is counted as a ``placement_miss``.
  * prefix-cache hits shrink a new request's prefill to the uncached tail
    (full shared pages are reused copy-on-write, never recomputed);
  * ``OutOfBlocks`` is per-shard: the YOUNGEST running request ON THE
    PRESSURED SHARD is preempted — its non-shared pages freed, its
    registered pages parked in the prefix cache, and the request requeued
    at the front with ``effective_prompt = prompt + output`` so greedy
    decoding resumes token-for-token instead of the engine crashing;
  * requests that can NEVER be served (prompt + generation budget over the
    per-request cap — ``max_len`` or the largest shard's page range) are
    marked ``REJECTED`` and surfaced, not silently dropped.

Resilience rules (every terminal decision carries a ``FinishReason`` and
fires ``on_terminal`` at the moment it happens, so frontends can close the
client's stream immediately instead of at idle-sweep time):

  * **deadline shedding** — a QUEUED request whose ``deadline_s`` expired
    is shed (``TIMED_OUT``) at the top of every scheduling turn; the
    engine never spends a device step on work nobody is waiting for.
    Running requests are never killed mid-flight — the deadline is an
    admission contract, not an execution interrupt.
  * **bounded preemption** — a request preempted more than
    ``max_preemptions`` times is rejected (``PREEMPTION_LIMIT``) instead
    of ping-ponging through the pool forever: unbounded preemption under
    sustained pressure is a livelock, not a policy.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.cache.block_manager import (BlockManager, OutOfBlocks,
                                       PageResidency, PrefixMatch,
                                       padded_pool_pages)
from repro.configs.base import CacheConfig
from repro.serving.request import FinishReason, Request, RequestState


def bucket_len(n: int, buckets: List[int]) -> Optional[int]:
    """Smallest bucket holding ``n`` tokens — used to PAD the step's chunk
    axis (bounding recompilation), never to admit or reject."""
    for b in buckets:
        if n <= b:
            return b
    return None


@dataclass
class PrefillChunk:
    req: Request
    start: int                 # logical position of the chunk's first token
    tokens: np.ndarray         # (<= n,) TEXT token ids fed this step (vlm:
                               # positions inside the patch stub carry none)
    final: bool                # completes the prompt -> sample first token
    first: bool = False        # the request's first chunk since (re)admission
                               # (engine: reset/restore recurrent state, fill
                               # whisper cross-KV)
    count: int = -1            # logical POSITIONS covered by the chunk

    @property
    def n(self) -> int:
        return self.count if self.count >= 0 else int(len(self.tokens))


@dataclass
class DecodeItem:
    req: Request
    pos: int                   # logical position of the fed token
    slot: int                  # global flat slot receiving its KV


@dataclass
class StepPlan:
    prefill: List[PrefillChunk] = field(default_factory=list)
    decode: List[DecodeItem] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode


class Scheduler:
    def __init__(self, num_lanes: int, max_len: int, page_size: int,
                 prefill_buckets: List[int], extra_tokens: int = 0,
                 token_budget: Optional[int] = None,
                 enable_prefix_cache: bool = True,
                 num_shards: int = 1,
                 page_aligned: bool = False,
                 max_preemptions: int = 32,
                 cache_cfg: Optional[CacheConfig] = None):
        if cache_cfg is None:
            # deprecation shim: legacy loose knobs -> CacheConfig
            cache_cfg = CacheConfig(num_shards=num_shards,
                                    enable_prefix_cache=enable_prefix_cache)
        self.num_lanes = num_lanes
        self.max_len = max_len                 # per-REQUEST cap, not per-lane
        self.page_size = cache_cfg.page_size or page_size
        self.prefill_buckets = sorted(prefill_buckets)
        self.extra_tokens = extra_tokens       # modality-stub prefix (vlm)
        self.token_budget = token_budget or max(self.prefill_buckets)
        self.page_aligned = page_aligned       # recurrent-state families:
                                               # chunk ends land on page
                                               # boundaries (state snapshots)
        self.num_shards = max(int(cache_cfg.num_shards), 1)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}            # lane -> request
        self.free_lanes: List[int] = list(range(num_lanes - 1, -1, -1))
        self.pages_per_lane = \
            (max_len + self.page_size - 1) // self.page_size
        # ONE pool for all lanes, page range padded so it tiles evenly over
        # the shards; the final device page is reserved so its last line can
        # serve as the Pallas write kernel's SkipSet sentinel (it belongs to
        # the LAST shard's device range, which therefore owns one page less).
        self.cache_cfg = cache_cfg.resolve(
            page_size=self.page_size,
            num_pages=num_lanes * self.pages_per_lane)
        p_dev = padded_pool_pages(self.cache_cfg.num_pages, self.num_shards)
        total = max(p_dev - 1, 1)
        self.manager = BlockManager(
            cfg=self.cache_cfg.replace(num_pages=total))
        # ----------------------------------------------- prefetch hooks ----
        # engine-provided: prefetch_tick() runs at the top of every turn
        # (commits/aborts flights whose upload is now ordered ahead of any
        # future step); prefetcher(req, match) dispatches host->HBM uploads
        # for a queued request's matched non-DEVICE pages and returns the
        # chain hashes to gate admission on.
        self.prefetch_tick: Optional[Callable[[], None]] = None
        self.prefetcher: Optional[
            Callable[[Request, PrefixMatch], List[int]]] = None
        self.prefetch_depth = self.cache_cfg.prefetch_depth
        self.prefetches_planned = 0
        self.prefetch_held_turns = 0   # admission turns spent waiting on an
                                       # IN_FLIGHT prefix (overlapped with
                                       # the in-flight step, not idle)
        self.prefetch_replans = 0      # landed prefixes stolen pre-admission
                                       # and fetched again
        self.max_prefetch_replans = 3  # per request; then admit as a miss
        self.preemptions = 0
        self.preemptions_by_shard = [0] * self.num_shards
        self.placement_prefix_hits = 0   # admitted on the prefix-affine shard
        self.placement_misses = 0        # prefix lived on a shard we could
                                         # not use -> cross-shard reuse lost
        self.rejected: List[Request] = []
        self.max_preemptions = max(int(max_preemptions), 0)
        self.deadline_shed = 0           # queued requests shed TIMED_OUT
        self.preemption_limit_rejects = 0
        # fired the MOMENT a request terminates without ever reaching the
        # step path (REJECTED / TIMED_OUT / PREEMPTION_LIMIT), so the async
        # frontend can close the client's stream immediately — a client
        # blocked on stream.get() must not wait for the pipeline to idle
        self.on_terminal: Optional[Callable[[Request], None]] = None
        self._next_pool_id = 0             # engine-unique allocator keys
                                           # (req_ids may collide across
                                           # streams; the pool must not)

    # -------------------------------------------------------------- admit --
    def add_request(self, req: Request) -> None:
        self.waiting.append(req)

    def _target(self, req: Request) -> int:
        """Prompt-side tokens that must be in the cache before decoding
        (frozen at admission — generated tokens arrive via decode slots,
        not prefill chunks)."""
        return req.prefill_target

    def _reject(self, req: Request,
                reason: FinishReason = FinishReason.REJECTED) -> None:
        req.state = RequestState.REJECTED
        req.finish(reason)
        self.rejected.append(req)
        if self.on_terminal is not None:
            self.on_terminal(req)

    def _shed_expired(self) -> None:
        """Shed QUEUED requests whose deadline has passed (TIMED_OUT).
        Safe with in-flight sampled tokens (async pipeline): the emission
        path drops tokens for terminal requests, and a preempted request's
        pages were already freed at preemption."""
        if not any(r.deadline is not None for r in self.waiting):
            return
        now = time.perf_counter()
        kept: Deque[Request] = deque()
        while self.waiting:
            r = self.waiting.popleft()
            dl = r.deadline
            if dl is not None and now >= dl:
                self._reject(r, FinishReason.TIMED_OUT)
                self.deadline_shed += 1
            else:
                kept.append(r)
        self.waiting = kept

    def _chunk_len(self, lo: int, remaining: int, budget: int) -> int:
        """Length of the next chunk of a prompt starting at logical position
        ``lo`` with ``remaining`` tokens to go. Page-aligned mode trims the
        chunk to end on the last page boundary it can reach, so the engine
        can snapshot recurrent state under the committed prefix chain hash
        (the final sub-page tail becomes its own chunk)."""
        n = min(remaining, budget, max(self.prefill_buckets))
        if self.page_aligned:
            aligned = ((lo + n) // self.page_size) * self.page_size - lo
            if 0 < aligned < n:
                return aligned
        return n

    def _youngest_running(self, exclude: Optional[Request] = None,
                          shard: Optional[int] = None):
        cands = [r for r in self.running.values() if r is not exclude
                 and (shard is None or r.shard == shard)]
        if not cands:
            return None
        return max(cands, key=lambda r: (r.arrival_time, r.req_id))

    def preempt(self, req: Request) -> None:
        """Evict a running request: free its references (shared pages stay
        alive under their other owners / the prefix cache) and requeue it at
        the FRONT with everything-so-far as its new prompt. A request past
        ``max_preemptions`` is rejected (PREEMPTION_LIMIT) instead of
        requeued — under sustained pressure the preempt/re-admit cycle is a
        livelock, and a bounded reject lets the client retry elsewhere."""
        self.manager.free(req.pool_id)
        del self.running[req.lane]
        self.free_lanes.append(req.lane)
        req.lane = -1
        req.num_computed = 0
        req.num_preemptions += 1
        self.preemptions += 1
        if 0 <= req.shard < self.num_shards:
            self.preemptions_by_shard[req.shard] += 1
        req.shard = -1                    # re-placed at re-admission
        if req.num_preemptions > self.max_preemptions:
            self.preemption_limit_rejects += 1
            self._reject(req, FinishReason.PREEMPTION_LIMIT)
            return
        req.state = RequestState.PREEMPTED
        self.waiting.appendleft(req)

    def _append_with_preemption(self, req: Request) -> Optional[int]:
        """Grow ``req`` by one decode slot, preempting the youngest running
        request ON THE PRESSURED SHARD on exhaustion. Returns None if
        ``req`` itself was the youngest there and had to be preempted."""
        while True:
            try:
                return self.manager.append_token(req.pool_id)
            except OutOfBlocks as e:
                victim = self._youngest_running(exclude=req, shard=e.shard)
                if victim is None or _younger(req, victim):
                    self.preempt(req)
                    return None
                self.preempt(victim)

    def _plan_prefetch(self) -> None:
        """Scan the first ``prefetch_depth`` queued requests for prefixes
        that are matched but not device-resident (HOST) and hand them to
        the engine's prefetcher, which dispatches the host->HBM staging
        uploads asynchronously — overlapped with the step currently in
        flight. ``match_prefix`` is read-only, so planning never skews the
        allocate-time hit accounting."""
        if (self.prefetcher is None or self.prefetch_depth <= 0
                or not self.manager.host_tier_enabled):
            return
        mgr = self.manager
        scanned = 0
        for r in list(self.waiting):
            if scanned >= self.prefetch_depth:
                break
            if r.prefetch_keys or r.inflight > 0 or r.is_terminal:
                continue
            scanned += 1
            eff = r.effective_prompt()
            m = mgr.match_prefix(eff, len(eff) + self.extra_tokens)
            if not m.fetchable:
                continue
            keys = self.prefetcher(r, m)
            if keys:
                r.prefetch_keys = list(keys)
                r.prefetch_shard = m.shard
                self.prefetches_planned += 1

    def _place(self, pool_id: int, total: int,
               token_ids, pref_hint: Optional[int] = None) -> Optional[int]:
        """Shard-affine admission: try the prefix-affine shard first, then
        every other shard in least-loaded order. Returns the pages' shard or
        None when no shard can hold the request right now (admission never
        preempts running work). Updates placement stats. ``pref_hint``
        (the shard a just-landed prefetch restored the prefix to)
        overrides the chain-hash-head lookup."""
        mgr = self.manager
        pref = pref_hint if pref_hint is not None \
            else mgr.preferred_shard(token_ids, total)
        order = sorted(range(self.num_shards), key=mgr.load_key)
        if pref is not None:
            order.remove(pref)
            order.insert(0, pref)
        for shard in order:
            try:
                mgr.allocate(pool_id, total, token_ids=token_ids,
                             shard=shard)
            except OutOfBlocks:
                continue
            if pref is not None:
                if shard == pref:
                    self.placement_prefix_hits += 1
                else:
                    self.placement_misses += 1
            return shard
        return None

    # --------------------------------------------------------------- plan --
    def schedule_step(self) -> StepPlan:
        """Compose one engine step under the token budget."""
        self._shed_expired()
        if self.prefetch_tick is not None:
            self.prefetch_tick()       # land flights dispatched last turn
        self._plan_prefetch()          # start fetches for queued prefixes
        plan = StepPlan()
        budget = self.token_budget
        mgr = self.manager

        # 1) decode: every prefill-complete running request, oldest first
        #    (so OutOfBlocks preemption always hits a not-yet-planned,
        #    younger victim).
        decode_reqs = sorted(
            (r for r in self.running.values()
             if r.num_computed >= self._target(r)),
            key=lambda r: (r.arrival_time, r.req_id))
        for r in decode_reqs:
            if budget <= 0:
                break
            if r.state is not RequestState.RUNNING:
                continue                               # preempted this step
            if r.num_generated + r.inflight >= r.max_new_tokens:
                continue   # async pipeline: every remaining output token is
                           # already sampled on device (never binds when the
                           # sync loop drains emissions each step)
            slot = self._append_with_preemption(r)
            if slot is None:
                continue
            plan.decode.append(
                DecodeItem(r, pos=mgr.num_tokens(r.pool_id) - 1, slot=slot))
            budget -= 1

        # 2) continuation chunks of partially-prefilled prompts
        for r in sorted(self.running.values(),
                        key=lambda r: (r.arrival_time, r.req_id)):
            tgt = self._target(r)
            if r.num_computed >= tgt or budget <= 0:
                continue
            lo = r.num_computed
            n = self._chunk_len(lo, tgt - lo, budget)
            eff = r.effective_prompt()
            plan.prefill.append(PrefillChunk(
                r, start=lo,
                tokens=eff[max(lo - self.extra_tokens, 0):
                           max(lo - self.extra_tokens + n, 0)],
                final=(lo + n >= tgt), count=n))
            budget -= n

        # 3) admissions (shard-affine placement, chunked for every family)
        while self.waiting and self.free_lanes and budget > 0:
            r = self.waiting[0]
            if r.inflight > 0:
                # async pipeline: a preempted request with sampled-but-not-
                # emitted tokens has an incomplete effective_prompt — hold
                # the queue (it sits at the FRONT) until they drain
                break
            if r.prefetch_keys:
                res = [mgr.residency(h) for h in r.prefetch_keys]
                if any(x is PageResidency.IN_FLIGHT for x in res):
                    # its prefix is mid-upload: hold admission (~1 turn,
                    # overlapped with the in-flight step) so allocate sees
                    # the restored pages as plain device hits
                    self.prefetch_held_turns += 1
                    break
                r.prefetch_keys = []   # landed / aborted — admit normally
                if (any(x is PageResidency.HOST for x in res)
                        and r.prefetch_replans < self.max_prefetch_replans):
                    # a landed page was stolen back to the host tier by
                    # allocation pressure before this request admitted:
                    # forfeit nothing — hold one turn and re-plan the
                    # fetch (keys are clear, so the next turn's
                    # ``_plan_prefetch`` picks it up again). Bounded so a
                    # thrashing pool degrades to recompute, never livelock.
                    r.prefetch_replans += 1
                    self.prefetch_replans += 1
                    break
            eff = r.effective_prompt()
            total = len(eff) + self.extra_tokens
            # a request is pinned to ONE shard, so the largest shard's page
            # range bounds what is ever servable
            cap = min(self.max_len,
                      mgr.max_shard_capacity() * self.page_size)
            if total + (r.max_new_tokens - r.num_generated) > cap:
                self.waiting.popleft()
                self._reject(r)
                continue
            pool_id = self._next_pool_id
            # NOTE(vlm/whisper): the prefix key covers TEXT tokens only —
            # sound while the modality frontends are zero stubs (every
            # request's patch embeddings / audio frames are identical, so
            # the cached patch K/V and frame-conditioned decoder self-KV
            # are too). Real image/audio inputs must fold a modality-content
            # digest into the chain-hash seed, as the recurrent families'
            # prefix_gate does for state (see ROADMAP).
            shard = self._place(
                pool_id, total, eff,
                pref_hint=r.prefetch_shard if r.prefetch_shard >= 0
                else None)
            if shard is None:
                break              # admission never preempts running work
            cached = mgr.cached_tokens(pool_id)
            self._next_pool_id += 1
            r.pool_id = pool_id
            r.shard = shard
            r.prefetch_shard = -1
            if r.admit_time < 0:
                r.admit_time = time.perf_counter()   # queue-wait anchor
            self.waiting.popleft()
            lane = self.free_lanes.pop()
            r.lane = lane
            r.state = RequestState.RUNNING
            r.num_computed = cached
            r.prefill_target = total
            self.running[lane] = r
            n = self._chunk_len(cached, total - cached, budget)
            lo = cached
            plan.prefill.append(PrefillChunk(
                r, start=lo,
                tokens=eff[max(lo - self.extra_tokens, 0):
                           max(lo - self.extra_tokens + n, 0)],
                final=(cached + n >= total),
                first=True, count=n))
            budget -= n
        return plan

    # ---------------------------------------------------------- execution --
    def note_prefilled(self, req: Request, n: int) -> None:
        """Engine callback after a chunk's KV landed on device: advance the
        request and register now-complete full pages for prefix reuse."""
        req.num_computed += n
        self.manager.commit_prefill(req.pool_id, req.num_computed,
                                    token_ids=req.effective_prompt())

    def finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish(FinishReason.FINISHED)
        self.manager.free(req.pool_id)
        del self.running[req.lane]
        self.free_lanes.append(req.lane)
        req.lane = -1

    def release(self, req: Request,
                reason: FinishReason = FinishReason.CANCELLED) -> None:
        """Cancel/abort support: drop ``req`` wherever it currently lives —
        free its pool pages and lane if running, or unlink it from the
        waiting queue. Safe with in-flight sampled tokens: the async
        pipeline drops them at emission (terminal state), and device-order
        execution keeps already-dispatched steps ahead of any page reuse."""
        if req.state is RequestState.RUNNING:
            self.manager.free(req.pool_id)
            del self.running[req.lane]
            self.free_lanes.append(req.lane)
            req.lane = -1
        elif req in self.waiting:
            self.waiting.remove(req)
        req.state = RequestState.CANCELLED
        req.finish(reason)

    def abort_all(self, reason: FinishReason,
                  error: Optional[BaseException] = None) -> List[Request]:
        """Fault drain: release EVERY live request (running and queued) so
        the pool holds zero pages, marking each with ``reason``. Returns
        the drained requests so the caller can close their streams."""
        drained = list(self.running.values()) + list(self.waiting)
        for req in drained:
            req.finish(reason, error)
            self.release(req, reason)
        return drained

    # ------------------------------------------------------------ queries --
    def active_lanes(self) -> List[int]:
        return sorted(self.running)

    def page_table(self, req: Request) -> np.ndarray:
        return self.manager.page_table(req.pool_id, self.pages_per_lane)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)


def _younger(a: Request, b: Request) -> bool:
    return (a.arrival_time, a.req_id) > (b.arrival_time, b.req_id)


# ----------------------------------------------- concat-prefill packing ----
@dataclass
class PackedRow:
    """One engine-step row holding SEVERAL requests' prefill chunks as
    segments — the concat-prefill layout the segment-aware chunk kernels
    execute (per-row segment ids keep attention from leaking across
    prompts)."""
    chunks: List[PrefillChunk] = field(default_factory=list)
    tokens: int = 0                # occupied query columns
    pages: int = 0                 # page-table slots used
    finals: int = 0                # chunks sampling a first token
    shard: int = -1                # all chunks share one KV shard


def chunk_pages(c: PrefillChunk, page_size: int) -> int:
    """Page-table slots chunk ``c`` needs: its request's WHOLE cached
    history through the end of the chunk (the chunk attends everything)."""
    return -(-(c.start + c.n) // page_size)


def pack_rows(chunks: List[PrefillChunk], width: int, pack_slots: int,
              pages_per_lane: int, page_size: int) -> List[PackedRow]:
    """First-fit-decreasing packing of prefill chunks into rows of
    ``width`` query columns. A chunk is NEVER split: it lands whole in one
    row (and a request's pages live on one shard, so neither crosses
    shards). Row constraints: total tokens <= width, page-table slots <=
    ``pages_per_lane`` (the step's page-table width), sampled chunks
    (final=True) <= ``pack_slots`` (the packed step's per-row logits
    slots), and one KV shard per row."""
    rows: List[PackedRow] = []
    for c in sorted(chunks, key=lambda c: -c.n):
        np_c = chunk_pages(c, page_size)
        shard = c.req.shard
        for row in rows:
            if (row.tokens + c.n <= width
                    and row.pages + np_c <= pages_per_lane
                    and row.finals + int(c.final) <= pack_slots
                    and row.shard == shard):
                break
        else:
            row = PackedRow(shard=shard)
            rows.append(row)
        row.chunks.append(c)
        row.tokens += c.n
        row.pages += np_c
        row.finals += int(c.final)
    return rows
