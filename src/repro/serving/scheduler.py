"""Continuous-batching scheduler (vLLM-style, lane-based).

The engine exposes ``num_lanes`` batch lanes, each backed by a private paged
pool of ``max_len`` tokens (JetStream-style static allocation — XLA-friendly;
DESIGN.md §3 "allocator mismatch" adaptation). The scheduler:

  * admits WAITING requests into free lanes when their prompt + generation
    budget fits the lane's page pool,
  * groups the admissions of one step into a single bucketed prefill,
  * evicts FINISHED requests and recycles lanes,
  * tracks per-lane BlockManagers so slot indices (and the Opt-KV SkipSet for
    padding) are exactly the paper's Eq. 5 write-filter inputs.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.cache.block_manager import BlockManager
from repro.serving.request import Request, RequestState


def bucket_len(n: int, buckets: List[int]) -> Optional[int]:
    for b in buckets:
        if n <= b:
            return b
    return None


class Scheduler:
    def __init__(self, num_lanes: int, max_len: int, page_size: int,
                 prefill_buckets: List[int], extra_tokens: int = 0,
                 allow_chunked: bool = False):
        self.num_lanes = num_lanes
        self.max_len = max_len
        self.page_size = page_size
        self.prefill_buckets = sorted(prefill_buckets)
        self.extra_tokens = extra_tokens     # modality-stub prefix (vlm)
        # prompts longer than the largest bucket are admitted and prefilled
        # chunk-by-chunk (Sarathi-style) when the model family supports it
        self.allow_chunked = allow_chunked
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}        # lane -> request
        self.free_lanes: List[int] = list(range(num_lanes - 1, -1, -1))
        pages = (max_len + page_size - 1) // page_size
        self.managers = [BlockManager(pages, page_size)
                         for _ in range(num_lanes)]

    # -------------------------------------------------------------- admit --
    def add_request(self, req: Request) -> None:
        self.waiting.append(req)

    def schedule_prefills(self) -> List[Request]:
        """Pop admissible requests into free lanes (one scheduling step)."""
        admitted = []
        while self.waiting and self.free_lanes:
            req = self.waiting[0]
            if req.prompt_len + self.extra_tokens + req.max_new_tokens \
                    > self.max_len:
                # request can never fit: reject (truncate policy lives here)
                self.waiting.popleft()
                req.state = RequestState.FINISHED
                continue
            if bucket_len(req.prompt_len, self.prefill_buckets) is None \
                    and not self.allow_chunked:
                self.waiting.popleft()
                req.state = RequestState.FINISHED
                continue
            lane = self.free_lanes.pop()
            self.waiting.popleft()
            req.lane = lane
            req.state = RequestState.RUNNING
            mgr = self.managers[lane]
            mgr.allocate(seq_id=req.req_id,
                         num_tokens=req.prompt_len + self.extra_tokens)
            self.running[lane] = req
            admitted.append(req)
        return admitted

    # -------------------------------------------------------------- decode --
    def active_lanes(self) -> List[int]:
        return sorted(self.running)

    def decode_slots(self) -> np.ndarray:
        """Per-lane flat slot for the next generated token (-1 = idle lane)."""
        slots = np.full(self.num_lanes, -1, np.int32)
        for lane, req in self.running.items():
            slots[lane] = self.managers[lane].append_token(req.req_id)
        return slots

    def finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        self.managers[req.lane].free(req.req_id)
        del self.running[req.lane]
        self.free_lanes.append(req.lane)
        req.lane = -1

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
