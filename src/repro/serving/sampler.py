"""Token sampler: greedy / temperature / top-k / top-p, pure JAX."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => no top-k filter
    top_p: float = 1.0                # 1 => no nucleus filter

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def top_k_mask(lf, top_k: int):
    """Top-k keep-mask (B, V): EXACTLY the ``top_k`` highest-ranked tokens.

    Ties are broken by sorted RANK, mirroring ``top_p_mask`` — masking on
    ``lf < kth`` would keep every token tied with the k-th logit and
    inflate the candidate set beyond k (common after low-precision logits
    quantize the tail to a few distinct values).
    """
    order = jnp.argsort(-lf, axis=-1)                # descending, stable
    rank = jnp.argsort(order, axis=-1)               # token -> sorted rank
    return rank < top_k


def top_p_mask(lf, top_p: float):
    """Nucleus keep-mask (B, V): the SMALLEST set of tokens whose
    probability mass reaches ``top_p``.

    Ties are broken by sorted RANK, not by logit value — masking on
    ``lf < cutoff`` would keep every token tied with the cutoff logit and
    inflate the nucleus beyond ``top_p`` (ties are common after top-k
    masking quantizes the tail to -inf, and in low-precision logits).
    """
    order = jnp.argsort(-lf, axis=-1)                # descending, stable
    sorted_lf = jnp.take_along_axis(lf, order, axis=-1)
    cum = jnp.cumsum(jax.nn.softmax(sorted_lf, axis=-1), axis=-1)
    # smallest prefix with cumulative mass >= top_p (keep first exceeding)
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    keep_sorted = jnp.arange(lf.shape[-1])[None, :] <= cutoff_idx
    rank = jnp.argsort(order, axis=-1)               # token -> sorted rank
    return jnp.take_along_axis(keep_sorted, rank, axis=-1)


@partial(jax.jit, static_argnames=("temperature", "top_k", "top_p"))
def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0):
    """logits (B, V) -> tokens (B,) int32."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        lf = jnp.where(top_k_mask(lf, top_k), lf, -jnp.inf)
    if top_p < 1.0:
        lf = jnp.where(top_p_mask(lf, top_p), lf, -jnp.inf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
