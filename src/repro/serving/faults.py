"""Deterministic fault injection for the serving stack.

A seeded ``FaultPlan`` names WHERE and WHEN faults fire; ``FaultInjector``
installs the plan onto a live ``Engine`` (``engine.faults``) and the
serving code calls back into it at three hook points:

  * ``append_token`` (wrapped at install time) — raise ``OutOfBlocks`` on
    a chosen call index, for a chosen run length: a pool-pressure STORM
    that drives the scheduler's preemption/requeue machinery without
    needing a genuinely full pool;
  * ``before_execute`` (sync ``Engine._execute`` and async
    ``Engine._dispatch_async``) — raise ``FaultInjected`` at a chosen
    step: the dispatched-step fault the frontend must drain as ERROR;
  * ``on_emit`` (``AsyncEngine._emit_worker``) — delay every host sync,
    or raise ``WorkerKilled`` at a chosen emission so the worker dies
    SILENTLY and only the stall watchdog can notice;
  * ``on_turn`` (top of ``AsyncEngine._loop_once``) — seeded cancel
    storms: at chosen turns, cancel a deterministic fraction of the open
    streams;
  * ``on_spill`` (``Engine._spill_page``) — drop chosen device->host
    spills on the floor (the evicted prefix page dies DROPPED instead of
    landing HOST, modelling a failed / raced spill copy);
  * ``on_prefetch`` (``Engine._start_prefetch``) — fail chosen host->HBM
    prefetches (the flight aborts at landing, payload returned to the
    host store) or stretch their landing by extra scheduler turns
    (slow-link prefetch: the gated request is held longer).

Everything is keyed to deterministic counters (append calls, dispatched
steps, emissions, loop turns) and a seeded RNG — the same plan against the
same workload replays the same episode, so the chaos suite can assert
exact terminal statuses and bit-identical survivor outputs."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.cache.block_manager import OutOfBlocks
from repro.serving.frontend import WorkerKilled


class FaultInjected(RuntimeError):
    """The step fault ``FaultPlan.raise_at_step`` injects."""


@dataclass(frozen=True)
class FaultPlan:
    """One chaos episode's fault schedule (all counters 1-based; None or
    () disables a fault)."""
    seed: int = 0
    oob_at_append: Optional[int] = None   # Nth append_token call raises
    oob_count: int = 1                    # ..and this many in a row
    raise_at_step: Optional[int] = None   # Nth dispatched step raises
                                          # FaultInjected before execution
    emit_delay_s: float = 0.0             # slow every emit-worker host sync
    kill_emit_at: Optional[int] = None    # Nth emission kills the worker
                                          # silently (WorkerKilled)
    cancel_at_turns: Tuple[int, ...] = () # loop turns firing a cancel storm
    cancel_frac: float = 0.5              # fraction of open streams per storm
    # ------------------------------------------------ host-DRAM KV tier --
    spill_drop_at: Optional[int] = None   # Nth spill is dropped (page dies
                                          # DROPPED instead of landing HOST)
    spill_drop_count: int = 1             # ..and this many in a row
    prefetch_fail_at: Optional[int] = None  # Nth prefetch aborts at landing
    prefetch_fail_count: int = 1            # ..and this many in a row
    prefetch_delay_turns: int = 0         # extra scheduler turns every
                                          # prefetch takes to land (slow
                                          # host link)


class FaultInjector:
    """Live counters + hook callbacks for one ``FaultPlan`` episode."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.appends = 0        # append_token calls seen
        self.steps = 0          # device steps dispatched
        self.emissions = 0      # emit-worker items processed
        self.turns = 0          # frontend loop turns
        self.injected_oob = 0
        self.injected_cancels = 0
        self.spills = 0         # spill attempts seen
        self.prefetches = 0     # prefetch uploads started
        self.injected_spill_drops = 0
        self.injected_prefetch_fails = 0

    # ---------------------------------------------------------- install --
    def install(self, engine) -> "FaultInjector":
        """Attach to ``engine``: set ``engine.faults`` and wrap the block
        manager's ``append_token`` for pool-pressure injection."""
        engine.faults = self
        mgr = engine.scheduler.manager
        orig = mgr.append_token
        plan = self.plan

        def wrapped(seq_id: int) -> int:
            self.appends += 1
            if (plan.oob_at_append is not None
                    and plan.oob_at_append <= self.appends
                    < plan.oob_at_append + plan.oob_count):
                self.injected_oob += 1
                raise OutOfBlocks(
                    f"injected OutOfBlocks (append #{self.appends})",
                    shard=mgr.seq_shard(seq_id))
            return orig(seq_id)

        mgr.append_token = wrapped
        return self

    # ------------------------------------------------------------ hooks --
    def before_execute(self, sb) -> None:
        """Engine hook, both dispatch paths: one call per device step."""
        self.steps += 1
        if self.plan.raise_at_step == self.steps:
            raise FaultInjected(
                f"injected step fault at dispatched step {self.steps} "
                f"(kind {sb.kind})")

    def on_emit(self) -> None:
        """Emit-worker hook: one call per drained step, BEFORE the host
        sync."""
        self.emissions += 1
        if self.plan.emit_delay_s > 0:
            time.sleep(self.plan.emit_delay_s)
        if (self.plan.kill_emit_at is not None
                and self.emissions >= self.plan.kill_emit_at):
            raise WorkerKilled()

    def on_spill(self) -> bool:
        """Engine spill-sink hook: one call per device->host spill attempt.
        Returns False to drop the spill (the evicted page is destroyed —
        DROPPED — exactly what a failed copy looks like to the allocator)."""
        self.spills += 1
        p = self.plan
        if (p.spill_drop_at is not None
                and p.spill_drop_at <= self.spills
                < p.spill_drop_at + p.spill_drop_count):
            self.injected_spill_drops += 1
            return False
        return True

    def on_prefetch(self) -> Tuple[bool, int]:
        """Engine prefetch hook: one call per host->HBM upload started.
        Returns (ok, extra_delay_turns) — ``ok=False`` makes the flight
        abort at landing (staging page freed, payload back on the host
        store); the delay stretches the landing turn (slow host link)."""
        self.prefetches += 1
        p = self.plan
        ok = True
        if (p.prefetch_fail_at is not None
                and p.prefetch_fail_at <= self.prefetches
                < p.prefetch_fail_at + p.prefetch_fail_count):
            self.injected_prefetch_fails += 1
            ok = False
        return ok, p.prefetch_delay_turns

    def on_turn(self, frontend) -> None:
        """Frontend hook, top of every loop turn: seeded cancel storms."""
        self.turns += 1
        if self.turns not in self.plan.cancel_at_turns:
            return
        open_streams = sorted(frontend._streams.items())
        n = int(round(len(open_streams) * self.plan.cancel_frac))
        if not n:
            return
        picks = self.rng.choice(len(open_streams), size=n, replace=False)
        for i in sorted(int(j) for j in picks):
            frontend.cancel(open_streams[i][1])
            self.injected_cancels += 1
