"""Deterministic fault injection for the serving stack.

A seeded ``FaultPlan`` names WHERE and WHEN faults fire; ``FaultInjector``
installs the plan onto a live ``Engine`` (``engine.faults``) and the
serving code calls back into it at three hook points:

  * ``append_token`` (wrapped at install time) — raise ``OutOfBlocks`` on
    a chosen call index, for a chosen run length: a pool-pressure STORM
    that drives the scheduler's preemption/requeue machinery without
    needing a genuinely full pool;
  * ``before_execute`` (sync ``Engine._execute`` and async
    ``Engine._dispatch_async``) — raise ``FaultInjected`` at a chosen
    step: the dispatched-step fault the frontend must drain as ERROR;
  * ``on_emit`` (``AsyncEngine._emit_worker``) — delay every host sync,
    or raise ``WorkerKilled`` at a chosen emission so the worker dies
    SILENTLY and only the stall watchdog can notice;
  * ``on_turn`` (top of ``AsyncEngine._loop_once``) — seeded cancel
    storms: at chosen turns, cancel a deterministic fraction of the open
    streams.

Everything is keyed to deterministic counters (append calls, dispatched
steps, emissions, loop turns) and a seeded RNG — the same plan against the
same workload replays the same episode, so the chaos suite can assert
exact terminal statuses and bit-identical survivor outputs."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.cache.block_manager import OutOfBlocks
from repro.serving.frontend import WorkerKilled


class FaultInjected(RuntimeError):
    """The step fault ``FaultPlan.raise_at_step`` injects."""


@dataclass(frozen=True)
class FaultPlan:
    """One chaos episode's fault schedule (all counters 1-based; None or
    () disables a fault)."""
    seed: int = 0
    oob_at_append: Optional[int] = None   # Nth append_token call raises
    oob_count: int = 1                    # ..and this many in a row
    raise_at_step: Optional[int] = None   # Nth dispatched step raises
                                          # FaultInjected before execution
    emit_delay_s: float = 0.0             # slow every emit-worker host sync
    kill_emit_at: Optional[int] = None    # Nth emission kills the worker
                                          # silently (WorkerKilled)
    cancel_at_turns: Tuple[int, ...] = () # loop turns firing a cancel storm
    cancel_frac: float = 0.5              # fraction of open streams per storm


class FaultInjector:
    """Live counters + hook callbacks for one ``FaultPlan`` episode."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.appends = 0        # append_token calls seen
        self.steps = 0          # device steps dispatched
        self.emissions = 0      # emit-worker items processed
        self.turns = 0          # frontend loop turns
        self.injected_oob = 0
        self.injected_cancels = 0

    # ---------------------------------------------------------- install --
    def install(self, engine) -> "FaultInjector":
        """Attach to ``engine``: set ``engine.faults`` and wrap the block
        manager's ``append_token`` for pool-pressure injection."""
        engine.faults = self
        mgr = engine.scheduler.manager
        orig = mgr.append_token
        plan = self.plan

        def wrapped(seq_id: int) -> int:
            self.appends += 1
            if (plan.oob_at_append is not None
                    and plan.oob_at_append <= self.appends
                    < plan.oob_at_append + plan.oob_count):
                self.injected_oob += 1
                raise OutOfBlocks(
                    f"injected OutOfBlocks (append #{self.appends})",
                    shard=mgr.seq_shard(seq_id))
            return orig(seq_id)

        mgr.append_token = wrapped
        return self

    # ------------------------------------------------------------ hooks --
    def before_execute(self, sb) -> None:
        """Engine hook, both dispatch paths: one call per device step."""
        self.steps += 1
        if self.plan.raise_at_step == self.steps:
            raise FaultInjected(
                f"injected step fault at dispatched step {self.steps} "
                f"(kind {sb.kind})")

    def on_emit(self) -> None:
        """Emit-worker hook: one call per drained step, BEFORE the host
        sync."""
        self.emissions += 1
        if self.plan.emit_delay_s > 0:
            time.sleep(self.plan.emit_delay_s)
        if (self.plan.kill_emit_at is not None
                and self.emissions >= self.plan.kill_emit_at):
            raise WorkerKilled()

    def on_turn(self, frontend) -> None:
        """Frontend hook, top of every loop turn: seeded cancel storms."""
        self.turns += 1
        if self.turns not in self.plan.cancel_at_turns:
            return
        open_streams = sorted(frontend._streams.items())
        n = int(round(len(open_streams) * self.plan.cancel_frac))
        if not n:
            return
        picks = self.rng.choice(len(open_streams), size=n, replace=False)
        for i in sorted(int(j) for j in picks):
            frontend.cancel(open_streams[i][1])
            self.injected_cancels += 1
