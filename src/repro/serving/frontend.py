"""Async continuous-batching frontend: an overlapped host/device pipeline
over the synchronous ``Engine``.

The sync loop serializes HOST plan-building, DEVICE execution, and HOST
token emission: every step blocks on ``np.asarray(logits)`` before the next
plan can be built, so the host and device take turns idling. This frontend
exploits JAX's async dispatch to overlap them:

  * ``AsyncEngine.submit(prompt, ...)`` registers a request and returns a
    ``TokenStream``; ``stream()`` (or iterating it) yields generated token
    ids as they arrive. ``cancel(handle)`` releases the request's pool
    pages and lane as soon as its in-flight device tokens drain, dropping
    any still-pipelined samples at emission.
  * The LOOP (driving thread) builds the plan for step N+1 and dispatches
    it while step N still executes on device (pipeline depth
    ``PIPELINE_DEPTH`` = 2). It never blocks on device results: sampling
    happens ON DEVICE inside the step (``Engine._async_step_impl``) and
    each decode lane's input token is read from the device-resident
    ``lane_tok`` feed, so plan construction needs only host metadata
    (the scheduler's pool state advances at DISPATCH time, not emission
    time — ``Request.inflight`` tracks the gap).
  * The EMIT worker (background thread) owns the only host sync: it drains
    the dispatch queue in device order, blocks on ``np.asarray(tokens)``,
    and hands the host tokens back to the loop, which routes them to the
    per-request stream queues ("detokenize/emit off the critical path").
  * ``warmup()`` AOT-compiles (``jax.jit(...).lower().compile()``) the
    async step executable for EVERY shape in the bucket lattice — prefill
    buckets x packed row buckets x decode — so steady-state serving never
    traces: ``engine.aot_misses`` stays 0 and ``engine.trace_counts`` is
    frozen after warmup.

Greedy outputs are bit-identical to ``Engine.generate``: the device
consumes its own sampled tokens in dispatch order, and the paged-pool step
math is schedule-independent, so overlapping only changes WHEN tokens reach
the host, never their values. The pipeline may overrun EOS by at most
``PIPELINE_DEPTH - 1`` steps; overrun tokens are dropped at emission.

Single-process, two threads: the loop thread owns ALL scheduler/request/
cache mutation; the emit worker only converts device arrays to host and
never touches shared state. Used by ``launch.serve --async`` and
``benchmarks.bench_serving``.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine, StepBatch
from repro.serving.request import Request, RequestState

PIPELINE_DEPTH = 2          # dispatched-but-not-emitted device steps
_END = object()             # TokenStream sentinel


@dataclass
class TokenStream:
    """Per-request output channel. ``get()`` blocks for the next token id
    (None = stream closed); iteration yields tokens until completion."""
    req: Request
    _q: "queue.Queue[object]" = field(default_factory=queue.Queue)

    def put(self, tok: int) -> None:
        self._q.put(tok)

    def close(self) -> None:
        self._q.put(_END)

    def get(self, timeout: Optional[float] = None) -> Optional[int]:
        tok = self._q.get(timeout=timeout)
        return None if tok is _END else tok      # type: ignore[return-value]

    def __iter__(self):
        while True:
            tok = self.get()
            if tok is None:
                return
            yield tok


class AsyncEngine:
    """Continuous-batching request frontend over a synchronous ``Engine``.

    ``submit()`` / ``stream()`` / ``cancel()`` may be called from any
    thread; the serving loop runs on the caller of ``run_until_idle`` (or
    the ``serve_forever`` thread)."""

    def __init__(self, engine: Engine, pipeline_depth: int = PIPELINE_DEPTH,
                 warmup: bool = True):
        self.engine = engine
        self.depth = max(1, int(pipeline_depth))
        self._submit_q: "queue.Queue[Tuple[Request, TokenStream]]" = \
            queue.Queue()
        self._emit_q: "queue.Queue[Optional[Tuple[StepBatch, object]]]" = \
            queue.Queue()
        self._done_q: "queue.Queue[Tuple[StepBatch, np.ndarray]]" = \
            queue.Queue()
        self._streams: Dict[int, TokenStream] = {}
        self._cancelled: set = set()           # req_ids pending release
        self._inflight_steps = 0
        self._next_id = 0
        self._id_lock = threading.Lock()
        # device-resident per-lane token feed (decode inputs / sample sink)
        self._lane_tok = jnp.zeros((engine.ecfg.num_lanes,), jnp.int32)
        self._emitter = threading.Thread(target=self._emit_worker,
                                         daemon=True)
        self._emitter.start()
        self.warmed_shapes = engine.warmup() if warmup else 0

    # ------------------------------------------------------------- client --
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None) -> TokenStream:
        """Register a request; returns its ``TokenStream``. Stamps the
        submission time — the TTFT anchor, so queue wait counts."""
        now = time.perf_counter()
        with self._id_lock:
            rid = self._next_id
            self._next_id += 1
        req = Request(req_id=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token,
                      arrival_time=now, submit_time=now)
        stream = TokenStream(req)
        self._submit_q.put((req, stream))
        return stream

    def stream(self, handle: TokenStream):
        """Yield the request's generated token ids until completion."""
        return iter(handle)

    def cancel(self, handle: TokenStream) -> None:
        """Abandon a request: the loop releases its pool pages and lane on
        its next turn; still-pipelined samples are dropped at emission and
        the stream closes."""
        self._cancelled.add(handle.req.req_id)

    # --------------------------------------------------------- emit worker --
    def _emit_worker(self) -> None:
        """The ONLY host sync: drain dispatched steps in device order and
        convert the sampled tokens to host memory off the loop's critical
        path."""
        while True:
            item = self._emit_q.get()
            if item is None:
                return
            sb, toks_dev = item
            self._done_q.put((sb, np.asarray(toks_dev)))

    # ---------------------------------------------------------------- loop --
    def _drain_submissions(self) -> None:
        while True:
            try:
                req, stream = self._submit_q.get_nowait()
            except queue.Empty:
                return
            self._streams[req.req_id] = stream
            self.engine.add_request(req)

    def _drain_done(self, block: bool) -> bool:
        """Apply one completed step's host tokens: decrement in-flight
        counters, drop post-EOS / cancelled samples, route the rest to
        their streams, retire finished requests."""
        try:
            sb, toks = self._done_q.get(block=block)
        except queue.Empty:
            return False
        self._inflight_steps -= 1
        eng = self.engine
        now = time.perf_counter()
        finished: List[Request] = []
        for req, first, idx in sb.samples:
            emitted = eng._emit(req, int(toks[idx]), now, first=first)
            stream = self._streams.get(req.req_id)
            if emitted and stream is not None:
                stream.put(int(toks[idx]))
            finished.append(req)
        eng._finish_done(finished)
        for req in finished:
            if req.state is RequestState.FINISHED:
                self._close_stream(req)
        eng._update_pool_stats()
        return True

    def _close_stream(self, req: Request) -> None:
        stream = self._streams.pop(req.req_id, None)
        if stream is not None:
            stream.close()
        self._cancelled.discard(req.req_id)

    def _apply_cancels(self) -> None:
        """Release cancelled requests IMMEDIATELY — pool pages and lane
        back to the free lists, stream closed. Already-dispatched steps
        that still reference the freed pages are safe: the device executes
        steps in dispatch order, so any reuse of those pages happens in a
        LATER step; their sampled tokens are dropped at emission
        (``Engine._emit`` checks CANCELLED)."""
        if not self._cancelled:
            return
        sched = self.engine.scheduler
        for req in (list(sched.running.values()) + list(sched.waiting)):
            if req.req_id in self._cancelled:
                sched.release(req)
                self._close_stream(req)

    def _dispatch_one(self) -> bool:
        """Build + dispatch ONE device step without waiting for results."""
        eng = self.engine
        plan = eng.scheduler.schedule_step()
        if plan.empty:
            return False
        sb = eng._build_step(plan, device_feed=True)
        toks_dev, self._lane_tok = eng._dispatch_async(sb, self._lane_tok)
        # host metadata advances at DISPATCH time so the next plan can be
        # built immediately; emission-side effects wait for the tokens
        eng._note_executed(sb)
        for req, _, _ in sb.samples:
            req.inflight += 1
        self._inflight_steps += 1
        self._emit_q.put((sb, toks_dev))
        return True

    def _loop_once(self) -> bool:
        """One scheduling turn. Returns True if anything happened."""
        self._drain_submissions()
        progressed = False
        while self._drain_done(block=False):
            progressed = True
        self._apply_cancels()
        if self._inflight_steps < self.depth:
            if self._dispatch_one():
                return True
        if not progressed and self._inflight_steps:
            # pipeline full (or nothing plannable): block for the oldest
            # dispatched step instead of spinning
            progressed = self._drain_done(block=True)
        return progressed

    @property
    def _has_work(self) -> bool:
        return (self.engine.scheduler.has_work or self._inflight_steps > 0
                or not self._submit_q.empty())

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        """Drive the pipeline until every submitted request is finished,
        rejected, or cancelled."""
        steps = 0
        while steps < max_steps:
            self._drain_submissions()
            if not self._has_work:
                break
            self._loop_once()
            steps += 1
        # surface rejections (no device step will ever touch them)
        for rid, stream in list(self._streams.items()):
            if stream.req.state is RequestState.REJECTED:
                self._close_stream(stream.req)

    def close(self) -> None:
        self._emit_q.put(None)
        self._emitter.join(timeout=5.0)
