"""Async continuous-batching frontend: an overlapped host/device pipeline
over the synchronous ``Engine``.

The sync loop serializes HOST plan-building, DEVICE execution, and HOST
token emission: every step blocks on ``np.asarray(logits)`` before the next
plan can be built, so the host and device take turns idling. This frontend
exploits JAX's async dispatch to overlap them:

  * ``AsyncEngine.submit(prompt, ...)`` registers a request and returns a
    ``TokenStream``; ``stream()`` (or iterating it) yields generated token
    ids as they arrive. ``cancel(handle)`` releases the request's pool
    pages and lane as soon as its in-flight device tokens drain, dropping
    any still-pipelined samples at emission.
  * The LOOP (driving thread) builds the plan for step N+1 and dispatches
    it while step N still executes on device (pipeline depth
    ``PIPELINE_DEPTH`` = 2). It never blocks on device results: sampling
    happens ON DEVICE inside the step (``Engine._async_step_impl``) and
    each decode lane's input token is read from the device-resident
    ``lane_tok`` feed, so plan construction needs only host metadata
    (the scheduler's pool state advances at DISPATCH time, not emission
    time — ``Request.inflight`` tracks the gap).
  * The EMIT worker (background thread) owns the only host sync: it drains
    the dispatch queue in device order, blocks on ``np.asarray(tokens)``,
    and hands the host tokens back to the loop, which routes them to the
    per-request stream queues ("detokenize/emit off the critical path").
  * ``warmup()`` AOT-compiles (``jax.jit(...).lower().compile()``) the
    async step executable for EVERY shape in the bucket lattice — prefill
    buckets x packed row buckets x decode — so steady-state serving never
    traces: ``engine.aot_misses`` stays 0 and ``engine.trace_counts`` is
    frozen after warmup.

Greedy outputs are bit-identical to ``Engine.generate``: the device
consumes its own sampled tokens in dispatch order, and the paged-pool step
math is schedule-independent, so overlapping only changes WHEN tokens reach
the host, never their values. The pipeline may overrun EOS by at most
``PIPELINE_DEPTH - 1`` steps; overrun tokens are dropped at emission.

Single-process, two threads: the loop thread owns ALL scheduler/request/
cache mutation; the emit worker only converts device arrays to host and
never touches shared state. Used by ``launch.serve --async`` and
``benchmarks.bench_serving``.

Host-DRAM KV tier: the hierarchical cache's spill uploads and prefetch
bookkeeping ride the SAME loop thread — ``schedule_step`` ticks the
engine's prefetch flights at the top of every turn, so host->HBM uploads
dispatched on turn N are ordered before any step of turn N+1 without a
single host sync, and the async pipeline needs no extra machinery (spills
are ``jax.device_put`` calls queued in device order like every other
dispatch; see ``cache.block_manager`` for the residency state machine).

Failure semantics — every stream terminates with a ``FinishReason``,
delivered AT the terminal event (never at an idle sweep). The table is the
contract the multi-host router inherits:

  ====================  =================  ==================================
  terminal event        FinishReason       who observes it, and when
  ====================  =================  ==================================
  ran to completion     FINISHED           stream closes as the last token
                                           (EOS / max_new_tokens) emits
  unservable request    REJECTED           stream closes the scheduling turn
                                           that rejected it (on_terminal) —
                                           NOT when the pipeline idles
  client cancel()       CANCELLED          stream closes on the loop's next
                                           turn (pages freed immediately;
                                           in-flight samples dropped)
  deadline_s expired    TIMED_OUT          stream closes the scheduling turn
  while QUEUED                             the scheduler shed it
  submit() watermark    SHED               stream returned ALREADY CLOSED —
  (queue depth/tokens)                     the request never enters a queue
  > max_preemptions     PREEMPTION_LIMIT   stream closes the scheduling turn
  evictions                                the preemption bound tripped
  pipeline fault        ERROR              every live stream closes with the
  (step exception,                         exception on ``.error``; the
  emit-worker death,                       pool drains to zero pages; the
  stall watchdog)                          watchdog raises
                                           ``PipelineStallError`` from
                                           ``run_until_idle`` (fail loudly,
                                           never deadlock)
  ====================  =================  ==================================
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine, StepBatch
from repro.serving.request import FinishReason, Request, RequestState

PIPELINE_DEPTH = 2          # dispatched-but-not-emitted device steps
_END = object()             # TokenStream sentinel


class PipelineStallError(RuntimeError):
    """The watchdog found the pipeline wedged: steps in flight but no
    completion within ``watchdog_s`` (emit worker dead or device hung).
    Raised from the driving loop AFTER the fault drain, so every stream
    has already closed with ``FinishReason.ERROR``."""


@dataclass
class TokenStream:
    """Per-request output channel. ``get()`` blocks for the next token id;
    ``None`` STRICTLY means the stream closed — inspect ``finish_reason``
    (and ``error`` for ERROR) for why. A closed stream keeps returning
    ``None``; iteration yields tokens until the close."""
    req: Request
    _q: "queue.Queue[object]" = field(default_factory=queue.Queue)
    finish_reason: Optional[FinishReason] = None
    error: Optional[BaseException] = None

    @property
    def closed(self) -> bool:
        return self.finish_reason is not None

    def put(self, tok: int) -> None:
        self._q.put(tok)

    def close(self, reason: Optional[FinishReason] = None,
              error: Optional[BaseException] = None) -> None:
        """Terminate the stream (idempotent, first writer wins). The reason
        defaults to the request's own terminal status."""
        if self.finish_reason is not None:
            return
        self.finish_reason = (reason if reason is not None
                              else self.req.finish_reason)
        self.error = error if error is not None else self.req.error
        self._q.put(_END)

    def get(self, timeout: Optional[float] = None) -> Optional[int]:
        """Next token id, or ``None`` once the stream closed. A ``timeout``
        elapsing raises ``TimeoutError`` (never ``queue.Empty``)."""
        try:
            tok = self._q.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError(
                f"no token within {timeout}s (request {self.req.req_id} "
                "still open)") from None
        if tok is _END:
            self._q.put(_END)       # stay closed for any later get()
            return None
        return tok      # type: ignore[return-value]

    def __iter__(self):
        while True:
            tok = self.get()
            if tok is None:
                return
            yield tok


class AsyncEngine:
    """Continuous-batching request frontend over a synchronous ``Engine``.

    ``submit()`` / ``stream()`` / ``cancel()`` may be called from any
    thread; the serving loop runs on the caller of ``run_until_idle`` (or
    the ``serve_forever`` thread).

    Resilience knobs: ``max_queue_depth`` / ``max_queued_tokens`` are the
    load-shedding watermarks (``submit`` fast-rejects SHED past either —
    overload degrades to bounded queueing, not unbounded latency);
    ``watchdog_s`` bounds how long the loop waits on an in-flight step
    before declaring the pipeline stalled (``PipelineStallError``)."""

    def __init__(self, engine: Engine, pipeline_depth: int = PIPELINE_DEPTH,
                 warmup: bool = True,
                 max_queue_depth: Optional[int] = None,
                 max_queued_tokens: Optional[int] = None,
                 watchdog_s: float = 30.0):
        self.engine = engine
        self.depth = max(1, int(pipeline_depth))
        self.max_queue_depth = max_queue_depth
        self.max_queued_tokens = max_queued_tokens
        self.watchdog_s = float(watchdog_s)
        self._submit_q: "queue.Queue[Tuple[Request, TokenStream]]" = \
            queue.Queue()
        self._emit_q: "queue.Queue[Optional[Tuple[StepBatch, object]]]" = \
            queue.Queue()
        self._done_q: "queue.Queue[Tuple[StepBatch, object]]" = \
            queue.Queue()
        self._streams: Dict[int, TokenStream] = {}
        self._cancelled: set = set()           # req_ids pending release
        self._inflight_steps = 0
        self._next_id = 0
        self._id_lock = threading.Lock()
        self._failed: Optional[BaseException] = None
        # load-shedding bookkeeping (under _id_lock): requests submitted
        # but not yet admitted to a lane — the watermarked queue
        self._awaiting: Dict[int, Request] = {}
        self._queued_tokens = 0
        # terminal decisions made INSIDE the scheduler (REJECTED /
        # TIMED_OUT / PREEMPTION_LIMIT) close the client's stream the
        # moment they happen — the callback runs on the loop thread
        engine.scheduler.on_terminal = self._close_stream
        # device-resident per-lane token feed (decode inputs / sample sink)
        self._lane_tok = jnp.zeros((engine.ecfg.num_lanes,), jnp.int32)
        self._emitter = threading.Thread(target=self._emit_worker,
                                         daemon=True)
        self._emitter.start()
        self.warmed_shapes = engine.warmup() if warmup else 0

    # ------------------------------------------------------------- client --
    def _over_watermark(self, n_tokens: int) -> bool:
        """Load-shed check (``_id_lock`` held): sweep requests that left
        the queue (admitted or terminal), then test the watermarks."""
        if self.max_queue_depth is None and self.max_queued_tokens is None:
            return False
        for rid, req in list(self._awaiting.items()):
            if req.admit_time >= 0 or req.is_terminal:
                del self._awaiting[rid]
                self._queued_tokens -= req.prompt_len
        if (self.max_queue_depth is not None
                and len(self._awaiting) >= self.max_queue_depth):
            return True
        return (self.max_queued_tokens is not None
                and self._queued_tokens + n_tokens > self.max_queued_tokens)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_token: Optional[int] = None,
               deadline_s: float = 0.0) -> TokenStream:
        """Register a request; returns its ``TokenStream``. Stamps the
        submission time — the TTFT anchor, so queue wait counts.
        ``deadline_s`` is the client's latency budget: the scheduler sheds
        the request (TIMED_OUT) if it is still queued when it expires.
        Past the queue watermarks the stream comes back ALREADY CLOSED
        with ``FinishReason.SHED`` — the overload fast path."""
        now = time.perf_counter()
        prompt = np.asarray(prompt, np.int32)
        with self._id_lock:
            rid = self._next_id
            self._next_id += 1
            req = Request(req_id=rid, prompt=prompt,
                          max_new_tokens=max_new_tokens,
                          eos_token=eos_token, arrival_time=now,
                          submit_time=now, deadline_s=deadline_s)
            stream = TokenStream(req)
            if self._failed is not None:
                req.state = RequestState.REJECTED
                req.finish(FinishReason.ERROR, self._failed)
            elif self._over_watermark(req.prompt_len):
                req.state = RequestState.REJECTED
                req.finish(FinishReason.SHED)
                self.engine.stats.shed += 1
            elif (self.max_queue_depth is not None
                    or self.max_queued_tokens is not None):
                # only tracked under active watermarks (the sweep that
                # retires entries lives in the watermark check)
                self._awaiting[rid] = req
                self._queued_tokens += req.prompt_len
        if req.is_terminal:
            stream.close()
            return stream
        self._submit_q.put((req, stream))
        return stream

    def stream(self, handle: TokenStream):
        """Yield the request's generated token ids until completion."""
        return iter(handle)

    def cancel(self, handle: TokenStream) -> None:
        """Abandon a request: the loop releases its pool pages and lane on
        its next turn; still-pipelined samples are dropped at emission and
        the stream closes (``FinishReason.CANCELLED``). Cancelling an
        already-terminated stream is a no-op."""
        if handle.closed or handle.req.is_terminal:
            return
        self._cancelled.add(handle.req.req_id)

    # --------------------------------------------------------- emit worker --
    def _emit_worker(self) -> None:
        """The ONLY host sync: drain dispatched steps in device order and
        convert the sampled tokens to host memory off the loop's critical
        path. A conversion fault is POSTED to the loop (which fails the
        pipeline and routes ERROR to every stream) — never swallowed; a
        killed worker dies silently and the stall watchdog detects it."""
        while True:
            item = self._emit_q.get()
            if item is None:
                return
            sb, toks_dev = item
            try:
                faults = self.engine.faults
                if faults is not None:
                    faults.on_emit()
                self._done_q.put((sb, np.asarray(toks_dev)))
            except WorkerKilled:
                return                  # silent death: the watchdog fires
            except BaseException as exc:
                self._done_q.put((sb, exc))

    # ---------------------------------------------------------------- loop --
    def _drain_submissions(self) -> None:
        while True:
            try:
                req, stream = self._submit_q.get_nowait()
            except queue.Empty:
                return
            self._streams[req.req_id] = stream
            if self._failed is not None:
                # raced a pipeline fault: never reached the scheduler
                if req.finish(FinishReason.ERROR, self._failed):
                    self.engine.stats.errors += 1
                self._close_stream(req)
                continue
            self.engine.add_request(req)

    def _drain_done(self, block: bool) -> bool:
        """Apply one completed step's host tokens: decrement in-flight
        counters, drop post-EOS / terminal samples, route the rest to
        their streams, retire finished requests. A blocking wait is
        bounded by ``watchdog_s`` — its expiry means the pipeline is
        wedged (dead emit worker / hung device) and fails loudly."""
        try:
            if block:
                sb, toks = self._done_q.get(timeout=self.watchdog_s)
            else:
                sb, toks = self._done_q.get(block=False)
        except queue.Empty:
            if not block:
                return False
            self._stall()               # drains + raises PipelineStallError
        self._inflight_steps -= 1
        if isinstance(toks, BaseException):
            self._fail(toks)            # emit-worker fault, posted in-band
            return True
        eng = self.engine
        now = time.perf_counter()
        finished: List[Request] = []
        for req, first, idx in sb.samples:
            emitted = eng._emit(req, int(toks[idx]), now, first=first)
            stream = self._streams.get(req.req_id)
            if emitted and stream is not None:
                stream.put(int(toks[idx]))
            finished.append(req)
        eng._finish_done(finished)
        for req in finished:
            if req.state is RequestState.FINISHED:
                self._close_stream(req)
        eng._update_pool_stats()
        return True

    def _close_stream(self, req: Request) -> None:
        """Close (idempotently) the client's stream with the request's own
        terminal status. Also the scheduler's ``on_terminal`` callback, so
        REJECTED / TIMED_OUT / PREEMPTION_LIMIT close at decision time."""
        stream = self._streams.pop(req.req_id, None)
        if stream is not None:
            stream.close()
        self._cancelled.discard(req.req_id)

    def _apply_cancels(self) -> None:
        """Release cancelled requests IMMEDIATELY — pool pages and lane
        back to the free lists, stream closed. Already-dispatched steps
        that still reference the freed pages are safe: the device executes
        steps in dispatch order, so any reuse of those pages happens in a
        LATER step; their sampled tokens are dropped at emission
        (``Engine._emit`` checks the terminal status)."""
        if not self._cancelled:
            return
        sched = self.engine.scheduler
        for req in (list(sched.running.values()) + list(sched.waiting)):
            if req.req_id in self._cancelled:
                sched.release(req)
                self._close_stream(req)
        # ids whose streams already closed (raced another terminal event)
        self._cancelled.intersection_update(self._streams)

    def _dispatch_one(self) -> bool:
        """Build + dispatch ONE device step without waiting for results."""
        eng = self.engine
        plan = eng.scheduler.schedule_step()
        if plan.empty:
            return False
        sb = eng._build_step(plan, device_feed=True)
        toks_dev, self._lane_tok = eng._dispatch_async(sb, self._lane_tok)
        # host metadata advances at DISPATCH time so the next plan can be
        # built immediately; emission-side effects wait for the tokens
        eng._note_executed(sb)
        for req, _, _ in sb.samples:
            req.inflight += 1
        self._inflight_steps += 1
        self._emit_q.put((sb, toks_dev))
        return True

    # ------------------------------------------------------- fault drain --
    def _fail(self, exc: BaseException) -> None:
        """Terminal fault path: drain the WHOLE pipeline as ERROR. Every
        live request (running, queued, still in the submit queue) is
        released — the pool returns to zero pages in use — and every open
        stream closes carrying ``exc``. First fault wins; later submits
        come back already closed."""
        if self._failed is not None:
            return
        self._failed = exc
        # requests still in the frontend's submit queue never reached the
        # scheduler — register their streams so they close with ERROR too
        while True:
            try:
                req, stream = self._submit_q.get_nowait()
            except queue.Empty:
                break
            self._streams[req.req_id] = stream
            if req.finish(FinishReason.ERROR, exc):
                self.engine.stats.errors += 1
        self.engine.abort_all(exc)
        for stream in list(self._streams.values()):
            stream.req.finish(FinishReason.ERROR, exc)   # first-writer-wins
            self._close_stream(stream.req)
        self._cancelled.clear()
        self._inflight_steps = 0

    def _stall(self) -> None:
        """Watchdog trip: no step completed within ``watchdog_s`` while
        steps were in flight. Fail the pipeline (streams close ERROR, pool
        drains) and raise — a wedged pipeline must be loud, not a hang."""
        dead = not self._emitter.is_alive()
        exc = PipelineStallError(
            f"pipeline stalled: {self._inflight_steps} step(s) in flight "
            f"but none completed within watchdog_s={self.watchdog_s}s"
            + ("; the emit worker is DEAD" if dead else ""))
        self._fail(exc)
        raise exc

    def _loop_once(self) -> bool:
        """One scheduling turn. Returns True if anything happened."""
        faults = self.engine.faults
        if faults is not None:
            faults.on_turn(self)
        self._drain_submissions()
        progressed = False
        while self._drain_done(block=False):
            progressed = True
        if self._failed is not None:
            return True
        self._apply_cancels()
        if self._inflight_steps < self.depth:
            try:
                if self._dispatch_one():
                    return True
            except Exception as exc:
                # a dispatched-step fault must not strand the pipeline:
                # drain everything as ERROR (streams carry the exception)
                self._fail(exc)
                return True
        if not progressed and self._inflight_steps:
            # pipeline full (or nothing plannable): block for the oldest
            # dispatched step instead of spinning
            progressed = self._drain_done(block=True)
        return progressed

    @property
    def _has_work(self) -> bool:
        return (self.engine.scheduler.has_work or self._inflight_steps > 0
                or not self._submit_q.empty())

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        """Drive the pipeline until every submitted request terminated
        (finished, rejected, cancelled, shed, timed out, or errored).
        Raises ``PipelineStallError`` if the watchdog trips — after the
        fault drain, so no stream is left open either way."""
        steps = 0
        while steps < max_steps:
            self._drain_submissions()
            if self._failed is not None or not self._has_work:
                break
            self._loop_once()
            steps += 1
        # safety net: every terminal request's stream must be closed by
        # now (terminal events close them in-line); sweep any straggler
        for stream in list(self._streams.values()):
            if stream.req.is_terminal:
                self._close_stream(stream.req)

    def close(self) -> None:
        self._emit_q.put(None)
        self._emitter.join(timeout=5.0)


class WorkerKilled(BaseException):
    """Fault-injection signal: kill the emit worker SILENTLY (thread
    exits, nothing posted) so the stall watchdog — not error propagation —
    has to detect the loss. Derives from BaseException so production
    ``except Exception`` cleanup can never absorb it."""
