"""Generic decoder-only transformer covering the dense / moe / mla / vlm
families, with scan-over-layers (stacked params), paged KV caching, and the
three LLM-CoOpt techniques toggled by a ``CoOptConfig``.

Step kinds (configs/shapes.py):
  forward     – teacher-forced full sequence (train)
  prefill     – forward + KV-cache population (in-flight bf16 attention;
                the cache stores the Opt-KV-quantized copy for later decode)
  decode_step – ONE token against the paged cache (Opt-Pa / Opt-KV read path)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.core.opt_kv import (identity_page_table, identity_slots,
                               pool_layout, write_kv)
from repro.core.opt_pa import paged_chunk_attention, paged_decode_attention
from repro.models import mla as mla_mod
from repro.models.layers import (Spec, apply_rope, causal_attention, init_tree,
                                 linear, repeat_kv, rmsnorm, shard_act, swiglu)
from repro.models.moe import moe_ffn


def _pages(seq_len: int, page_size: int) -> int:
    return max((seq_len + page_size - 1) // page_size, 1)


class TransformerModel:
    """Families: dense (yi/qwen/deepseek/llama), moe (mixtral), mla
    (deepseek-v2), vlm (internvl2 — stub patch embeddings prepended)."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.family in ("dense", "moe", "mla", "vlm")
        self.cfg = cfg

    # ------------------------------------------------------------- params --
    def _segments(self):
        cfg = self.cfg
        moe = "moe" if cfg.num_experts else "dense"
        if cfg.num_experts and cfg.first_dense_layers:
            return [(cfg.first_dense_layers, "dense"),
                    (cfg.num_layers - cfg.first_dense_layers, moe)]
        return [(cfg.num_layers, moe)]

    def _attn_specs(self, L: int) -> Dict[str, Spec]:
        cfg = self.cfg
        d, H, Hkv, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        s: Dict[str, Spec] = {
            "ln1": Spec((L, d), ("layers", None), "ones", jnp.float32)}
        if cfg.family == "mla":
            dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
            R, dv = cfg.kv_lora_rank, cfg.v_head_dim
            s.update(
                wq=Spec((L, d, H * (dn + dr)), ("layers", "d_in", "d_out")),
                w_dkv=Spec((L, d, R + dr), ("layers", "d_in", "d_out")),
                kv_norm=Spec((L, R), ("layers", None), "ones", jnp.float32),
                w_uk=Spec((L, R, H * dn), ("layers", "d_in", "d_out")),
                w_uv=Spec((L, R, H * dv), ("layers", "d_in", "d_out")),
                wo=Spec((L, H * dv, d), ("layers", "d_out", "d_in")),
            )
            return s
        s.update(
            wq=Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            wk=Spec((L, d, Hkv * D), ("layers", "d_in", "d_out")),
            wv=Spec((L, d, Hkv * D), ("layers", "d_in", "d_out")),
            wo=Spec((L, H * D, d), ("layers", "d_out", "d_in")),
        )
        if cfg.qkv_bias:
            s.update(bq=Spec((L, H * D), ("layers", "d_out"), "zeros"),
                     bk=Spec((L, Hkv * D), ("layers", "d_out"), "zeros"),
                     bv=Spec((L, Hkv * D), ("layers", "d_out"), "zeros"))
        if cfg.qk_norm:
            s.update(q_norm=Spec((L, D), ("layers", None), "ones", jnp.float32),
                     k_norm=Spec((L, D), ("layers", None), "ones", jnp.float32))
        return s

    def _ffn_specs(self, L: int, kind: str) -> Dict[str, Spec]:
        cfg = self.cfg
        d = cfg.d_model
        s = {"ln2": Spec((L, d), ("layers", None), "ones", jnp.float32)}
        if kind == "dense":
            ff = cfg.d_ff
            s.update(wg=Spec((L, d, ff), ("layers", "d_in", "d_out")),
                     wu=Spec((L, d, ff), ("layers", "d_in", "d_out")),
                     wd=Spec((L, ff, d), ("layers", "d_out", "d_in")))
        else:
            E, ff = cfg.num_experts, cfg.moe_d_ff
            s.update(
                wr=Spec((L, d, E), ("layers", "d_in", None)),
                # expert-parallel: experts -> "data" when divisible (else
                # d_in takes it), ff -> model. (§Perf P1: un-sharding d and
                # putting ff on (data, model) replicated the expert compute
                # 100x — refuted; the fix that held is the activation
                # constraints inside moe_ffn.)
                wg_e=Spec((L, E, d, ff), ("layers", "experts", "moe_d_in",
                                          "d_out")),
                wu_e=Spec((L, E, d, ff), ("layers", "experts", "moe_d_in",
                                          "d_out")),
                wd_e=Spec((L, E, ff, d), ("layers", "experts", "d_out",
                                          "moe_d_in")),
            )
            if cfg.num_shared_experts:
                sf = ff * cfg.num_shared_experts
                s.update(wg_s=Spec((L, d, sf), ("layers", "d_in", "d_out")),
                         wu_s=Spec((L, d, sf), ("layers", "d_in", "d_out")),
                         wd_s=Spec((L, sf, d), ("layers", "d_out", "d_in")))
        return s

    def param_specs(self):
        cfg = self.cfg
        segs = []
        for count, kind in self._segments():
            seg = dict(self._attn_specs(count))
            seg.update(self._ffn_specs(count, kind))
            segs.append(seg)
        return {
            "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "d_out"),
                          "embed"),
            "segments": segs,
            "final_norm": Spec((cfg.d_model,), (None,), "ones", jnp.float32),
            "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("d_in", "d_out")),
        }

    def init(self, key):
        return init_tree(key, self.param_specs())

    # -------------------------------------------------------------- layers --
    def _attention_full(self, p, x, positions, coopt: CoOptConfig):
        """Full-sequence attention (train/prefill). Returns (out, k, v) —
        k/v are the per-token cache entries (None head-expanded)."""
        cfg = self.cfg
        B, S, _ = x.shape
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        if cfg.family == "mla":
            qn, qr, latent = mla_mod.mla_project(x, p, cfg, positions)
            o = mla_mod.mla_full_attention(qn, qr, latent, p, cfg,
                                           window=cfg.attn_window)
            out = linear(o.reshape(B, S, -1), p["wo"])
            return out, latent, None
        q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, D)
        k = linear(x, p["wk"], p.get("bk")).reshape(B, S, Hkv, D)
        v = linear(x, p["wv"], p.get("bv")).reshape(B, S, Hkv, D)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if coopt.use_kernel:
            from repro.kernels import ops
            if coopt.opt_gqa or Hkv == H:
                o = ops.flash_prefill(q, k, v, window=cfg.attn_window)
            else:
                o = ops.flash_prefill(q, repeat_kv(k, H // Hkv),
                                      repeat_kv(v, H // Hkv),
                                      window=cfg.attn_window)
        elif coopt.opt_gqa or Hkv == H:
            o = causal_attention(q, k, v, window=cfg.attn_window)
        else:  # Original: KV physically expanded per query head (Fig. 2)
            o = causal_attention(q, repeat_kv(k, H // Hkv),
                                 repeat_kv(v, H // Hkv), window=cfg.attn_window)
        return linear(o.reshape(B, S, H * D), p["wo"]), k, v

    def _attention_decode(self, p, x, kv_slice, positions, new_len,
                          page_table, coopt, long_window: int):
        """One-token attention against this layer's slice of the GLOBAL
        paged pool. kv_slice: ("kv", "scale") for this layer (already
        containing the new token); page_table: (B, P_lane) physical pages
        in logical order. Returns projected output (B,1,d)."""
        cfg = self.cfg
        B = x.shape[0]
        window = cfg.attn_window or long_window
        if cfg.family == "mla":
            qn, qr, _lat = None, None, None
            H = cfg.num_heads
            dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
            q = linear(x, p["wq"]).reshape(B, 1, H, dn + dr)
            qn, qr = q[..., :dn], q[..., dn:]
            qr = apply_rope(qr, positions, cfg.rope_theta)
            o = mla_mod.mla_paged_decode(
                qn[:, 0], qr[:, 0], kv_slice["kv"], kv_slice.get("scale"),
                new_len, p, cfg, coopt, window=window,
                sink_pages=cfg.sink_blocks, page_table=page_table)
            return linear(o.reshape(B, 1, -1), p["wo"])
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = linear(x, p["wq"], p.get("bq")).reshape(B, 1, H, D)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        o = paged_decode_attention(
            q[:, 0], kv_slice["kv"], kv_slice.get("scale"), new_len,
            coopt=coopt, window=window, sink_pages=cfg.sink_blocks,
            page_table=page_table)
        return linear(o.reshape(B, 1, H * D), p["wo"])

    def _new_kv(self, p, x, positions):
        """Per-token cache entries (decode token or prefill chunk). Returns
        (k, v) or (latent, None) for MLA. Shapes (B,S,Hkv,D) / (B,S,R+dr)."""
        cfg = self.cfg
        B, S, _ = x.shape
        if cfg.family == "mla":
            _, _, latent = mla_mod.mla_project(x, p, cfg, positions)
            return latent, None
        Hkv, D = cfg.num_kv_heads, cfg.head_dim
        k = linear(x, p["wk"], p.get("bk")).reshape(B, S, Hkv, D)
        v = linear(x, p["wv"], p.get("bv")).reshape(B, S, Hkv, D)
        if cfg.qk_norm:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        k = apply_rope(k, positions, cfg.rope_theta)
        return k, v

    def _ffn(self, p, x, kind, coopt: CoOptConfig = COOPT):
        cfg = self.cfg
        if kind == "dense":
            return swiglu(x, p["wg"], p["wu"], p["wd"]), None
        shared = ((p["wg_s"], p["wu_s"], p["wd_s"])
                  if cfg.num_shared_experts else None)
        return moe_ffn(x, p["wr"], p["wg_e"], p["wu_e"], p["wd_e"],
                       top_k=cfg.top_k, shared=shared,
                       capacity_factor=coopt.moe_capacity_factor)

    # ------------------------------------------------------------- forward --
    def _embed(self, params, batch):
        """Token (+ modality-stub) embedding. Returns (h, text_offset)."""
        cfg = self.cfg
        h = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
        off = 0
        if cfg.family == "vlm" and "patches" in batch:
            h = jnp.concatenate(
                [batch["patches"].astype(jnp.bfloat16), h], axis=1)
            off = cfg.num_patches
        return h, off

    def forward(self, params, batch, coopt: CoOptConfig = COOPT):
        """Teacher-forced logits aligned with batch['labels'] (see
        input_specs): dense -> (B,S,V); vlm -> (B,S_text,V)."""
        cfg = self.cfg
        h, off = self._embed(params, batch)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = shard_act(h, ("batch", "seq", None))
        auxes = []
        for seg_params, (count, kind) in zip(params["segments"],
                                             self._segments()):
            def body(carry, pl, kind=kind):
                hh = carry
                a, _, _ = self._attention_full(pl, rmsnorm(hh, pl["ln1"],
                                                           cfg.norm_eps),
                                               positions, coopt)
                hh = hh + a
                f, aux = self._ffn(pl, rmsnorm(hh, pl["ln2"], cfg.norm_eps),
                                   kind, coopt)
                hh = shard_act(hh + f, ("batch", "seq", None))
                aux_v = (jnp.zeros(3, jnp.float32) if aux is None
                         else jnp.stack([aux.load_balance_loss,
                                         aux.router_z_loss,
                                         aux.dropped_fraction]))
                return hh, aux_v
            body = jax.checkpoint(body)
            h, aux = jax.lax.scan(body, h, seg_params)
            auxes.append(aux)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        if off:
            # same convention as dense: logits[i] predicts text token i+1
            h = h[:, off:]
        logits = linear(h, params["lm_head"])
        aux = jnp.sum(jnp.concatenate(auxes, 0), axis=0)
        return logits, {"load_balance": aux[0], "router_z": aux[1],
                        "dropped": aux[2]}

    # ------------------------------------------------------------ caching --
    def cache_shape(self, batch: int, max_len: int, coopt: CoOptConfig,
                    num_shards: int = 1, cache_cfg=None):
        """Dict of (shape, dtype, logical axes) — consumed by launch/dryrun
        for ShapeDtypeStructs + shardings, and by init_cache.

        GLOBAL-POOL layout: kv/scale leaves carry no batch dimension — the
        pool holds ``batch * pages(max_len)`` pages shared by every lane
        (refcounted + prefix-cached by the host-side BlockManager), padded
        up so the pages axis tiles evenly over ``num_shards`` mesh shards
        (CACHE_RULES: pages -> (pod, data)). A ``CacheConfig`` overrides
        the pool size / page size / shard count (opt_kv.pool_layout is the
        shared sizing rule). Direct callers fall back to the static
        lane-identity partition; the engine reserves the final page so its
        last line can serve as the Pallas write kernel's SkipSet sentinel.
        ``length`` stays per-lane."""
        cfg = self.cfg
        P, ps = pool_layout(batch, max_len, coopt, num_shards, cache_cfg)
        out: Dict[str, Any] = {}
        if cfg.family == "mla":
            width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            out["kv"] = ((cfg.num_layers, P, ps, width),
                         coopt.kv_dtype,
                         ("layers", "pages", None, "latent"))
            if coopt.opt_kv:
                # two scales per token: c_kv and k_rope magnitudes differ,
                # a shared scale would crush the smaller segment's mantissa
                out["scale"] = ((cfg.num_layers, P, ps, 2),
                                jnp.float32,
                                ("layers", "pages", None, None))
        else:
            Hkv, D = cfg.num_kv_heads, cfg.head_dim
            out["kv"] = ((cfg.num_layers, 2, P, ps, Hkv, D),
                         coopt.kv_dtype,
                         ("layers", None, "pages", None, "kv_heads",
                          "head_dim"))
            if coopt.opt_kv:
                out["scale"] = ((cfg.num_layers, 2, P, ps, Hkv),
                                jnp.float32,
                                ("layers", None, "pages", None,
                                 "kv_heads"))
        out["length"] = ((batch,), jnp.int32, ("batch",))
        return out

    def init_cache(self, batch: int, max_len: int, coopt: CoOptConfig,
                   num_shards: int = 1, cache_cfg=None):
        return {k: jnp.zeros(sh, dt)
                for k, (sh, dt, _) in
                self.cache_shape(batch, max_len, coopt,
                                 num_shards=num_shards,
                                 cache_cfg=cache_cfg).items()}

    def _write_layer(self, kv_c, sc_c, new_a, new_b, slots, coopt):
        """Write cache entries for one layer (GLOBAL flat slots; -1 =
        SkipSet drop). MLA: new_a=(B,S,R+dr), kv_c=(P,ps,R+dr)."""
        if self.cfg.family == "mla":
            # ops dispatch: shard-local scatter under a mesh ctx, the
            # identical jnp scatter otherwise (ONE write implementation)
            from repro.kernels import ops
            return ops.latent_pool_write(
                kv_c, sc_c, new_a, slots, opt_kv=coopt.opt_kv,
                lora_rank=self.cfg.kv_lora_rank)
        return write_kv(kv_c, sc_c, new_a, new_b, slots, coopt)

    def _scan_with_cache(self, params, cache, h, new_len, coopt, step_fn):
        """Scan layers threading per-layer cache slices as xs/ys.
        ``new_len`` (B,) is the per-lane token count after this step —
        supplied by the engine (global slots carry no length info)."""
        cfg = self.cfg
        start = 0
        kv_out, sc_out = [], []
        for seg_params, (count, kind) in zip(params["segments"],
                                             self._segments()):
            kv_seg = cache["kv"][start:start + count]
            sc_seg = (cache["scale"][start:start + count]
                      if coopt.opt_kv else None)
            xs = (seg_params, kv_seg, sc_seg) if coopt.opt_kv else \
                 (seg_params, kv_seg)

            def body(carry, xs, kind=kind):
                hh = carry
                if coopt.opt_kv:
                    pl, kv_c, sc_c = xs
                else:
                    pl, kv_c = xs
                    sc_c = None
                hh, kv_c, sc_c = step_fn(hh, pl, kv_c, sc_c, kind)
                ys = (kv_c, sc_c) if coopt.opt_kv else (kv_c,)
                return hh, ys

            h, ys = jax.lax.scan(body, h, xs)
            kv_out.append(ys[0])
            if coopt.opt_kv:
                sc_out.append(ys[1])
            start += count
        cache = dict(cache)
        cache["kv"] = jnp.concatenate(kv_out, 0) if len(kv_out) > 1 else kv_out[0]
        if coopt.opt_kv:
            cache["scale"] = (jnp.concatenate(sc_out, 0)
                              if len(sc_out) > 1 else sc_out[0])
        cache["length"] = new_len
        return h, cache

    def _attention_chunk(self, p, x, positions, kv_c, sc_c, page_table,
                         coopt, long_window: int = 0, seg_q=None,
                         page_seg=None, page_base=None):
        """Prefill-continuation attention (chunked prefill / mixed step):
        the chunk's K/V are already written to the GLOBAL paged cache;
        queries attend over the lane's WHOLE cache (prefix-cache hits +
        previous chunks + this one) through its page table with true
        positions — see ``core.opt_pa.paged_chunk_attention``. Supports
        PER-LANE query positions (the token-budget scheduler mixes decode
        lanes, chunk length 1, with prefill-chunk lanes in one call). MLA
        runs the matrix-absorption form against the latent pool. The
        ``long_window`` block-sparse policy matches ``_attention_decode``,
        so a token's logits are step-composition independent."""
        cfg = self.cfg
        B, S, _ = x.shape
        window = cfg.attn_window or long_window
        if cfg.family == "mla":
            H = cfg.num_heads
            dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
            q = linear(x, p["wq"]).reshape(B, S, H, dn + dr)
            qn, qr = q[..., :dn], q[..., dn:]
            qr = apply_rope(qr, positions, cfg.rope_theta)
            o = mla_mod.mla_chunk_attention(
                qn, qr, kv_c, sc_c, positions, page_table, p, cfg, coopt,
                window=window, sink_pages=cfg.sink_blocks, seg_q=seg_q,
                page_seg=page_seg, page_base=page_base)
            return linear(o.reshape(B, S, -1), p["wo"])
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = linear(x, p["wq"], p.get("bq")).reshape(B, S, H, D)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        o = paged_chunk_attention(q, kv_c, sc_c, positions, page_table,
                                  coopt, window=window,
                                  sink_pages=cfg.sink_blocks, seg_q=seg_q,
                                  page_seg=page_seg, page_base=page_base)
        return linear(o.reshape(B, S, H * D).astype(x.dtype), p["wo"])

    def _pool_defaults(self, cache, batch, B):
        """(page_table, total_pages) — batch-provided or lane-identity."""
        axis = 1 if self.cfg.family == "mla" else 2
        P_total = cache["kv"].shape[axis]
        pt = batch.get("page_table")
        if pt is None:
            pt = identity_page_table(B, P_total)
        return pt.astype(jnp.int32), P_total

    def prefill(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                long_window: int = 0):
        """Full-prompt forward + cache population. Returns
        (last-token logits (B,V), cache).

        Chunked-prefill continuation (Sarathi-style / mixed decode+prefill
        step — the engine's ONE ragged step path): pass
        ``batch["positions"]`` (B, S) with each lane's absolute positions
        plus matching GLOBAL ``slot_idx``, the lane ``page_table`` and the
        post-step ``cache_len``; attention then runs over the whole cached
        history so chunk k+1 sees chunks 0..k — and a decode lane is just a
        chunk of length 1. All transformer families: dense/moe/vlm via
        ``paged_chunk_attention``, MLA via the absorbed latent form. For vlm,
        token column j IS position ``positions[:, j]``: columns whose
        position falls inside the patch-stub prefix take their embedding
        from ``batch["patches"]`` instead of the token table."""
        cfg = self.cfg
        chunked = "positions" in batch
        if chunked:
            positions = batch["positions"].astype(jnp.int32)
            h = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
            off = cfg.num_patches if cfg.family == "vlm" else 0
            if off and "patches" in batch:
                pidx = jnp.clip(positions, 0, off - 1)
                pe = jnp.take_along_axis(
                    batch["patches"].astype(jnp.bfloat16),
                    pidx[..., None], axis=1)
                h = jnp.where((positions < off)[..., None], pe, h)
            B, S, _ = h.shape
        else:
            h, off = self._embed(params, batch)
            B, S, _ = h.shape
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = shard_act(h, ("batch", "seq", None))
        page_table, P_total = self._pool_defaults(cache, batch, B)
        if "slot_idx" in batch:
            slots = batch["slot_idx"].astype(jnp.int32)
        else:
            slots = identity_slots(B, positions, P_total, coopt.page_size)
        new_len = batch.get("cache_len")
        if new_len is None:
            new_len = jnp.maximum(cache["length"],
                                  jnp.max(positions, axis=1) + 1)
        new_len = new_len.astype(jnp.int32)
        seg_q = batch.get("seg_q")
        page_seg = batch.get("page_seg")
        page_base = batch.get("page_base")

        def step(hh, pl, kv_c, sc_c, kind):
            x = rmsnorm(hh, pl["ln1"], cfg.norm_eps)
            if chunked:
                new_a, new_b = self._new_kv(pl, x, positions)
                kv_c, sc_c = self._write_layer(kv_c, sc_c, new_a, new_b,
                                               slots, coopt)
                a = self._attention_chunk(pl, x, positions, kv_c, sc_c,
                                          page_table, coopt, long_window,
                                          seg_q=seg_q, page_seg=page_seg,
                                          page_base=page_base)
            else:
                a, new_a, new_b = self._attention_full(pl, x, positions,
                                                       coopt)
                kv_c, sc_c = self._write_layer(kv_c, sc_c, new_a, new_b,
                                               slots, coopt)
            hh = hh + a
            f, _ = self._ffn(pl, rmsnorm(hh, pl["ln2"], cfg.norm_eps), kind,
                             coopt)
            return shard_act(hh + f, ("batch", "seq", None)), kv_c, sc_c

        h, cache = self._scan_with_cache(params, cache, h, new_len, coopt,
                                         step)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        last = batch.get("last_pos", jnp.full((B,), S - 1, jnp.int32))
        if last.ndim == 2:
            # packed rows sample SEVERAL columns per row (one per finished
            # segment): last (B, G) -> logits (B, G, V)
            h_last = jnp.take_along_axis(h, last[..., None], axis=1)
            return linear(h_last, params["lm_head"]), cache
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        return linear(h_last, params["lm_head"]), cache

    def decode_step(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                    long_window: int = 0):
        """ONE token (B,1) against the paged cache. Returns (logits (B,V),
        cache). The engine supplies ``positions``/``slot_idx``/``page_table``
        /``cache_len``; direct callers fall back to the per-lane ``length``
        leaf and the lane-identity pool partition."""
        cfg = self.cfg
        h = params["embed"][batch["token"]].astype(jnp.bfloat16)  # (B,1,d)
        B = h.shape[0]
        positions = batch.get("positions")
        if positions is None:
            positions = cache["length"][:, None]                   # (B,1)
        positions = positions.astype(jnp.int32)
        page_table, P_total = self._pool_defaults(cache, batch, B)
        if "slot_idx" in batch:
            slots = batch["slot_idx"].astype(jnp.int32)
        else:
            slots = identity_slots(B, positions, P_total, coopt.page_size)
        new_len = batch.get("cache_len")
        if new_len is None:
            new_len = cache["length"] + 1
        new_len = new_len.astype(jnp.int32)

        def step(hh, pl, kv_c, sc_c, kind):
            x = rmsnorm(hh, pl["ln1"], cfg.norm_eps)
            new_a, new_b = self._new_kv(pl, x, positions)
            kv_c, sc_c = self._write_layer(kv_c, sc_c, new_a, new_b, slots,
                                           coopt)
            a = self._attention_decode(pl, x, {"kv": kv_c, "scale": sc_c},
                                       positions, new_len, page_table,
                                       coopt, long_window)
            hh = hh + a
            f, _ = self._ffn(pl, rmsnorm(hh, pl["ln2"], cfg.norm_eps), kind,
                             coopt)
            return hh + f, kv_c, sc_c

        h, cache = self._scan_with_cache(params, cache, h, new_len, coopt,
                                         step)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return linear(h[:, 0], params["lm_head"]), cache

    # -------------------------------------------------------------- specs --
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "decode":
            return {"token": tok(B, 1)}
        st = S - cfg.num_patches if cfg.family == "vlm" else S
        out = {"tokens": tok(B, st)}
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            out["labels"] = tok(B, st)
        return out

    # --------------------------------------------------------------- misc --
    def param_count(self) -> int:
        from repro.models.layers import param_count
        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        cfg = self.cfg
        total = self.param_count()
        if not cfg.num_experts:
            return total
        per_layer = 3 * cfg.d_model * cfg.moe_d_ff
        moe_layers = cfg.num_layers - cfg.first_dense_layers
        return total - per_layer * (cfg.num_experts - cfg.top_k) * moe_layers
