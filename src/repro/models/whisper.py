"""Whisper-small — encoder-decoder transformer [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the task carve-out:
``input_specs`` provides precomputed frame embeddings (B, num_frames, d_model).
This module implements the transformer that consumes them:

  encoder  — bidirectional pre-LN attention over frames (kv=12 -> Opt-GQA
             grouping is the identity, but the code path is shared),
  decoder  — causal self-attention with the LLM-CoOpt paged cache (Opt-KV fp8
             write/read, Opt-Pa block-wise softmax) + cross-attention whose
             K/V are computed ONCE from the encoder output at prefill and
             stored (Opt-KV-quantized) in the cache — the "static KV is
             quantized once" case from DESIGN.md §5.

Whisper uses LayerNorm + GELU MLP + learned positional embeddings (sinusoidal
for the encoder); we keep that (not RMSNorm/SwiGLU).

long_500k is skipped for this arch (full-attention decoder, 448-token native
context — DESIGN.md §5); decode_32k runs as a stress shape.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.core.opt_kv import (identity_page_table, identity_slots,
                               pool_layout, write_kv)
from repro.core.opt_pa import paged_chunk_attention, paged_decode_attention
from repro.cache.quant import quantize_fp8, dequantize_fp8
from repro.models.layers import (Spec, causal_attention, gelu_mlp, init_tree,
                                 layernorm, linear, repeat_kv, shard_act)

_MAX_POS = 32768 * 2   # learned decoder positions (stress shapes included)


def _pages(seq_len: int, page_size: int) -> int:
    return max((seq_len + page_size - 1) // page_size, 1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "whisper"
        self.cfg = cfg

    # ------------------------------------------------------------- params --
    def _block_specs(self, L: int, cross: bool):
        cfg = self.cfg
        d, H, D = cfg.d_model, cfg.num_heads, cfg.head_dim
        s = {
            "ln1": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "ln1_b": Spec((L, d), ("layers", None), "zeros", jnp.float32),
            "wq": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "bq": Spec((L, H * D), ("layers", "d_out"), "zeros"),
            "wk": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "wv": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "bv": Spec((L, H * D), ("layers", "d_out"), "zeros"),
            "wo": Spec((L, H * D, d), ("layers", "d_out", "d_in")),
            "bo": Spec((L, d), ("layers", None), "zeros"),
            "ln2": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "ln2_b": Spec((L, d), ("layers", None), "zeros", jnp.float32),
            "w1": Spec((L, d, cfg.d_ff), ("layers", "d_in", "d_out")),
            "b1": Spec((L, cfg.d_ff), ("layers", "d_out"), "zeros"),
            "w2": Spec((L, cfg.d_ff, d), ("layers", "d_out", "d_in")),
            "b2": Spec((L, d), ("layers", None), "zeros"),
        }
        if cross:
            s.update({
                "lnx": Spec((L, d), ("layers", None), "ones", jnp.float32),
                "lnx_b": Spec((L, d), ("layers", None), "zeros", jnp.float32),
                "xwq": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
                "xbq": Spec((L, H * D), ("layers", "d_out"), "zeros"),
                "xwk": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
                "xwv": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
                "xbv": Spec((L, H * D), ("layers", "d_out"), "zeros"),
                "xwo": Spec((L, H * D, d), ("layers", "d_out", "d_in")),
                "xbo": Spec((L, d), ("layers", None), "zeros"),
            })
        return s

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "d_out"),
                          "embed"),
            "pos_dec": Spec((_MAX_POS, cfg.d_model), (None, "d_out"), "embed"),
            "enc": self._block_specs(cfg.encoder_layers, cross=False),
            "enc_ln": Spec((cfg.d_model,), (None,), "ones", jnp.float32),
            "enc_ln_b": Spec((cfg.d_model,), (None,), "zeros", jnp.float32),
            "dec": self._block_specs(cfg.num_layers, cross=True),
            "final_norm": Spec((cfg.d_model,), (None,), "ones", jnp.float32),
            "final_norm_b": Spec((cfg.d_model,), (None,), "zeros",
                                 jnp.float32),
            "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("d_in", "d_out")),
        }

    def init(self, key):
        return init_tree(key, self.param_specs())

    # -------------------------------------------------------------- encoder --
    @staticmethod
    def _sinusoids(length: int, channels: int):
        half = channels // 2
        log_ts = math.log(10000.0) / (half - 1)
        inv = jnp.exp(-log_ts * jnp.arange(half, dtype=jnp.float32))
        t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None]
        return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)

    def encode(self, params, frames):
        """frames (B, F, d) stub embeddings -> encoder states (B, F, d)."""
        cfg = self.cfg
        B, F, d = frames.shape
        H, D = cfg.num_heads, cfg.head_dim
        h = frames.astype(jnp.bfloat16) + \
            self._sinusoids(F, d).astype(jnp.bfloat16)[None]
        h = shard_act(h, ("batch", "seq", None))

        def body(hh, pl):
            x = layernorm(hh, pl["ln1"], pl["ln1_b"], cfg.norm_eps)
            q = linear(x, pl["wq"], pl["bq"]).reshape(B, F, H, D)
            k = linear(x, pl["wk"]).reshape(B, F, H, D)
            v = linear(x, pl["wv"], pl["bv"]).reshape(B, F, H, D)
            o = causal_attention(q, k, v, causal=False)
            hh = hh + linear(o.reshape(B, F, H * D), pl["wo"], pl["bo"])
            x = layernorm(hh, pl["ln2"], pl["ln2_b"], cfg.norm_eps)
            hh = hh + gelu_mlp(x, pl["w1"], pl["b1"], pl["w2"], pl["b2"])
            return shard_act(hh, ("batch", "seq", None)), None

        h, _ = jax.lax.scan(jax.checkpoint(body), h, params["enc"])
        return layernorm(h, params["enc_ln"], params["enc_ln_b"],
                         cfg.norm_eps)

    # ---------------------------------------------------------- cross-attn --
    def _cross_kv(self, pl, enc):
        """Static cross-attention K/V from encoder states (per layer)."""
        cfg = self.cfg
        B, F, _ = enc.shape
        H, D = cfg.num_heads, cfg.head_dim
        k = linear(enc, pl["xwk"]).reshape(B, F, H, D)
        v = linear(enc, pl["xwv"], pl["xbv"]).reshape(B, F, H, D)
        return k, v

    def _cross_attn(self, pl, x, xk, xv, xscale, coopt):
        """x (B,S,d); xk/xv (B,F,H,D) possibly fp8 (+ per-token scale)."""
        cfg = self.cfg
        B, S, _ = x.shape
        H, D = cfg.num_heads, cfg.head_dim
        q = linear(x, pl["xwq"], pl["xbq"]).reshape(B, S, H, D)
        if coopt.opt_kv and xscale is not None:
            xk = dequantize_fp8(xk, xscale[0], axis=-1)
            xv = dequantize_fp8(xv, xscale[1], axis=-1)
        else:
            xk, xv = xk.astype(q.dtype), xv.astype(q.dtype)
        o = causal_attention(q, xk, xv, causal=False)
        return linear(o.reshape(B, S, H * D), pl["xwo"], pl["xbo"])

    # -------------------------------------------------------------- decoder --
    def _decoder(self, params, tokens, cache, coopt, positions, slots,
                 write_cache: bool, long_window: int = 0,
                 page_table=None, cache_len=None, chunk_attn: bool = False):
        cfg = self.cfg
        B, S = tokens.shape
        H, D = cfg.num_heads, cfg.head_dim
        h = params["embed"][tokens].astype(jnp.bfloat16)
        h = h + params["pos_dec"][positions].astype(jnp.bfloat16)
        h = shard_act(h, ("batch", "seq", None))
        if page_table is None:
            page_table = identity_page_table(B, cache["kv"].shape[2])
        page_table = page_table.astype(jnp.int32)
        new_len = (cache["length"] + S if cache_len is None
                   else cache_len).astype(jnp.int32)

        xs = (params["dec"], cache["kv"], cache["xk"], cache["xv"])
        if coopt.opt_kv:
            xs = xs + (cache["scale"], cache["xscale"])

        def body(hh, xs):
            if coopt.opt_kv:
                pl, kv_c, xk, xv, sc_c, xsc = xs
            else:
                pl, kv_c, xk, xv = xs
                sc_c, xsc = None, None
            x = layernorm(hh, pl["ln1"], pl["ln1_b"], cfg.norm_eps)
            q = linear(x, pl["wq"], pl["bq"]).reshape(B, S, H, D)
            k = linear(x, pl["wk"]).reshape(B, S, H, D)
            v = linear(x, pl["wv"], pl["bv"]).reshape(B, S, H, D)
            kv_c, sc_c = write_kv(kv_c, sc_c, k, v, slots, coopt)
            if chunk_attn:
                # continuation chunk: attend the lane's whole cached history
                # (prefix hits + earlier chunks + this one) with true
                # positions — the unified ragged step path; the long_window
                # policy mirrors the decode branch so a token's logits are
                # step-composition independent
                o = paged_chunk_attention(q, kv_c, sc_c, positions,
                                          page_table, coopt,
                                          window=long_window,
                                          sink_pages=cfg.sink_blocks)
            elif S == 1:
                o = paged_decode_attention(
                    q[:, 0], kv_c, sc_c, new_len, coopt=coopt,
                    window=long_window, sink_pages=cfg.sink_blocks,
                    page_table=page_table)[:, None]
            else:
                o = causal_attention(q, k, v)
            hh = hh + linear(o.reshape(B, S, H * D), pl["wo"], pl["bo"])
            x = layernorm(hh, pl["lnx"], pl["lnx_b"], cfg.norm_eps)
            hh = hh + self._cross_attn(pl, x, xk, xv, xsc, coopt)
            x = layernorm(hh, pl["ln2"], pl["ln2_b"], cfg.norm_eps)
            hh = hh + gelu_mlp(x, pl["w1"], pl["b1"], pl["w2"], pl["b2"])
            ys = (kv_c, sc_c) if coopt.opt_kv else (kv_c,)
            return shard_act(hh, ("batch", "seq", None)), ys

        body_fn = jax.checkpoint(body) if S > 1 else body
        h, ys = jax.lax.scan(body_fn, h, xs)
        cache = dict(cache)
        cache["kv"] = ys[0]
        if coopt.opt_kv:
            cache["scale"] = ys[1]
        cache["length"] = new_len
        h = layernorm(h, params["final_norm"], params["final_norm_b"],
                      cfg.norm_eps)
        return h, cache

    def _fill_cross(self, params, cache, enc, coopt):
        """Compute + (optionally fp8-) store per-layer cross K/V."""
        def per_layer(pl):
            return self._cross_kv(pl, enc)

        k, v = jax.lax.map(lambda pl: per_layer(pl), params["dec"])
        cache = dict(cache)
        if coopt.opt_kv:
            qk, sk = quantize_fp8(k, axis=-1)
            qv, sv = quantize_fp8(v, axis=-1)
            cache["xk"], cache["xv"] = qk, qv
            cache["xscale"] = jnp.stack([sk, sv], axis=1)   # (L, 2, B, F, H)
        else:
            cache["xk"], cache["xv"] = k.astype(jnp.bfloat16), \
                v.astype(jnp.bfloat16)
        return cache

    # ------------------------------------------------------------- forward --
    def forward(self, params, batch, coopt: CoOptConfig = COOPT):
        """Teacher-forced decoder logits over text tokens (B, S_text, V)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc = self.encode(params, batch["frames"])
        cache = self.init_cache(B, S, coopt)
        cache = self._fill_cross(params, cache, enc, coopt)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        slots = identity_slots(B, positions, cache["kv"].shape[2],
                               coopt.page_size)
        h, _ = self._decoder(params, tokens, cache, coopt, positions,
                             slots, True)
        return linear(h, params["lm_head"]), {}

    def prefill(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                long_window: int = 0):
        """Prompt prefill — monolithic (whole right-padded prompt) or
        chunked continuation (``positions`` present: absolute per-lane
        positions, the unified ragged step path).

        Cross-attention K/V are computed ONCE per request, on its FIRST
        chunk: pass ``frames`` plus a per-lane bool ``cross_mask`` naming
        the lanes whose cross K/V should be (re)filled; steps with no new
        first chunk omit ``frames`` and skip the encoder entirely."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        chunked = "positions" in batch
        if "frames" in batch:
            enc = self.encode(params, batch["frames"])
            filled = self._fill_cross(params, cache, enc, coopt)
            cm = batch.get("cross_mask")
            if cm is None:
                cache = filled
            else:
                merged = dict(cache)
                keys = [("xk", 1), ("xv", 1)]
                if coopt.opt_kv:
                    keys.append(("xscale", 2))       # (L, 2, B, F, H)
                for key, ax in keys:
                    new = filled[key]
                    m = cm.reshape((1,) * ax + (-1,) +
                                   (1,) * (new.ndim - ax - 1))
                    merged[key] = jnp.where(m, new, cache[key])
                cache = merged
        if chunked:
            positions = batch["positions"].astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if "slot_idx" in batch:
            slots = batch["slot_idx"].astype(jnp.int32)
        else:
            slots = identity_slots(B, positions, cache["kv"].shape[2],
                                   coopt.page_size)
        h, cache = self._decoder(params, tokens, cache, coopt, positions,
                                 slots, True, long_window=long_window,
                                 page_table=batch.get("page_table"),
                                 cache_len=batch.get("cache_len"),
                                 chunk_attn=chunked)
        last_pos = batch.get("last_pos")
        if last_pos is not None:
            if not chunked:
                # pads carry slot -1 (never cached); length = real tokens
                cache["length"] = (last_pos + 1).astype(jnp.int32)
            h_last = jnp.take_along_axis(
                h, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        else:
            h_last = h[:, -1]
        return linear(h_last, params["lm_head"]), cache

    def decode_step(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                    long_window: int = 0):
        B = batch["token"].shape[0]
        positions = batch.get("positions")
        if positions is None:
            positions = cache["length"][:, None]
        positions = positions.astype(jnp.int32)
        if "slot_idx" in batch:
            slots = batch["slot_idx"].astype(jnp.int32)
        else:
            slots = identity_slots(B, positions, cache["kv"].shape[2],
                                   coopt.page_size)
        h, cache = self._decoder(params, batch["token"], cache, coopt,
                                 positions, slots, True,
                                 long_window=long_window,
                                 page_table=batch.get("page_table"),
                                 cache_len=batch.get("cache_len"))
        return linear(h[:, 0], params["lm_head"]), cache

    # ------------------------------------------------------------- caching --
    def cache_shape(self, batch: int, max_len: int, coopt: CoOptConfig,
                    num_shards: int = 1, cache_cfg=None):
        cfg = self.cfg
        P, ps = pool_layout(batch, max_len, coopt, num_shards, cache_cfg)
        L, H, D, F = cfg.num_layers, cfg.num_heads, cfg.head_dim, \
            cfg.num_frames
        out = {
            # decoder self-attn KV: GLOBAL pool (no batch dim); cross-attn
            # K/V are static per-lane encoder projections and stay
            # batch-major (quantized once — DESIGN.md §5).
            "kv": ((L, 2, P, ps, H, D), coopt.kv_dtype,
                   ("layers", None, "pages", None, "kv_heads",
                    "head_dim")),
            "xk": ((L, batch, F, H, D), coopt.kv_dtype,
                   ("layers", "batch", None, "kv_heads", "head_dim")),
            "xv": ((L, batch, F, H, D), coopt.kv_dtype,
                   ("layers", "batch", None, "kv_heads", "head_dim")),
            "length": ((batch,), jnp.int32, ("batch",)),
        }
        if coopt.opt_kv:
            out["scale"] = ((L, 2, P, ps, H), jnp.float32,
                            ("layers", None, "pages", None,
                             "kv_heads"))
            out["xscale"] = ((L, 2, batch, F, H), jnp.float32,
                             ("layers", None, "batch", None, "kv_heads"))
        return out

    def init_cache(self, batch: int, max_len: int, coopt: CoOptConfig,
                   num_shards: int = 1, cache_cfg=None):
        return {k: jnp.zeros(sh, dt)
                for k, (sh, dt, _) in
                self.cache_shape(batch, max_len, coopt,
                                 num_shards=num_shards,
                                 cache_cfg=cache_cfg).items()}

    # -------------------------------------------------------------- specs --
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "decode":
            return {"token": tok(B, 1)}
        out = {"tokens": tok(B, S),
               "frames": jax.ShapeDtypeStruct((B, cfg.num_frames, cfg.d_model),
                                              jnp.bfloat16)}
        if shape.kind == "train":
            out["labels"] = tok(B, S)
        return out

    def param_count(self) -> int:
        from repro.models.layers import param_count
        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        return self.param_count()
