"""Multi-head Latent Attention (deepseek-v2) with a *paged, quantizable latent
cache* — Opt-KV/Opt-Pa applied to MLA (DESIGN.md §5).

The per-token cache entry is the compressed latent c_kv (R) concatenated with
the shared rotary key k_rope (dr): one vector of R+dr floats. Opt-KV
quantizes it to FP8 with DUAL per-token scales (c_kv and k_rope have
different dynamic ranges — ``cache.quant.quantize_latent``); Opt-Pa pages it
and runs block-wise online softmax. Decode and chunk continuation both use
the matrix-absorption form (queries projected into latent space), so K/V are
never materialised per head.

Hot path: under ``coopt.use_kernel`` both ``mla_paged_decode`` and
``mla_chunk_attention`` dispatch to the fused Pallas kernels
(``kernels.paged_latent_decode`` / ``kernels.latent_chunk_prefill``) that
stream latent pages HBM->VMEM once for all H heads straight off the FP8
pool — no ``jnp.take`` full-pool gather. Under a GSPMD mesh the SAME
kernels run per shard against their owned latent page range through the
``kernels.sharded`` shard_map layer (partial softmax states lse-merged
across the pages axes) — there is no separate distributed hot path. The
jnp code below is the numerically-equivalent PARITY REFERENCE used by
tests; the ``w_uk`` absorption and ``w_uv`` expansion live outside the
kernels in both cases, so weights never enter VMEM.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.coopt import CoOptConfig
from repro.cache.quant import dequantize_latent
from repro.models.layers import (apply_rope, causal_attention, linear,
                                 rmsnorm, shard_act)

_NEG = -1e30


def mla_project(x, p, cfg, positions):
    """Shared projections. x (B,S,d) -> q_nope (B,S,H,dn), q_rope (B,S,H,dr),
    latent (B,S,R+dr) (k_rope already rotated)."""
    H, dn, dr, R = (cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                    cfg.kv_lora_rank)
    B, S, _ = x.shape
    q = linear(x, p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = linear(x, p["w_dkv"])                      # (B,S,R+dr)
    c, k_rope = ckv[..., :R], ckv[..., R:]
    c = rmsnorm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    latent = jnp.concatenate([c, k_rope], axis=-1)
    return q_nope, q_rope, latent


def mla_full_attention(q_nope, q_rope, latent, p, cfg, *, window: int = 0):
    """Train/prefill path: expand latent -> per-head K/V, chunked causal attn."""
    H, dn, dr, R, dv = (cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                        cfg.kv_lora_rank, cfg.v_head_dim)
    B, S, _ = latent.shape
    c, k_rope = latent[..., :R], latent[..., R:]
    k_nope = jnp.einsum("btr,rhd->bthd", c, p["w_uk"].reshape(R, H, dn))
    v = jnp.einsum("btr,rhd->bthd", c, p["w_uv"].reshape(R, H, dv))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = causal_attention(q, k, v, window=window)     # (B,S,H,dn+dr->dv? no:)
    return o                                          # (B,S,H,dv)


def _absorb_q(q_nope, p, cfg):
    """W_uk absorption OUTSIDE the kernel: q_lat_h = q_nope_h @ W_uk_h, so
    score_h(t) = <q_lat_h, c_t> + <q_rope_h, k_rope_t> against raw latents."""
    H, dn, R = cfg.num_heads, cfg.qk_nope_head_dim, cfg.kv_lora_rank
    spec = "bshd,rhd->bshr" if q_nope.ndim == 4 else "bhd,rhd->bhr"
    return jnp.einsum(spec, q_nope.astype(jnp.float32),
                      p["w_uk"].reshape(R, H, dn).astype(jnp.float32))


def _expand_o(o_lat, p, cfg, dtype):
    """W_uv expansion OUTSIDE the kernel: latent-space attention output ->
    per-head values. o_lat (..., H, R) -> (..., H, dv)."""
    H, R, dv = cfg.num_heads, cfg.kv_lora_rank, cfg.v_head_dim
    spec = "bshr,rhd->bshd" if o_lat.ndim == 4 else "bhr,rhd->bhd"
    return jnp.einsum(spec, o_lat,
                      p["w_uv"].reshape(R, H, dv).astype(jnp.float32)
                      ).astype(dtype)


def mla_chunk_attention(q_nope, q_rope, lat_pages, scale_pages, positions,
                        page_table, p, cfg, coopt: CoOptConfig, *,
                        window: int = 0, sink_pages: int = 1, seg_q=None,
                        page_seg=None, page_base=None):
    """Matrix-absorption CHUNK attention against the global latent pool —
    the MLA leg of the unified chunked-continuation prefill path.

    q_nope (B,S,H,dn), q_rope (B,S,H,dr) are this chunk's queries with
    absolute ``positions`` (B,S); the chunk's latents are already written to
    the paged cache, so queries attend the lane's WHOLE latent history
    (prefix-cache hits + earlier chunks + this one) in absorbed form
    — K/V are never materialised per head, exactly like decode (a decode
    lane is a chunk of length 1). Under ``coopt.use_kernel`` this dispatches
    to the fused ``latent_chunk_prefill`` Pallas kernel (latent pages
    streamed off the FP8 pool, no host-side gather); the jnp body below is
    the parity reference. ``seg_q``/``page_seg``/``page_base`` enable
    concat-prefill packing (segment-masked attention, per-segment position
    restart — see ``opt_pa.paged_chunk_attention``); None = unpacked.
    Returns (B,S,H,dv)."""
    H, dn, dr, R, dv = (cfg.num_heads, cfg.qk_nope_head_dim,
                        cfg.qk_rope_head_dim, cfg.kv_lora_rank,
                        cfg.v_head_dim)
    B, S = q_nope.shape[:2]
    P_total, ps, _ = lat_pages.shape
    if page_table is None:
        from repro.core.opt_kv import identity_page_table
        page_table = identity_page_table(B, P_total)
    scale = 1.0 / math.sqrt(dn + dr)
    q_lat = _absorb_q(q_nope, p, cfg)                  # (B,S,H,R)

    if coopt.use_kernel:
        from repro.kernels import ops
        o_lat = ops.latent_chunk_prefill(
            q_lat, q_rope.astype(jnp.float32), positions, lat_pages,
            scale_pages if coopt.opt_kv else None, page_table,
            sm_scale=scale, opt_kv=coopt.opt_kv, window=window,
            sink_pages=sink_pages, seg_q=seg_q, page_seg=page_seg,
            page_base=page_base)
        return _expand_o(o_lat, p, cfg, q_nope.dtype)

    q_lat = shard_act(q_lat, ("batch", None, None, "latent"))
    q_rope = shard_act(q_rope.astype(jnp.float32),
                       ("batch", None, None, "latent"))

    pt = jnp.maximum(page_table, 0)
    lat = jnp.take(lat_pages, pt, axis=0)              # (B,NP,ps,R+dr)
    if coopt.opt_kv:
        sc = jnp.take(scale_pages, pt, axis=0)
        lat = dequantize_latent(lat, sc, R, dtype=jnp.float32)
    else:
        lat = lat.astype(jnp.float32)
    T = page_table.shape[1] * ps
    lat = lat.reshape(B, T, R + dr)
    lat_c = shard_act(lat[..., :R], ("batch", None, "latent"))
    lat_r = shard_act(lat[..., R:], ("batch", None, "latent"))

    s = (jnp.einsum("bshr,btr->bhst", q_lat, lat_c)
         + jnp.einsum("bshe,bte->bhst", q_rope, lat_r)) * scale
    s = shard_act(s, ("batch", None, None, None))
    if page_base is not None:
        # packed: key j's position restarts per segment at page_base*ps
        kpos = (page_base.astype(jnp.int32)[:, :, None] * ps
                + jnp.arange(ps, dtype=jnp.int32)[None, None, :]
                ).reshape(B, T)[:, None, :]
    else:
        kpos = jnp.arange(T, dtype=jnp.int32)[None, None, :]
    qpos = positions[:, :, None]
    mask = (kpos <= qpos) & \
        jnp.repeat(page_table >= 0, ps, axis=1)[:, None, :]
    if seg_q is not None:
        mask &= (jnp.repeat(page_seg.astype(jnp.int32), ps, axis=1)[:, None]
                 == seg_q.astype(jnp.int32)[:, :, None])
    if window:
        mask &= (kpos > qpos - window) | (kpos < sink_pages * ps)
    s = jnp.where(mask[:, None], s, _NEG)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", pr, lat_c)
    return _expand_o(o_lat, p, cfg, q_nope.dtype)


def mla_paged_decode(q_nope, q_rope, lat_pages, scale_pages, cache_len, p, cfg,
                     coopt: CoOptConfig, *, window: int = 0, sink_pages: int = 1,
                     page_table=None):
    """Absorbed decode against the GLOBAL latent pool. q_nope/q_rope
    (B,H,dn|dr); lat_pages (P_total,ps,R+dr) shared by all lanes;
    page_table (B,P_lane) physical pages in logical order (default:
    lane-identity partition). Under ``coopt.use_kernel`` this dispatches to
    the fused ``paged_latent_decode`` Pallas kernel — each latent page
    streamed into VMEM once and shared by all H absorbed heads, dual-scale
    FP8 dequant fused at the HBM->VMEM boundary; the jnp body below is the
    parity reference. Returns (B,H,dv)."""
    H, dn, dr, R, dv = (cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                        cfg.kv_lora_rank, cfg.v_head_dim)
    B = q_nope.shape[0]
    P_total, ps, _ = lat_pages.shape
    if page_table is None:
        from repro.core.opt_kv import identity_page_table
        page_table = identity_page_table(B, P_total)
    P = page_table.shape[1]
    scale = 1.0 / math.sqrt(dn + dr)
    # absorb W_uk into q: score_h(t) = <q_lat_h, c_t> + <q_rope_h, k_rope_t>
    q_lat = _absorb_q(q_nope, p, cfg)                  # (B,H,R)

    if coopt.use_kernel:
        # (physical, logical) tables for the scalar-prefetched latent
        # kernel: Eq. 9 filtering / the {sink + window} policy decided
        # host-free, shared with the dense-KV path (decode_page_select).
        from repro.core.opt_kv import decode_page_select
        from repro.kernels import ops
        phys, logical = decode_page_select(cache_len, page_table, ps,
                                           window=window,
                                           sink_pages=sink_pages,
                                           opt_pa=coopt.opt_pa)
        o_lat = ops.paged_latent_decode(
            q_lat, q_rope.astype(jnp.float32), lat_pages,
            scale_pages if coopt.opt_kv else None, cache_len, phys, logical,
            sm_scale=scale, opt_kv=coopt.opt_kv, window=window,
            sink_pages=sink_pages, share_visits=coopt.share_visits)
        return _expand_o(o_lat, p, cfg, q_nope.dtype)

    # (q_lat resharded once per layer to match the model-sharded latent
    # cache — its r dim inherits w_uk's d_in->data otherwise, §Perf P2)
    q_lat = shard_act(q_lat, ("batch", None, "latent"))
    q_rope = shard_act(q_rope, ("batch", None, "latent"))

    def dequant(pages, scales):
        """pages (..., R+dr); scales (..., 2) — separate c / rope scales."""
        if coopt.opt_kv:
            return dequantize_latent(pages, scales, R, dtype=jnp.float32)
        return pages.astype(jnp.float32)

    if window:
        from repro.core.opt_kv import logical_to_physical, window_page_table
        logical = window_page_table(cache_len, P, ps, window, sink_pages)
        phys = logical_to_physical(logical, page_table)
        pt = jnp.maximum(phys, 0)
        lat = jnp.take(lat_pages, pt, axis=0)          # (B,NSel,ps,R+dr)
        sc = (jnp.take(scale_pages, pt, axis=0) if coopt.opt_kv else None)
        lat = dequant(lat, sc)
        lat = lat.reshape(B, -1, R + dr)
        pos = (jnp.maximum(logical, 0)[:, :, None] * ps
               + jnp.arange(ps)[None, None]).reshape(B, -1)
        ok = (pos < cache_len[:, None]) \
            & ((pos >= jnp.maximum(cache_len[:, None] - window, 0))
               | (pos < sink_pages * ps)) \
            & jnp.repeat(phys >= 0, ps, axis=1)
        s = (jnp.einsum("bhr,btr->bht", q_lat, lat[..., :R])
             + jnp.einsum("bhe,bte->bht", q_rope.astype(jnp.float32),
                          lat[..., R:])) * scale
        s = jnp.where(ok[:, None], s, _NEG)
        m = jnp.max(s, axis=-1, keepdims=True)
        pr = jnp.exp(s - m)
        pr = pr / jnp.maximum(jnp.sum(pr, axis=-1, keepdims=True), 1e-30)
        o_lat = jnp.einsum("bht,btr->bhr", pr, lat[..., :R])
        return _expand_o(o_lat, p, cfg, q_nope.dtype)

    # dense path: gather the lane's pages in logical order, then reduce —
    # token j of the gathered view is logical position j.
    pt = jnp.maximum(page_table, 0)
    lat_lane = jnp.take(lat_pages, pt, axis=0)         # (B,P,ps,R+dr)
    sc_lane = (jnp.take(scale_pages, pt, axis=0) if coopt.opt_kv else None)
    valid = jnp.repeat(page_table >= 0, ps, axis=1)    # (B, P*ps)

    pg = coopt.page_group if coopt.opt_pa else P
    while P % pg:
        pg //= 2
    pg = max(pg, 1)
    NG, T = P // pg, pg * ps
    lat_g = lat_lane.reshape(B, NG, T, R + dr)
    sc_g = sc_lane.reshape(B, NG, T, 2) if coopt.opt_kv else None
    valid_g = valid.reshape(B, NG, T)

    def body(carry, g):
        m, l, acc = carry
        lat = dequant(lat_g[:, g], None if sc_g is None else sc_g[:, g])
        # keep the dequantized latent model-sharded along its width and
        # force the (tiny) score tensor to be the all-reduced partial sum —
        # without this GSPMD all-gathers the full latent page group per
        # scan step (EXPERIMENTS.md §Perf P2)
        lat_c = shard_act(lat[..., :R], ("batch", None, "latent"))
        lat_r = shard_act(lat[..., R:], ("batch", None, "latent"))
        s = (jnp.einsum("bhr,btr->bht", q_lat, lat_c)
             + jnp.einsum("bhe,bte->bht", q_rope.astype(jnp.float32),
                          lat_r)) * scale
        s = shard_act(s, ("batch", None, None))
        pos = g * T + jnp.arange(T)[None, None, :]
        ok = (pos < cache_len[:, None, None]) & valid_g[:, g][:, None, :]
        s = jnp.where(ok, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m - m_new)
        pr = jnp.exp(s - m_new)
        l = l * corr[..., 0] + jnp.sum(pr, axis=-1)
        acc = acc * corr + shard_act(
            jnp.einsum("bht,btr->bhr", pr, lat_c),
            ("batch", None, "latent"))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    a0 = jnp.zeros((B, H, R), jnp.float32)
    if NG == 1:
        (m, l, acc), _ = body((m0, l0, a0), 0)
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(NG))
    o_lat = acc / jnp.maximum(l, 1e-30)[..., None]
    return _expand_o(o_lat, p, cfg, q_nope.dtype)
