"""Mixture-of-Experts FFN (mixtral, deepseek-v2) — gather-based dispatch.

Routing is computed per batch row (capacity C = ceil(S * top_k / E * cf)),
tokens are gathered per expert, run through the expert SwiGLU as a batched
matmul (MXU-friendly (E, C, d) x (E, d, ff)), and combined with the router
weights. Tokens beyond capacity are dropped (standard capacity-factor MoE).
Aux outputs: load-balance loss + router z-loss (used by train_step).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import linear, shard_act


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def _route(logits, top_k: int, capacity: int):
    """logits (B,S,E) -> (idx (B,E,C) token positions, comb (B,E,C) weights,
    aux)."""
    B, S, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)               # (B,S,K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalise

    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)     # (B,S,K,E)
    mask = jnp.sum(onehot, axis=2)                           # (B,S,E) 0/1
    # position of each token in its expert's queue (within the batch row)
    pos = jnp.cumsum(mask, axis=1) - 1.0                     # (B,S,E)
    keep = (pos < capacity) & (mask > 0)
    pos = pos.astype(jnp.int32)

    # scatter token position s into (e, pos) slots
    tok = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, E))
    flat_slot = jnp.where(keep, jnp.arange(E)[None, None, :] * capacity + pos,
                          E * capacity)                      # OOB -> dropped
    idx = jnp.full((B, E * capacity + 1), S, jnp.int32)      # S = pad token id
    idx = idx.at[jnp.arange(B)[:, None], flat_slot.reshape(B, -1)].set(
        tok.reshape(B, -1), mode="drop")
    idx = idx[:, :-1].reshape(B, E, capacity)

    # combine weight of the token occupying each (e, c) slot
    w_tok_e = jnp.sum(top_p[..., None] * onehot, axis=2)     # (B,S,E)
    w_tok_e = jnp.where(keep, w_tok_e, 0.0)
    w_pad = jnp.concatenate([w_tok_e, jnp.zeros((B, 1, E))], axis=1)
    comb = w_pad[jnp.arange(B)[:, None, None], idx,
                 jnp.arange(E)[None, :, None]]               # (B,E,C)

    # aux losses (Switch-style)
    frac_tokens = jnp.mean(mask, axis=1)                     # (B,E)
    frac_probs = jnp.mean(probs, axis=1)                     # (B,E)
    lb = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32),
                                             axis=-1)))
    dropped = 1.0 - jnp.sum(keep) / jnp.maximum(jnp.sum(mask), 1.0)
    return idx, comb, MoEAux(lb, z, dropped)


def moe_ffn(x, wr, wg, wu, wd, *, top_k: int, capacity_factor: float = 1.25,
            shared: Optional[tuple] = None):
    """x (B,S,d); wr (d,E); wg/wu (E,d,ff); wd (E,ff,d).

    shared: optional (wg_s, wu_s, wd_s) always-on shared-expert SwiGLU.
    Returns (out (B,S,d), MoEAux).
    """
    B, S, d = x.shape
    E = wr.shape[-1]
    capacity = max(int(math.ceil(S * top_k / E * capacity_factor)), 1)
    capacity = min(capacity, S)

    logits = linear(x, wr)                                   # (B,S,E)
    idx, comb, aux = _route(logits, top_k, capacity)

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xin = jnp.take_along_axis(x_pad[:, None], idx[..., None], axis=2)  # (B,E,C,d)
    # pin the dispatched tokens to batch sharding — without this GSPMD
    # replicates xin across the mesh and all-reduces full-size f32 copies
    # per layer (EXPERIMENTS.md §Perf P1)
    xin = shard_act(xin, ("batch", "experts", None, None))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, wg)) \
        * jnp.einsum("becd,edf->becf", xin, wu)
    h = shard_act(h, ("batch", "experts", None, "ffn"))
    y = jnp.einsum("becf,efd->becd", h, wd)                  # (B,E,C,d)
    y = y * comb[..., None].astype(y.dtype)
    y = shard_act(y, ("batch", "experts", None, None))

    # combine back: scatter-add expert outputs to token positions
    out = jnp.zeros((B, S + 1, d), y.dtype)
    out = out.at[jnp.arange(B)[:, None], idx.reshape(B, -1)].add(
        y.reshape(B, -1, d))
    out = out[:, :S]

    if shared is not None:
        wg_s, wu_s, wd_s = shared
        out = out + linear(jax.nn.silu(linear(x, wg_s)) * linear(x, wu_s), wd_s)
    return out.astype(x.dtype), aux
