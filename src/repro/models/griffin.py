"""Griffin / RecurrentGemma — RG-LRU + local-attention hybrid, pattern 1 attn
per 2 recurrent layers [arXiv:2402.19427].

LLM-CoOpt applicability (DESIGN.md §5): the local-attention layers carry a
(windowed) paged KV cache — Opt-KV (fp8 + SkipSet), Opt-GQA (kv=1 -> MQA
grouping) and Opt-Pa (valid-block filtering + online softmax) all apply there.
RG-LRU layers carry O(1) recurrent state (kept f32 — quantizing the recurrence
would compound error across steps and is not claimed by the paper).

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    a_t = exp(c * r_t * (-softplus(LAMBDA)))            # c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Train/prefill realises the linear recurrence with ``lax.associative_scan``
(TPU-idiomatic parallel prefix, O(log T) depth); decode is the O(1) step.

Layer layout for scan-over-layers: recurrent layers and attention layers are
stacked separately; we scan over pattern *periods* (rec, rec, attn), plus a
trailing mini-scan for ``num_layers % 3`` leftover recurrent layers.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.core.opt_kv import (identity_page_table, identity_slots,
                               pool_layout, write_kv)
from repro.core.opt_pa import paged_chunk_attention, paged_decode_attention
from repro.models.layers import (Spec, apply_rope, causal_attention, init_tree,
                                 linear, repeat_kv, rmsnorm, shard_act)

_C = 8.0  # RG-LRU temperature


def _pages(seq_len: int, page_size: int) -> int:
    return max((seq_len + page_size - 1) // page_size, 1)


class GriffinModel:
    # batch-major cache leaves carrying cross-chunk recurrent state: the
    # engine zeroes them on a request's first chunk and snapshots them at
    # committed page boundaries (prefix-cache resume points)
    recurrent_leaves = ("conv", "lru")

    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "griffin"
        self.cfg = cfg
        self.n_periods = cfg.num_layers // 3
        self.n_trail = cfg.num_layers % 3          # leftover rec layers
        self.n_rec = self.n_periods * 2 + self.n_trail
        self.n_attn = self.n_periods

    # ------------------------------------------------------------- params --
    def _rec_specs(self, L: int):
        cfg = self.cfg
        d, W = cfg.d_model, cfg.lru_width
        cw = cfg.conv1d_width
        return {
            "ln": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "w_gelu": Spec((L, d, W), ("layers", "d_in", "d_out")),
            "w_rec_in": Spec((L, d, W), ("layers", "d_in", "d_out")),
            "conv_w": Spec((L, cw, W), ("layers", None, "d_out")),
            "conv_b": Spec((L, W), ("layers", "d_out"), "zeros"),
            "w_a": Spec((L, W, W), ("layers", "d_in", "d_out")),
            "w_x": Spec((L, W, W), ("layers", "d_in", "d_out")),
            "lam": Spec((L, W), ("layers", "d_out"), "ones", jnp.float32),
            "w_rec_out": Spec((L, W, d), ("layers", "d_out", "d_in")),
            "ln_f": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "wg": Spec((L, d, cfg.d_ff), ("layers", "d_in", "d_out")),
            "wu": Spec((L, d, cfg.d_ff), ("layers", "d_in", "d_out")),
            "wd": Spec((L, cfg.d_ff, d), ("layers", "d_out", "d_in")),
        }

    def _attn_specs(self, L: int):
        cfg = self.cfg
        d, H, Hkv, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        return {
            "ln": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "wq": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "wk": Spec((L, d, Hkv * D), ("layers", "d_in", "d_out")),
            "wv": Spec((L, d, Hkv * D), ("layers", "d_in", "d_out")),
            "wo": Spec((L, H * D, d), ("layers", "d_out", "d_in")),
            "ln_f": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "wg": Spec((L, d, cfg.d_ff), ("layers", "d_in", "d_out")),
            "wu": Spec((L, d, cfg.d_ff), ("layers", "d_in", "d_out")),
            "wd": Spec((L, cfg.d_ff, d), ("layers", "d_out", "d_in")),
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "d_out"),
                          "embed"),
            "rec": self._rec_specs(self.n_rec),
            "attn": self._attn_specs(self.n_attn),
            "final_norm": Spec((cfg.d_model,), (None,), "ones", jnp.float32),
            "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("d_in", "d_out")),
        }

    def init(self, key):
        return init_tree(key, self.param_specs())

    # ---------------------------------------------------------- RG-LRU core --
    def _rg_lru(self, pl, x, h0, valid=None):
        """x (B,S,W) f32; h0 (B,W) f32. Returns (y (B,S,W), h_S).
        ``valid`` (B,S) freezes the recurrence on padding (a=1, b=0)."""
        log_a0 = -jax.nn.softplus(pl["lam"].astype(jnp.float32))  # (W,) < 0
        r = jax.nn.sigmoid(linear(x, pl["w_a"]).astype(jnp.float32))
        i = jax.nn.sigmoid(linear(x, pl["w_x"]).astype(jnp.float32))
        log_a = _C * r * log_a0                                   # (B,S,W)
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
            * (i * x.astype(jnp.float32))
        if valid is not None:
            vm = valid[:, :, None]
            a = jnp.where(vm, a, 1.0)
            b = b * vm
        if x.shape[1] == 1:
            h = a[:, 0] * h0 + b[:, 0]
            return h[:, None], h
        # associative scan: h_t = a_t h_{t-1} + b_t
        b0 = b.at[:, 0].add(a[:, 0] * h0)

        def comb(u, v):
            au, bu = u
            av, bv = v
            return au * av, av * bu + bv

        _, hs = jax.lax.associative_scan(comb, (a, b0), axis=1)
        return hs, hs[:, -1]

    def _rec_block(self, pl, x, conv_state, h0, valid=None, last_pos=None):
        """Recurrent block. x (B,S,d). Returns (out, new conv_state, h_S)."""
        cfg = self.cfg
        B, S, _ = x.shape
        cw = cfg.conv1d_width
        gel = jax.nn.gelu(linear(x, pl["w_gelu"]))
        u = linear(x, pl["w_rec_in"])                    # (B,S,W)
        if valid is not None:  # padding contributes nothing to the conv taps
            u = u * valid[:, :, None].astype(u.dtype)
        # causal depthwise conv1d
        upad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
        w = pl["conv_w"].astype(jnp.float32)             # (cw, W)
        conv = sum(upad[:, k:k + S].astype(jnp.float32) * w[k]
                   for k in range(cw))
        conv = (conv + pl["conv_b"].astype(jnp.float32)).astype(u.dtype)
        if last_pos is None:
            new_conv_state = upad[:, S:S + cw - 1]
        else:  # last cw-1 REAL inputs end at last_pos (right padding)
            idx = last_pos[:, None] + 2 - cw + jnp.arange(cw - 1)[None]
            idx = jnp.maximum(idx + (cw - 1), 0)         # upad offset
            new_conv_state = jnp.take_along_axis(
                upad, idx[:, :, None].astype(jnp.int32), axis=1)
        y, h = self._rg_lru(pl, conv, h0, valid)
        y = (y.astype(x.dtype) * gel)
        return linear(y, pl["w_rec_out"]), new_conv_state, h

    # --------------------------------------------------------- attn blocks --
    def _attn_full(self, pl, x, positions, coopt):
        cfg = self.cfg
        B, S, _ = x.shape
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = linear(x, pl["wq"]).reshape(B, S, H, D)
        k = linear(x, pl["wk"]).reshape(B, S, Hkv, D)
        v = linear(x, pl["wv"]).reshape(B, S, Hkv, D)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if coopt.opt_gqa or Hkv == H:
            o = causal_attention(q, k, v, window=cfg.local_window)
        else:
            o = causal_attention(q, repeat_kv(k, H // Hkv),
                                 repeat_kv(v, H // Hkv),
                                 window=cfg.local_window)
        return linear(o.reshape(B, S, H * D), pl["wo"]), k, v

    def _mlp(self, pl, x):
        h = jax.nn.gelu(linear(x, pl["wg"])) * linear(x, pl["wu"])
        return linear(h, pl["wd"])

    # ------------------------------------------------------------- forward --
    def _period_scan(self, params, cache, h, positions, slots, coopt, attn_fn,
                     valid=None, last_pos=None):
        """Scan over (rec, rec, attn) periods + trailing rec layers.

        attn_fn(pl, x, kv_c, sc_c) -> (attn_out, kv_c, sc_c)."""
        cfg = self.cfg
        NP, NT = self.n_periods, self.n_trail
        rec_p = params["rec"]
        rec_main = jax.tree.map(
            lambda a: a[:NP * 2].reshape(NP, 2, *a.shape[1:]), rec_p)
        rec_trail = jax.tree.map(lambda a: a[NP * 2:], rec_p)

        cs, hs = cache["conv"], cache["lru"]
        cs_main = cs[:NP * 2].reshape(NP, 2, *cs.shape[1:])
        hs_main = hs[:NP * 2].reshape(NP, 2, *hs.shape[1:])
        kv = cache["kv"]
        sc = cache.get("scale") if coopt.opt_kv else None

        def one_rec(hh, pl, c0, h0):
            x = rmsnorm(hh, pl["ln"], cfg.norm_eps)
            a, c1, h1 = self._rec_block(pl, x, c0, h0, valid, last_pos)
            hh = hh + a
            hh = hh + self._mlp(pl, rmsnorm(hh, pl["ln_f"], cfg.norm_eps))
            return shard_act(hh, ("batch", "seq", None)), c1, h1

        def period(carry, xs):
            hh = carry
            if coopt.opt_kv:
                rp, c0, h0, ap, kv_c, sc_c = xs
            else:
                rp, c0, h0, ap, kv_c = xs
                sc_c = None
            c_out, h_out = [], []
            for j in range(2):
                rj = jax.tree.map(lambda a: a[j], rp)
                hh, c1, h1 = one_rec(hh, rj, c0[j], h0[j])
                c_out.append(c1)
                h_out.append(h1)
            x = rmsnorm(hh, ap["ln"], cfg.norm_eps)
            a, kv_c, sc_c = attn_fn(ap, x, kv_c, sc_c)
            hh = hh + a
            hh = hh + self._mlp(ap, rmsnorm(hh, ap["ln_f"], cfg.norm_eps))
            hh = shard_act(hh, ("batch", "seq", None))
            ys = (jnp.stack(c_out), jnp.stack(h_out), kv_c) + \
                ((sc_c,) if coopt.opt_kv else ())
            return hh, ys

        xs = (rec_main, cs_main, hs_main, params["attn"], kv) + \
            ((sc,) if coopt.opt_kv else ())
        period_fn = jax.checkpoint(period) if h.shape[1] > 1 else period
        h, ys = jax.lax.scan(period_fn, h, xs)
        new_conv = ys[0].reshape(NP * 2, *cs.shape[1:])
        new_lru = ys[1].reshape(NP * 2, *hs.shape[1:])
        new_kv = ys[2]
        new_sc = ys[3] if coopt.opt_kv else None

        # trailing rec layers (static count <= 2)
        trail_c, trail_h = [], []
        for j in range(NT):
            rj = jax.tree.map(lambda a: a[j], rec_trail)
            h, c1, h1 = one_rec(h, rj, cs[NP * 2 + j], hs[NP * 2 + j])
            trail_c.append(c1)
            trail_h.append(h1)
        if NT:
            new_conv = jnp.concatenate([new_conv, jnp.stack(trail_c)], 0)
            new_lru = jnp.concatenate([new_lru, jnp.stack(trail_h)], 0)

        cache = dict(cache)
        cache["conv"], cache["lru"], cache["kv"] = new_conv, new_lru, new_kv
        if coopt.opt_kv:
            cache["scale"] = new_sc
        return h, cache

    def forward(self, params, batch, coopt: CoOptConfig = COOPT):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens].astype(jnp.bfloat16)
        h = shard_act(h, ("batch", "seq", None))
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        cache = self.init_cache(B, S, coopt)
        slots = positions.astype(jnp.int32)

        def attn_fn(ap, x, kv_c, sc_c):
            # training: in-flight attention only, no cache writes
            a, _, _ = self._attn_full(ap, x, positions, coopt)
            return a, kv_c, sc_c

        h, _ = self._period_scan(params, cache, h, positions, slots, coopt,
                                 attn_fn)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return linear(h, params["lm_head"]), {}

    def prefill(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                long_window: int = 0):
        """Prompt prefill (``long_window`` accepted for engine-call
        uniformity; local attention always uses ``cfg.local_window``,
        matching ``decode_step``). With ``batch["positions"]`` (B,S) this
        is a CONTINUATION chunk (the unified ragged step path): the recurrent
        state in the cache is the state after the previous chunk and is
        threaded straight through (state after chunk k feeds chunk k+1),
        while the local-attention layers write this chunk's K/V to the paged
        pool and attend the lane's whole cached history with true positions
        — a decode lane is a chunk of length 1."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens].astype(jnp.bfloat16)
        h = shard_act(h, ("batch", "seq", None))
        chunked = "positions" in batch
        if chunked:
            positions = batch["positions"].astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        P_total = cache["kv"].shape[2]
        page_table = batch.get("page_table")
        if "slot_idx" in batch:
            slots = batch["slot_idx"].astype(jnp.int32)
        else:
            slots = identity_slots(B, positions, P_total, coopt.page_size)
        valid = batch.get("pad_mask")
        last_pos = batch.get("last_pos")
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

        def attn_fn(ap, x, kv_c, sc_c):
            if chunked:
                q = linear(x, ap["wq"]).reshape(B, S, H, D)
                k = linear(x, ap["wk"]).reshape(B, S, Hkv, D)
                v = linear(x, ap["wv"]).reshape(B, S, Hkv, D)
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                kv_c, sc_c = write_kv(kv_c, sc_c, k, v, slots, coopt)
                o = paged_chunk_attention(
                    q, kv_c, sc_c, positions, page_table, coopt,
                    window=cfg.local_window, sink_pages=cfg.sink_blocks)
                return linear(o.reshape(B, S, H * D), ap["wo"]), kv_c, sc_c
            a, k, v = self._attn_full(ap, x, positions, coopt)
            kv_c, sc_c = write_kv(kv_c, sc_c, k, v, slots, coopt)
            return a, kv_c, sc_c

        h, cache = self._period_scan(params, cache, h, positions, slots,
                                     coopt, attn_fn, valid, last_pos)
        new_len = batch.get("cache_len")
        if new_len is None:
            added = S if valid is None else jnp.sum(valid, axis=1)
            new_len = cache["length"] + added
        cache["length"] = new_len.astype(jnp.int32)
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        if last_pos is not None:
            h_last = jnp.take_along_axis(
                h, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        else:
            h_last = h[:, -1]
        return linear(h_last, params["lm_head"]), cache

    def decode_step(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                    long_window: int = 0):
        cfg = self.cfg
        h = params["embed"][batch["token"]].astype(jnp.bfloat16)
        B = h.shape[0]
        positions = batch.get("positions")
        if positions is None:
            positions = cache["length"][:, None]
        positions = positions.astype(jnp.int32)
        P_total = cache["kv"].shape[2]
        page_table = batch.get("page_table")
        if page_table is None:
            page_table = identity_page_table(B, P_total)
        page_table = page_table.astype(jnp.int32)
        if "slot_idx" in batch:
            slots = batch["slot_idx"].astype(jnp.int32)
        else:
            slots = identity_slots(B, positions, P_total, coopt.page_size)
        new_len = batch.get("cache_len")
        if new_len is None:
            new_len = cache["length"] + 1
        new_len = new_len.astype(jnp.int32)
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

        def attn_fn(ap, x, kv_c, sc_c):
            q = linear(x, ap["wq"]).reshape(B, 1, H, D)
            k = linear(x, ap["wk"]).reshape(B, 1, Hkv, D)
            v = linear(x, ap["wv"]).reshape(B, 1, Hkv, D)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            kv_c, sc_c = write_kv(kv_c, sc_c, k, v, slots, coopt)
            o = paged_decode_attention(
                q[:, 0], kv_c, sc_c, new_len, coopt=coopt,
                window=cfg.local_window, sink_pages=cfg.sink_blocks,
                page_table=page_table)
            return linear(o.reshape(B, 1, H * D), ap["wo"]), kv_c, sc_c

        h, cache = self._period_scan(params, cache, h, positions, slots,
                                     coopt, attn_fn)
        cache["length"] = new_len
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return linear(h[:, 0], params["lm_head"]), cache

    # ------------------------------------------------------------- caching --
    def cache_shape(self, batch: int, max_len: int, coopt: CoOptConfig,
                    num_shards: int = 1, cache_cfg=None):
        cfg = self.cfg
        # GLOBAL-POOL layout for the attention layers' paged KV (see
        # transformer.TransformerModel.cache_shape), pages padded to tile
        # over the KV shards; recurrent state (conv taps, RG-LRU h) is O(1)
        # per lane and stays batch-major.
        P, ps = pool_layout(batch, max_len, coopt, num_shards, cache_cfg)
        Hkv, D, W = cfg.num_kv_heads, cfg.head_dim, cfg.lru_width
        out = {
            "conv": ((self.n_rec, batch, cfg.conv1d_width - 1, W), jnp.bfloat16,
                     ("layers", "batch", None, "d_model")),
            "lru": ((self.n_rec, batch, W), jnp.float32,
                    ("layers", "batch", "d_model")),
            "kv": ((self.n_attn, 2, P, ps, Hkv, D), coopt.kv_dtype,
                   ("layers", None, "pages", None, "kv_heads",
                    "head_dim")),
            "length": ((batch,), jnp.int32, ("batch",)),
        }
        if coopt.opt_kv:
            out["scale"] = ((self.n_attn, 2, P, ps, Hkv), jnp.float32,
                            ("layers", None, "pages", None,
                             "kv_heads"))
        return out

    def init_cache(self, batch: int, max_len: int, coopt: CoOptConfig,
                   num_shards: int = 1, cache_cfg=None):
        return {k: jnp.zeros(sh, dt)
                for k, (sh, dt, _) in
                self.cache_shape(batch, max_len, coopt,
                                 num_shards=num_shards,
                                 cache_cfg=cache_cfg).items()}

    # -------------------------------------------------------------- specs --
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "decode":
            return {"token": tok(B, 1)}
        out = {"tokens": tok(B, S)}
        if shape.kind == "train":
            out["labels"] = tok(B, S)
        return out

    def param_count(self) -> int:
        from repro.models.layers import param_count
        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        return self.param_count()
