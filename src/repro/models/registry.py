"""Model registry: family -> implementation class.

Every model exposes the same engine-facing protocol:
  param_specs() / init(key)                    — parameter pytree (stacked layers)
  forward(params, batch, coopt)                — teacher-forced logits (+aux)
  prefill(params, batch, cache, coopt)         — last-token logits + filled cache
  decode_step(params, batch, cache, coopt, long_window) — one-token step
  cache_shape(batch, max_len, coopt, num_shards=1) / init_cache(...)
      — num_shards pads the paged-KV pages axis so it tiles evenly over
        the mesh (pod, data) shards of the sharded pool
  input_specs(shape)                           — ShapeDtypeStructs per input
"""
from __future__ import annotations

from functools import lru_cache

from repro.configs.base import ModelConfig


@lru_cache(maxsize=64)
def _get(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "mla", "vlm"):
        from repro.models.transformer import TransformerModel
        return TransformerModel(cfg)
    if cfg.family == "rwkv6":
        from repro.models.rwkv6 import RWKV6Model
        return RWKV6Model(cfg)
    if cfg.family == "griffin":
        from repro.models.griffin import GriffinModel
        return GriffinModel(cfg)
    if cfg.family == "whisper":
        from repro.models.whisper import WhisperModel
        return WhisperModel(cfg)
    raise KeyError(f"unknown family {cfg.family!r}")


def get_model(cfg: ModelConfig):
    return _get(cfg)
