"""RWKV-6 "Finch" — attention-free, data-dependent decay [arXiv:2404.05892].

LLM-CoOpt's three techniques are inapplicable here (no KV cache to quantize
or page, no query heads to group) — see DESIGN.md §5. The model is implemented
WITHOUT the technique, per the task instructions, but still first-class in the
framework: paged-cache plumbing is replaced by an O(1) recurrent state pytree
(per-layer (B, H, D, D) wkv state + (B, d) token-shift buffers), so
``prefill``/``decode_step`` have the same engine-facing signature as the
attention families.

Recurrence (per head, head_dim D, diag decay w_t in (0,1)):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state (D, D))
    o_t = (u ⊙ k_t) (q_t · v_t accumulation) ... realised as
    o_t = q_t^T (S_{t-1} + diag(u) k_t v_t^T)
where w_t = exp(-exp(ww_t)) is *data-dependent* (the Finch contribution) via
the low-rank "time-mix" MLP, and u is the per-head bonus for the current token.

Training/prefill uses a chunked scan: within a chunk the contribution of the
running state is a matmul, and the intra-chunk part is a masked quadratic form
— the standard linear-attention chunked form, O(T/C · C² + T·D²).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.models.layers import (Spec, init_tree, linear, rmsnorm, shard_act)

_LORA = 64        # low-rank dim of the data-dependent decay MLP
_CHUNK = 32       # chunked-scan length — bounds the (C,C,H,D) pairwise-decay
                  # tensor of the intra-chunk term (exact, clamp-free)


class RWKV6Model:
    # batch-major cache leaves carrying cross-chunk recurrent state: the
    # engine zeroes them on a request's first chunk and snapshots them at
    # committed page boundaries (prefix-cache resume points)
    recurrent_leaves = ("wkv", "shift_t", "shift_c")

    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "rwkv6"
        self.cfg = cfg

    # ------------------------------------------------------------- params --
    def param_specs(self):
        cfg = self.cfg
        L, d, H, D = cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.head_dim
        lay = {
            "ln1": Spec((L, d), ("layers", None), "ones", jnp.float32),
            "ln2": Spec((L, d), ("layers", None), "ones", jnp.float32),
            # token-shift mix coefficients (r,k,v,w,g) — Finch "ddlerp" base
            "mix": Spec((L, 5, d), ("layers", None, None), "uniform1",
                        jnp.float32),
            "wr": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "wk": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "wv": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "wg": Spec((L, d, H * D), ("layers", "d_in", "d_out")),
            "wo": Spec((L, H * D, d), ("layers", "d_out", "d_in")),
            # data-dependent decay: w = base + B @ tanh(A @ x)
            "w_base": Spec((L, H * D), ("layers", "d_out"), "zeros",
                           jnp.float32),
            "dd_a": Spec((L, d, _LORA), ("layers", "d_in", None)),
            "dd_b": Spec((L, _LORA, H * D), ("layers", None, "d_out")),
            "u": Spec((L, H, D), ("layers", None, None), "zeros", jnp.float32),
            "gn": Spec((L, H * D), ("layers", None), "ones", jnp.float32),
            # channel-mix (FFN): relu² k, sigmoid-gated
            "ck": Spec((L, d, cfg.d_ff), ("layers", "d_in", "d_out")),
            "cv": Spec((L, cfg.d_ff, d), ("layers", "d_out", "d_in")),
            "cr": Spec((L, d, d), ("layers", "d_in", "d_out")),
        }
        return {
            "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "d_out"),
                          "embed"),
            "layers": lay,
            "final_norm": Spec((cfg.d_model,), (None,), "ones", jnp.float32),
            "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("d_in", "d_out")),
        }

    def init(self, key):
        return init_tree(key, self.param_specs())

    # ------------------------------------------------------- wkv recurrence --
    def _proj(self, pl, x, x_prev):
        """Token-shifted projections. x (B,S,d); x_prev (B,1,d) = token before
        x[0]. Returns r,k,v,g (B,S,H,D), w (B,S,H,D) decay in (0,1),
        and the new shift buffer (B,1,d)."""
        cfg = self.cfg
        B, S, d = x.shape
        H, D = cfg.num_heads, cfg.head_dim
        xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)     # shifted by 1
        mix = pl["mix"].astype(x.dtype)                        # (5,d)

        def mixed(i):
            return x + (xs - x) * mix[i]

        r = linear(mixed(0), pl["wr"]).reshape(B, S, H, D)
        k = linear(mixed(1), pl["wk"]).reshape(B, S, H, D)
        v = linear(mixed(2), pl["wv"]).reshape(B, S, H, D)
        g = linear(mixed(4), pl["wg"]).reshape(B, S, H, D)
        # data-dependent decay (Finch): per-token, per-channel
        ww = pl["w_base"].astype(jnp.float32) + \
            linear(jnp.tanh(linear(mixed(3), pl["dd_a"])),
                   pl["dd_b"]).astype(jnp.float32)
        w = jnp.exp(-jnp.exp(jnp.clip(ww, -20.0, 8.0))).reshape(B, S, H, D)
        return r, k, v, g, w, x[:, -1:]

    @staticmethod
    def _wkv_chunked(r, k, v, w, u, state):
        """Chunked linear-recurrence. r,k,v,w (B,S,H,D) f32; u (H,D);
        state (B,H,D,D). Returns (out (B,S,H,D), new state).

        Within a chunk: decay-weighted quadratic form + inherited-state matmul.
        """
        B, S, H, D = r.shape
        C = _CHUNK if S % _CHUNK == 0 else S
        N = S // C
        r = r.reshape(B, N, C, H, D)
        k = k.reshape(B, N, C, H, D)
        v = v.reshape(B, N, C, H, D)
        w = w.reshape(B, N, C, H, D)
        logw = jnp.log(jnp.maximum(w, 1e-20))

        def chunk(state, xs):
            rc, kc, vc, wc, lwc = xs                          # (B,C,H,D)
            cum = jnp.cumsum(lwc, axis=1)                     # prod of w up to t (incl)
            # decay from chunk start to just BEFORE t: cum_{t-1}. All
            # exponents used below are true non-positive log-decays, so exp
            # never overflows and underflow-to-zero is the exact limit — no
            # clamping (a clamp breaks RELATIVE decays between nearby tokens
            # once the cumulative passes it).
            before = cum - lwc                                # <= 0
            r_d = rc * jnp.exp(before)                        # r_t * W_{0..t-1}
            k_d = kc * jnp.exp(cum[:, -1:] - cum)             # <= 0 exponent
            # inter-chunk: state contribution
            inter = jnp.einsum("bchd,bhde->bche", r_d, state)
            # intra-chunk: pairwise decay computed DIRECTLY —
            # exponent(t, s) = cum_{t-1} - cum_s = sum_{s<u<t} logw_u <= 0
            # (k_s is decayed by w_{s+1}..w_{t-1}, same convention as the
            # sequential step). (B,C,C,H,D) is bounded by _CHUNK=32.
            pair = before[:, :, None] - cum[:, None, :]       # (B,C,C,H,D)
            att = jnp.einsum("bthd,bshd,btshd->bhts", rc, kc,
                             jnp.exp(jnp.minimum(pair, 0.0)))
            tri = jnp.tril(jnp.ones((C, C)), -1)
            att = att * tri[None, None]
            intra = jnp.einsum("bhts,bshd->bthd", att, vc)
            # current-token bonus u
            bonus = jnp.einsum("bchd,bchd->bch", rc, u[None, None] * kc)
            out = inter + intra + bonus[..., None] * vc
            # state update
            new_state = state * jnp.exp(cum[:, -1])[..., None] + \
                jnp.einsum("bchd,bche->bhde", k_d, vc)
            return new_state, out

        # nested remat: without it the backward stashes the (B,C,C,H,D)
        # pairwise-decay tensor for every chunk-scan trip (~17 GiB/dev on
        # train_4k); recomputing it per chunk is two cheap einsums
        state, out = jax.lax.scan(
            jax.checkpoint(chunk), state,
            (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
             jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0),
             jnp.moveaxis(logw, 1, 0)))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, D)
        return out, state

    @staticmethod
    def _wkv_step(r, k, v, w, u, state):
        """One-token recurrence. r,k,v,w (B,H,D); state (B,H,D,D)."""
        kv = jnp.einsum("bhd,bhe->bhde", k, v)
        out = jnp.einsum("bhd,bhde->bhe", r, state + u[None, :, :, None] * kv)
        state = state * w[..., None] + kv
        return out, state

    def _time_mix(self, pl, x, shift, state, valid=None, last_pos=None):
        """x (B,S,d) -> (out, new_shift, new_state). ``valid`` (B,S) freezes
        the recurrence on padding tokens (w=1, k=0 — the state passes
        through untouched, exactly as if the token were never fed)."""
        cfg = self.cfg
        B, S, d = x.shape
        H, D = cfg.num_heads, cfg.head_dim
        r, k, v, g, w, new_shift = self._proj(pl, x, shift)
        if valid is not None:
            vmask = valid[:, :, None, None]
            w = jnp.where(vmask, w, 1.0)
            k = k * vmask.astype(k.dtype)
        if last_pos is not None:
            new_shift = jnp.take_along_axis(
                x, last_pos[:, None, None].astype(jnp.int32), axis=1)
        rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
        u = pl["u"].astype(jnp.float32)
        if S == 1:
            o, state = self._wkv_step(rf[:, 0], kf[:, 0], vf[:, 0], wf[:, 0],
                                      u, state)
            o = o[:, None]
        else:
            o, state = self._wkv_chunked(rf, kf, vf, wf, u, state)
        # group-norm over each head then gate (Finch uses GroupNorm(H))
        o = o.reshape(B, S, H, D)
        mu = jnp.mean(o, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(o - mu), axis=-1, keepdims=True)
        o = (o - mu) * jax.lax.rsqrt(var + 1e-5)
        o = (o.reshape(B, S, H * D) * pl["gn"].astype(jnp.float32))
        o = (o.reshape(B, S, H, D) * jax.nn.silu(g.astype(jnp.float32)))
        out = linear(o.reshape(B, S, H * D).astype(x.dtype), pl["wo"])
        return out, new_shift, state

    def _channel_mix(self, pl, x, shift, last_pos=None):
        """relu²-keyed FFN with sigmoid receptance gate."""
        xs = jnp.concatenate([shift, x[:, :-1]], axis=1)
        mix = pl["mix"].astype(x.dtype)
        xk = x + (xs - x) * mix[1]
        xr = x + (xs - x) * mix[0]
        k = jnp.square(jax.nn.relu(linear(xk, pl["ck"])))
        new_shift = (x[:, -1:] if last_pos is None else jnp.take_along_axis(
            x, last_pos[:, None, None].astype(jnp.int32), axis=1))
        return jax.nn.sigmoid(linear(xr, pl["cr"])) * linear(k, pl["cv"]), \
            new_shift

    # ------------------------------------------------------------- forward --
    def _run(self, params, tokens, state, valid=None, last_pos=None):
        """Shared trunk. state = None (train: zeros, discarded) or pytree.
        Returns (h_final (B,S,d), new_state)."""
        cfg = self.cfg
        B, S = tokens.shape
        h = params["embed"][tokens].astype(jnp.bfloat16)
        h = shard_act(h, ("batch", "seq", None))
        if state is None:
            state = self.init_state(B)

        def body(carry, xs):
            hh = carry
            pl, wkv, sh_t, sh_c = xs
            x = rmsnorm(hh, pl["ln1"], cfg.norm_eps)
            a, sh_t, wkv = self._time_mix(pl, x, sh_t, wkv, valid, last_pos)
            hh = hh + a
            x = rmsnorm(hh, pl["ln2"], cfg.norm_eps)
            f, sh_c = self._channel_mix(pl, x, sh_c, last_pos)
            hh = shard_act(hh + f, ("batch", "seq", None))
            return hh, (wkv, sh_t, sh_c)

        body = jax.checkpoint(body) if S > 1 else body
        h, (wkv, sh_t, sh_c) = jax.lax.scan(
            body, h, (params["layers"], state["wkv"], state["shift_t"],
                      state["shift_c"]))
        added = S if valid is None else jnp.sum(valid, axis=1)
        new_state = {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c,
                     "length": (state["length"] + added).astype(jnp.int32)}
        return rmsnorm(h, params["final_norm"], cfg.norm_eps), new_state

    def forward(self, params, batch, coopt: CoOptConfig = COOPT):
        h, _ = self._run(params, batch["tokens"], None)
        return linear(h, params["lm_head"]), {}

    def prefill(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                long_window: int = 0):
        """Prompt prefill / chunked continuation (the unified ragged step
        path): the state pytree in ``cache`` is the state after the previous
        chunk and threads straight through — paged-cache plumbing
        (positions/slots/page_table/long_window) is accepted and ignored."""
        valid = batch.get("pad_mask")
        last_pos = batch.get("last_pos")
        h, cache = self._run(params, batch["tokens"], cache, valid, last_pos)
        if "cache_len" in batch:
            cache["length"] = batch["cache_len"].astype(jnp.int32)
        if last_pos is not None:
            h_last = jnp.take_along_axis(
                h, last_pos[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        else:
            h_last = h[:, -1]
        return linear(h_last, params["lm_head"]), cache

    def decode_step(self, params, batch, cache, coopt: CoOptConfig = COOPT,
                    long_window: int = 0):
        h, cache = self._run(params, batch["token"], cache)
        return linear(h[:, 0], params["lm_head"]), cache

    # ------------------------------------------------------------- caching --
    def cache_shape(self, batch: int, max_len: int, coopt: CoOptConfig,
                    num_shards: int = 1, cache_cfg=None):
        # attention-free: no paged KV pool, so ``num_shards`` / ``cache_cfg``
        # (accepted for engine-call uniformity) size nothing here
        cfg = self.cfg
        L, d, H, D = cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.head_dim
        return {
            "wkv": ((L, batch, H, D, D), jnp.float32,
                    ("layers", "batch", "heads", None, None)),
            "shift_t": ((L, batch, 1, d), jnp.bfloat16,
                        ("layers", "batch", None, "d_model")),
            "shift_c": ((L, batch, 1, d), jnp.bfloat16,
                        ("layers", "batch", None, "d_model")),
            "length": ((batch,), jnp.int32, ("batch",)),
        }

    def init_cache(self, batch: int, max_len: int, coopt: CoOptConfig,
                   num_shards: int = 1, cache_cfg=None):
        return {k: jnp.zeros(sh, dt)
                for k, (sh, dt, _) in
                self.cache_shape(batch, max_len, coopt).items()}

    def init_state(self, batch: int):
        return self.init_cache(batch, 0, COOPT)

    # -------------------------------------------------------------- specs --
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        B, S = shape.global_batch, shape.seq_len
        tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "decode":
            return {"token": tok(B, 1)}
        out = {"tokens": tok(B, S)}
        if shape.kind == "train":
            out["labels"] = tok(B, S)
        return out

    def param_count(self) -> int:
        from repro.models.layers import param_count
        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        return self.param_count()
