"""Shared layer primitives + parameter-spec / sharding utilities.

Parameters are declared once as a pytree of ``Spec`` (shape, logical axes,
init); ``init_tree`` materialises arrays and ``make_pspecs`` maps logical axes
to mesh axes (dropping any axis whose dim is not divisible by the mesh axis —
this transparently handles e.g. whisper's vocab 51865 % 16 != 0 or MQA kv=1).

Logical weight axes (DESIGN.md §7):
  "d_in"  -> "data"   (ZeRO-3-ish input-dim shard)
  "d_out" -> "model"  (tensor-parallel output/ffn/head shard)
  "vocab" -> "data"   (embedding rows)
anything else (e.g. "layers" for scan-stacked params) -> unsharded.
"""
from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

DEFAULT_RULES = {"d_in": "data", "d_out": "model", "vocab": "data",
                 "experts": "data",
                 # expert d_model keeps ZeRO sharding even in decode-only
                 # weight rules (experts dominate MoE bytes; §Perf P3.2)
                 "moe_d_in": "data"}


class Spec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # "normal" | "zeros" | "ones" | "embed" | "uniform1"
    dtype: Any = jnp.bfloat16
    scale: Optional[float] = None  # stddev override for "normal"


def _init_one(key, s: Spec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "uniform1":  # uniform in [0, 1): RWKV mix coefficients
        return jax.random.uniform(key, s.shape, jnp.float32).astype(s.dtype)
    if s.init == "embed":
        std = s.scale if s.scale is not None else 0.02
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)
    # fan-in scaled normal
    fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
    std = s.scale if s.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_tree(key, spec_tree):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(k, s) for k, s in zip(keys, leaves)])


def shapes_tree(spec_tree):
    """ShapeDtypeStruct tree (dry-run param stand-ins, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, Spec))


def spec_pspec(s: Spec, mesh: Mesh, rules=None) -> PS:
    """Logical axes -> PartitionSpec. Rule values may be one mesh axis or a
    tuple of them (e.g. MoE expert ff -> ("data", "model") for 256-way
    sharding); non-divisible or already-used mesh axes are dropped
    per-tensor."""
    rules = rules or DEFAULT_RULES
    entries, used = [], set()
    for dim, ax in zip(s.shape, s.axes):
        m = rules.get(ax) if ax else None
        if m is None:
            entries.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a in mesh.shape and a not in used)
        size = math.prod(mesh.shape[a] for a in ms) if ms else 1
        if ms and dim % size == 0:
            entries.append(ms if len(ms) > 1 else ms[0])
            used.update(ms)
        else:
            entries.append(None)
    return PS(*entries)


def make_pspecs(spec_tree, mesh: Mesh, rules=None):
    return jax.tree.map(lambda s: spec_pspec(s, mesh, rules),
                        spec_tree, is_leaf=lambda x: isinstance(x, Spec))


def make_shardings(spec_tree, mesh: Mesh, rules=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, spec_pspec(s, mesh, rules)),
                        spec_tree, is_leaf=lambda x: isinstance(x, Spec))


def param_bytes(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, Spec))
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, Spec))
    return sum(math.prod(s.shape) for s in leaves)


# ----------------------------------------------------------------------------
# Activation sharding constraints (no-op outside a mesh context).
# ----------------------------------------------------------------------------
_ACT_CTX: ContextVar[Optional[Tuple[Mesh, dict]]] = ContextVar("act_ctx", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    """rules: logical activation axis -> mesh axis (or tuple of mesh axes)."""
    tok = _ACT_CTX.set((mesh, rules))
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def shard_act(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    entries, used = [], set()
    for dim, ax in zip(x.shape, axes):
        m = rules.get(ax) if ax else None
        if m is None:
            entries.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a in mesh.shape and a not in used)
        size = math.prod(mesh.shape[a] for a in ms) if ms else 1
        if ms and dim % size == 0:
            entries.append(ms if len(ms) > 1 else ms[0])
            used.update(ms)
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PS(*entries)))


# ----------------------------------------------------------------------------
# Norms / activations / projections
# ----------------------------------------------------------------------------
def rmsnorm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def linear(x, w, b=None):
    y = jnp.einsum("...i,io->...o", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(linear(x, wg)) * linear(x, wu)
    return linear(h, wd)


def gelu_mlp(x, w1, b1, w2, b2):
    return linear(jax.nn.gelu(linear(x, w1, b1)), w2, b2)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------
def rope_angles(positions, head_dim, theta):
    """positions (...,) -> cos/sin (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2.0 / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S) broadcastable."""
    d = x.shape[-1]
    cos, sin = rope_angles(positions, d, theta)   # (..., S, d/2)
    cos = cos[..., None, :]                        # (..., S, 1, d/2)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Full-sequence (train / prefill) grouped-query attention, q-chunked so the
# (S x S) score matrix is never materialised — the Opt-Pa "segment long
# sequences into manageable chunks" strategy applied to prefill.
# ----------------------------------------------------------------------------
def causal_attention(q, k, v, *, window: int = 0, chunk_q: int = 256,
                     causal: bool = True, q_offset=0):
    """q: (B,S,Hq,D)  k,v: (B,T,Hkv,D)  -> (B,S,Hq,D).

    Grouped (Opt-GQA Eq. 7/8): q heads are folded to (Hkv, G) and share each
    KV head. ``window>0`` = sliding-window (mixtral/griffin local attention).
    ``q_offset``: absolute position of q[0] (prefill continuation).
    """
    B, S, Hq, D = q.shape
    T, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    kpos = jnp.arange(T)

    nchunks = max(S // chunk_q, 1)
    cq = S // nchunks if S % nchunks == 0 else S  # fall back to single chunk
    nchunks = S // cq

    def one_chunk(ci):
        qs = jax.lax.dynamic_slice_in_dim(qg, ci * cq, cq, axis=1)
        qpos = q_offset + ci * cq + jnp.arange(cq)
        s = jnp.einsum("bqhgd,bthd->bhgqt", qs, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((cq, T), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        # Eq. 8: max-subtracted softmax
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.maximum(m, -1e30)  # rows that are fully masked
        p = jnp.exp(s - m)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(v.dtype), v)
        return o.reshape(B, cq, Hq, Dv)

    if nchunks == 1:
        return one_chunk(0)
    outs = jax.lax.map(one_chunk, jnp.arange(nchunks))       # (N,B,cq,Hq,Dv)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, Dv)


def repeat_kv(x, repeats: int):
    """Original-mode (non-Opt-GQA) path: materialise duplicated KV heads."""
    if repeats == 1:
        return x
    B, T, Hkv, D = x.shape
    return jnp.broadcast_to(x[:, :, :, None], (B, T, Hkv, repeats, D)
                            ).reshape(B, T, Hkv * repeats, D)
