"""Sharded checkpointing: pytree -> directory of npz shards + manifest.

Each leaf is written as its own ``.npy`` under a key derived from its tree
path; a ``manifest.json`` records dtype/shape and the tree structure so load
can rebuild the pytree without the model. On a real multi-host cluster each
host writes only the leaves it owns (process_index sharding); on this
single-process container that degenerates to one writer, but the layout is
the multi-host one.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": {}}
    for i, (kpath, leaf) in enumerate(leaves):
        key = f"{i:04d}__{_leaf_key(kpath)}"
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            np.save(os.path.join(path, key + ".npy"),
                    arr.view(np.uint16), allow_pickle=False)
            manifest["leaves"][key] = {"dtype": "bfloat16",
                                       "shape": list(arr.shape)}
        elif str(arr.dtype).startswith("float8"):
            np.save(os.path.join(path, key + ".npy"),
                    arr.view(np.uint8), allow_pickle=False)
            manifest["leaves"][key] = {"dtype": str(arr.dtype),
                                       "shape": list(arr.shape)}
        else:
            np.save(os.path.join(path, key + ".npy"), arr,
                    allow_pickle=False)
            manifest["leaves"][key] = {"dtype": str(arr.dtype),
                                       "shape": list(arr.shape)}
    with open(os.path.join(path, _MANIFEST), "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, like: Any) -> Any:
    """Rebuild a pytree with the structure of ``like`` from ``path``."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    keys = sorted(manifest["leaves"])
    leaves, treedef = jax.tree.flatten(like)
    assert len(keys) == len(leaves), \
        f"checkpoint has {len(keys)} leaves, expected {len(leaves)}"
    out = []
    for key, ref in zip(keys, leaves):
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, key + ".npy"))
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        elif meta["dtype"].startswith("float8"):
            arr = arr.view(jnp.dtype(meta["dtype"]))
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, _MANIFEST)) as f:
        return json.load(f)["step"]
