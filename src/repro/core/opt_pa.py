"""Opt-Pa — paged attention for long sequences (paper §3.3, Alg. 3).

Decode-phase attention of ONE query token per lane against the GLOBAL paged
KV pool: ``kv_pages (2, P_total, ps, Hkv, D)`` shared by every lane, with a
per-lane ``page_table (B, P_lane)`` naming the lane's physical pages in
logical order (-1 = unallocated). Lanes never alias pages they can write
(refcounted pool, CoW prefix sharing), so the gather is race-free.

Two-stage strategy, mapped to TPU (DESIGN.md §3):
  Phase 1 — *valid-block filtering* (Eq. 9): only logical pages b in
  [0, ceil(t/B)) participate; unallocated (-1) table entries never load. In
  this jnp reference that is a gather of the lane's pages + masking; in the
  Pallas kernel (``paged_pool_decode``) the page table is scalar-prefetched
  and dereferenced inside the BlockSpec index_map, so skipped pages are never
  DMA'd — the paper's "lazy memory mapping" as data-dependent prefetch.
  Phase 2 — *block-wise softmax with shared-memory reduction* (Eq. 10): an
  online-softmax accumulation over page groups. The DCU's ``block_sum``
  shared-memory reduction becomes a VMEM-resident running (max, sum, acc).

The "Original" baseline (`coopt.opt_pa == False`) reproduces unmodified vLLM
semantics on this platform: every page in the lane's table is uniformly
loaded and a flat softmax is taken over the whole (padded) history — "all KVs
being loaded into memory regardless of whether they are actually useful"
(paper §2).

Opt-KV (fp8 dequant on read) and Opt-GQA (grouped queries) compose here;
``LLM-CoOpt`` = all three, which is what the fused kernel implements.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.coopt import CoOptConfig
from repro.core.opt_kv import (decode_page_select, dequant_pages,
                               gather_cached_kv, identity_page_table)
from repro.models.layers import repeat_kv, shard_act

_NEG = -1e30


def _scores(q, k, opt_gqa: bool):
    """q (B,Hq,D), k (B,T,Hkv,D) -> scores (B,Hq,T) f32 (scaled).

    Under the production mesh, q's and k's head_dim are kept model-sharded
    and the (much smaller) score tensor is the all-reduced partial sum —
    without the constraints GSPMD all-gathers the dequantized KV page group
    per scan step (EXPERIMENTS.md §Perf P3)."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    q = shard_act(q, ("batch", None, "head_dim"))
    k = shard_act(k, ("batch", None, None, "head_dim"))
    if opt_gqa and Hkv != Hq:
        qg = q.reshape(B, Hkv, Hq // Hkv, D)
        s = jnp.einsum("bhgd,bthd->bhgt", qg, k,
                       preferred_element_type=jnp.float32)
        s = shard_act(s, ("batch", None, None, None))
        return s.reshape(B, Hq, -1) * scale
    k = repeat_kv(k, Hq // Hkv)
    s = jnp.einsum("bhd,bthd->bht", q, k,
                   preferred_element_type=jnp.float32)
    return shard_act(s, ("batch", None, None)) * scale


def _weighted_v(p, v, opt_gqa: bool, Hq: int):
    """p (B,Hq,T) f32, v (B,T,Hkv,D) -> (B,Hq,D) f32."""
    Hkv = v.shape[2]
    if opt_gqa and Hkv != Hq:
        pg = p.reshape(p.shape[0], Hkv, Hq // Hkv, p.shape[-1])
        o = jnp.einsum("bhgt,bthd->bhgd", pg, v.astype(jnp.float32))
        return o.reshape(p.shape[0], Hq, -1)
    v = repeat_kv(v, Hq // Hkv)
    return jnp.einsum("bht,bthd->bhd", p, v.astype(jnp.float32))


def paged_decode_attention(q, kv_pages, scale_pages, cache_len, *,
                           coopt: CoOptConfig, window: int = 0,
                           sink_pages: int = 1,
                           page_table: Optional[jax.Array] = None) -> jax.Array:
    """q: (B, Hq, D); kv_pages: (2, P_total, ps, Hkv, D) global pool;
    cache_len: (B,) tokens valid per lane (the current token must already be
    written); page_table: (B, P_lane) physical pages in logical order
    (default: static lane-identity partition of the pool).
    Returns (B, Hq, D) in q.dtype.
    """
    B, Hq, D = q.shape
    _, P_total, ps, Hkv, _ = kv_pages.shape
    if page_table is None:
        page_table = identity_page_table(B, P_total)

    if coopt.use_kernel:
        # (physical, logical) tables for the scalar-prefetched kernel —
        # Eq. 9 filtering / the {sink + window} policy decided host-free
        # (decode_page_select, shared with the MLA latent layout).
        from repro.kernels import ops
        phys, logical = decode_page_select(cache_len, page_table, ps,
                                           window=window,
                                           sink_pages=sink_pages,
                                           opt_pa=coopt.opt_pa)
        return ops.paged_pool_decode(
            q, kv_pages, scale_pages, cache_len, phys, logical,
            opt_kv=coopt.opt_kv,
            opt_gqa=True if window else coopt.opt_gqa,
            window=window, sink_pages=sink_pages if window else 0,
            share_visits=coopt.share_visits)

    if window:
        # Block-sparse policy: Opt-KV SkipSet = outside {sinks + window},
        # decided in the logical page domain then mapped to physical pages
        # (same selection the kernel branch prefetches).
        phys, logical = decode_page_select(cache_len, page_table, ps,
                                           window=window,
                                           sink_pages=sink_pages)
        return _windowed(q, kv_pages, scale_pages, cache_len, phys, logical,
                         window, sink_pages, coopt)

    # jnp reference: gather the lane's pages (logical order) then reduce.
    flat = gather_cached_kv(kv_pages, scale_pages, page_table, coopt)
    Psel = page_table.shape[1]
    kv_lane = flat.reshape(2, B, Psel, ps, Hkv, D)
    valid = jnp.repeat(page_table >= 0, ps, axis=1)       # (B, Psel*ps)
    coopt = coopt.replace(opt_kv=False)                   # already dequantized
    if coopt.opt_pa:
        return _blockwise(q, kv_lane, None, cache_len, coopt, valid)
    return _flat(q, kv_lane, None, cache_len, coopt, valid)


# ------------------------------------------------ continuation prefill ----
def paged_chunk_attention(q, kv_pages, scale_pages, positions, page_table,
                          coopt: CoOptConfig, *, window: int = 0,
                          sink_pages: int = 1, seg_q=None, page_seg=None,
                          page_base=None) -> jax.Array:
    """Chunked-continuation prefill attention (the ONE ragged step path):
    a chunk of queries per lane — q (B,S,Hq,D) with absolute ``positions``
    (B,S) — attends over the lane's WHOLE cached history (prefix-cache hits,
    earlier chunks, and this chunk, already written) through its page table.
    Key j of the gathered view is the lane's logical position j, so causality
    is a plain position compare; a decode lane is a chunk of length 1.

    ``window`` > 0 applies the block-sparse {sliding window + sink} policy
    (griffin local attention, long-context decode) with the same mask as the
    decode path, so a token's logits are schedule-independent.

    Concat-prefill packing: ``seg_q`` (B,S), ``page_seg`` (B,NP) and
    ``page_base`` (B,NP) pack several prompts' chunks into one row — a
    query attends a key only when their segment ids match, and key
    positions restart per segment at ``page_base * ps``. None = unpacked
    (byte-identical to the pre-packing math).
    Returns (B, S, Hq, D) in q.dtype."""
    B, S, Hq, D = q.shape
    _, P_total, ps, Hkv, _ = kv_pages.shape
    if page_table is None:
        page_table = identity_page_table(B, P_total)

    if coopt.use_kernel:
        from repro.kernels import ops
        return ops.paged_chunk_prefill(
            q, positions, kv_pages, scale_pages, page_table,
            opt_kv=coopt.opt_kv, opt_gqa=coopt.opt_gqa, window=window,
            sink_pages=sink_pages, seg_q=seg_q, page_seg=page_seg,
            page_base=page_base)

    # jnp reference: gather the lane's pages in logical order, then a
    # position-masked softmax over the gathered view.
    flat = gather_cached_kv(kv_pages, scale_pages, page_table, coopt)
    k, v = flat                                        # (B,T,Hkv,D) each
    T = k.shape[1]
    if not coopt.opt_gqa and Hkv != Hq:
        # Original: KV physically expanded per query head (Fig. 2)
        k, v = repeat_kv(k, Hq // Hkv), repeat_kv(v, Hq // Hkv)
        Hg, G = Hq, 1
    else:
        Hg, G = Hkv, Hq // Hkv
    qg = q.reshape(B, S, Hg, G, D).astype(jnp.float32)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k.astype(jnp.float32))
    s = s * (1.0 / math.sqrt(D))
    if page_base is not None:
        # packed: key j's position restarts per segment at page_base*ps
        kpos = (page_base.astype(jnp.int32)[:, :, None] * ps
                + jnp.arange(ps, dtype=jnp.int32)[None, None, :]
                ).reshape(B, T)[:, None, :]
    else:
        kpos = jnp.arange(T, dtype=jnp.int32)[None, None, :]
    qpos = positions[:, :, None]
    mask = (kpos <= qpos) & \
        jnp.repeat(page_table >= 0, ps, axis=1)[:, None, :]
    if seg_q is not None:
        mask &= (jnp.repeat(page_seg.astype(jnp.int32), ps, axis=1)[:, None]
                 == seg_q.astype(jnp.int32)[:, :, None])
    if window:
        mask &= (kpos > qpos - window) | (kpos < sink_pages * ps)
    s = jnp.where(mask[:, None, None], s, _NEG)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", pr, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)


# --------------------------------------------------------------- Original --
def _flat(q, kv_pages, scale_pages, cache_len, coopt, valid):
    B, Hq, D = q.shape
    _, _, P, ps, Hkv, _ = kv_pages.shape
    kv = dequant_pages(kv_pages, scale_pages, coopt)        # ALL pages loaded
    k, v = kv.reshape(2, B, P * ps, Hkv, D)
    s = _scores(q, k, coopt.opt_gqa)                        # (B,Hq,T)
    pos = jnp.arange(P * ps)[None, None, :]
    mask = pos < cache_len[:, None, None]
    if valid is not None:
        mask &= valid[:, None, :]
    s = jnp.where(mask, s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)                  # Eq. 8 / Eq. 10
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = _weighted_v(p, v, coopt.opt_gqa, Hq)
    return o.astype(q.dtype)


# ----------------------------------------------------- Opt-Pa (block-wise) --
def effective_page_group(num_pages: int, page_group: int) -> Tuple[int, int]:
    """Opt-Pa group size actually used by ``_blockwise`` for a pool of
    ``num_pages`` pages: (group, padded page count). The page axis is PADDED
    (masked) up to the next multiple of ``page_group`` instead of silently
    degrading the group — a group of 1 would turn Eq. 10's shared-memory
    block reduction into a per-page scan."""
    pg = max(min(page_group, num_pages), 1)
    return pg, num_pages + (-num_pages) % pg


def _blockwise(q, kv_pages, scale_pages, cache_len, coopt, valid):
    B, Hq, D = q.shape
    _, _, P, ps, Hkv, _ = kv_pages.shape
    pg, P_pad = effective_page_group(P, coopt.page_group)
    if P_pad != P:
        # keep the configured group: pad the page axis with masked pages
        # rather than halving pg down to a degenerate per-page scan
        pad = P_pad - P
        kv_pages = jnp.pad(kv_pages,
                           ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        if scale_pages is not None:
            scale_pages = jnp.pad(
                scale_pages, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        if valid is None:                     # pad pages must be masked out
            valid = jnp.ones((B, P * ps), bool)
        valid = jnp.pad(valid, ((0, 0), (0, pad * ps)))
        P = P_pad
    NG, T = P // pg, pg * ps

    kv_g = kv_pages.reshape(2, B, NG, T, Hkv, D)
    sc_g = (scale_pages.reshape(2, B, NG, T, Hkv)
            if scale_pages is not None else None)
    valid_g = valid.reshape(B, NG, T) if valid is not None else None

    def body(carry, g):
        m, l, acc = carry
        kv = dequant_pages(kv_g[:, :, g], None if sc_g is None else sc_g[:, :, g],
                           coopt)
        k, v = kv
        s = _scores(q, k, coopt.opt_gqa)                    # (B,Hq,T)
        pos = g * T + jnp.arange(T)[None, None, :]
        mask = pos < cache_len[:, None, None]
        if valid_g is not None:
            mask &= valid_g[:, g][:, None, :]
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m - m_new)                           # block_sum analogue
        p = jnp.exp(s - m_new)
        l = l * corr[..., 0] + jnp.sum(p, axis=-1)
        acc = acc * corr + _weighted_v(p, v, coopt.opt_gqa, Hq)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hq, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hq), jnp.float32)
    a0 = jnp.zeros((B, Hq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(NG))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ------------------------------------------------ window/sink block-sparse --
def _windowed(q, kv_pages, scale_pages, cache_len, phys_table, logical_table,
              window, sink_pages, coopt):
    B, Hq, D = q.shape
    _, P, ps, Hkv, _ = kv_pages.shape
    flat = gather_cached_kv(kv_pages, scale_pages, phys_table, coopt)
    k, v = flat                                              # (B,Ts,H,D)
    pos = jnp.maximum(logical_table, 0)[:, :, None] * ps + \
        jnp.arange(ps)[None, None, :]
    pos = pos.reshape(B, -1)                                 # (B, Ts)
    in_ctx = pos < cache_len[:, None]
    in_win = pos >= jnp.maximum(cache_len[:, None] - window, 0)
    in_sink = pos < sink_pages * ps
    mask = in_ctx & (in_win | in_sink) & \
        (phys_table >= 0).repeat(ps, axis=1)
    s = _scores(q, k, coopt.opt_gqa)
    s = jnp.where(mask[:, None, :], s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = _weighted_v(p, v, coopt.opt_gqa, Hq)
    return o.astype(q.dtype)
