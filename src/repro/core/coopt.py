"""LLM-CoOpt runtime configuration — which of the paper's three techniques are
active. ``ORIGINAL`` reproduces the unmodified-vLLM baseline; ``COOPT`` is the
full framework (Opt-KV + Opt-GQA + Opt-Pa). Intermediate combinations give the
paper's per-technique ablations (Figs. 6-7).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp

from repro.cache.quant import FP8_DTYPE


@dataclass(frozen=True)
class CoOptConfig:
    opt_kv: bool = False      # FP8 cache + SkipSet-aware writes (Alg. 1)
    opt_gqa: bool = False     # grouped computation (Alg. 2); else KV expanded per q-head
    opt_pa: bool = False      # valid-block filtering + block-wise softmax (Alg. 3)
    page_size: int = 64       # tokens per KV page (vLLM block)
    page_group: int = 8       # pages processed per online-softmax step (VMEM tile)
    use_kernel: bool = False  # Pallas hot path (single-host AND shard_map
                              # distributed — kernels.sharded) vs the
                              # pure-jnp parity reference
    # Cross-lane shared-prefix page batching (kernels.visits): the decode
    # kernels iterate a deduplicated (page, lane-set) visit list, so a
    # prefix page shared by N lanes streams into VMEM once instead of N
    # times. Degenerates to the bit-identical per-lane grid when no sharing
    # exists (and for B == 1 or B > visits.MAX_VISIT_LANES). Kernel path
    # only; the jnp reference gathers per lane regardless.
    share_visits: bool = True
    # MoE serving knob: expert capacity = ceil(S * top_k / E * cf). Decode
    # (S=1) is inherently dropless; cf >= E/top_k makes prefill dropless too
    # (exact teacher-forcing consistency) at proportional dispatch cost.
    moe_capacity_factor: float = 1.25

    @property
    def kv_dtype(self):
        return FP8_DTYPE if self.opt_kv else jnp.bfloat16

    def replace(self, **kw) -> "CoOptConfig":
        return dataclasses.replace(self, **kw)


ORIGINAL = CoOptConfig()
OPT_KV = CoOptConfig(opt_kv=True)
OPT_GQA = CoOptConfig(opt_gqa=True)
OPT_PA = CoOptConfig(opt_pa=True)
COOPT = CoOptConfig(opt_kv=True, opt_gqa=True, opt_pa=True)

MODES = {
    "original": ORIGINAL,
    "opt-kv": OPT_KV,
    "opt-gqa": OPT_GQA,
    "opt-pa": OPT_PA,
    "coopt": COOPT,
}
