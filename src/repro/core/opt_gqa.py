"""Opt-GQA — grouped-query attention restructuring (paper §3.2, Alg. 2).

Eq. 7: Group_q(i) = floor(i / H_g), H_g = H_q / H_k — query head i reads KV
head i // H_g. With Opt-GQA enabled, attention is computed with queries folded
to (H_k, H_g) so each KV head is loaded once per group ("each key-value pair
is shared among all query heads in its group", Fig. 4). With it disabled
("Original" / plain MHA semantics), K/V are physically expanded to H_q heads
before attention — each head "independently" consumes its KV pair, which is
the redundancy the paper measures against.

For the paper's MHA checkpoints (LLaMa-13B, H_k == H_q), ``mha_to_gqa``
restructures the K/V projection weights into H_k' < H_q shared heads by
mean-pooling each group — the standard GQA conversion [16] the paper builds on.
"""
from __future__ import annotations

import jax.numpy as jnp


def group_index(i, num_q_heads: int, num_kv_heads: int):
    """Eq. 7 mapping: query head i -> KV group index."""
    h_g = num_q_heads // num_kv_heads
    return i // h_g


def fold_queries(q, num_kv_heads: int):
    """(..., Hq, D) -> (..., Hkv, G, D) per Eq. 7 (heads of one group adjacent)."""
    *lead, Hq, D = q.shape
    G = Hq // num_kv_heads
    return q.reshape(*lead, num_kv_heads, G, D)


def unfold_outputs(o):
    """(..., Hkv, G, D) -> (..., Hq, D) — Alg. 2 Phase 3 concatenation."""
    *lead, Hkv, G, D = o.shape
    return o.reshape(*lead, Hkv * G, D)


def mha_to_gqa(wk, wv, num_kv_heads: int, head_dim: int):
    """Mean-pool MHA K/V projections into ``num_kv_heads`` shared heads.

    wk/wv: (d_model, Hq*D) -> (d_model, num_kv_heads*D).
    """
    d_model, hd = wk.shape
    Hq = hd // head_dim
    G = Hq // num_kv_heads

    def pool(w):
        w = w.reshape(d_model, num_kv_heads, G, head_dim)
        return jnp.mean(w.astype(jnp.float32), axis=2).astype(w.dtype) \
                  .reshape(d_model, num_kv_heads * head_dim)

    return pool(wk), pool(wv)
