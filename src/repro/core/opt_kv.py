"""Opt-KV — KV-cache write/read path optimization (paper §3.1, Alg. 1).

Write phase (Eq. 5): a token's K/V are cached only if its slot index is valid:
``slot_idx_i < 0 or slot_idx_i in SkipSet`` => skip. We realise the SkipSet as
slots pre-marked -1 by the caller (engine policy: padding tokens, duplicate
tokens, evicted/out-of-window tokens), so the write itself is a single scatter
with ``mode='drop'`` — negative indices never touch memory, exactly the
paper's "skip caching of K_i, V_i".

Read phase (Eq. 6): cached K/V are FP8 and dequantized on the fly
(``gather_cached_kv``). The Pallas kernel in ``repro.kernels`` fuses this into
the attention loop; this module is the numerically-identical jnp reference
used by tests and by the distributed (GSPMD) path.

Cache layout (one layer): kv (2, B, P, ps, Hkv, D) + scale (2, B, P, ps, Hkv).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cache.quant import dequantize_fp8, quantize_fp8
from repro.core.coopt import CoOptConfig


def make_layer_cache(batch: int, num_pages: int, page_size: int, num_kv_heads: int,
                     head_dim: int, coopt: CoOptConfig):
    """Zero-initialised single-layer paged cache (kv, scale|None)."""
    kv = jnp.zeros((2, batch, num_pages, page_size, num_kv_heads, head_dim),
                   coopt.kv_dtype)
    scale = (jnp.zeros((2, batch, num_pages, page_size, num_kv_heads), jnp.float32)
             if coopt.opt_kv else None)
    return kv, scale


def write_kv(kv_cache, scale_cache, k_new, v_new, slot_idx, coopt: CoOptConfig):
    """Write new tokens' K/V into the paged cache.

    k_new/v_new: (B, S, Hkv, D); slot_idx: (B, S) int32 — flat slot
    (= page * page_size + offset) in this sequence's pool; -1/SkipSet => skip.
    Returns updated (kv_cache, scale_cache).
    """
    _, B, P, ps, H, D = kv_cache.shape
    if coopt.use_kernel:
        from repro.kernels import ops
        return ops.kv_cache_write(kv_cache, scale_cache, k_new, v_new,
                                  slot_idx, opt_kv=coopt.opt_kv)
    flat = kv_cache.reshape(2, B, P * ps, H, D)
    new = jnp.stack([k_new, v_new])                      # (2,B,S,H,D)
    clipped = jnp.where(slot_idx < 0, -1, slot_idx)      # keep skip sentinel

    if coopt.opt_kv:
        q, s = quantize_fp8(new, axis=-1)                # (2,B,S,H,D),(2,B,S,H)
        flat = flat.at[:, jnp.arange(B)[:, None], clipped].set(
            q.astype(flat.dtype), mode="drop")
        sflat = scale_cache.reshape(2, B, P * ps, H)
        sflat = sflat.at[:, jnp.arange(B)[:, None], clipped].set(s, mode="drop")
        scale_cache = sflat.reshape(2, B, P, ps, H)
    else:
        flat = flat.at[:, jnp.arange(B)[:, None], clipped].set(
            new.astype(flat.dtype), mode="drop")
    return flat.reshape(2, B, P, ps, H, D), scale_cache


def dequant_pages(kv_pages, scale_pages, coopt: CoOptConfig, dtype=jnp.bfloat16):
    """Eq. 6 read path: fp8 pages -> compute dtype."""
    if coopt.opt_kv:
        return dequantize_fp8(kv_pages, scale_pages, axis=-1, dtype=dtype)
    return kv_pages.astype(dtype)


def gather_cached_kv(kv_cache, scale_cache, page_table, coopt: CoOptConfig,
                     dtype=jnp.bfloat16):
    """Reference of the paper's dedicated ``gather_cached_kv`` kernel.

    page_table: (B, Psel) int32 physical page ids (negative => zero page).
    Returns (2, B, Psel*ps, Hkv, D) dequantized.
    """
    _, B, P, ps, H, D = kv_cache.shape
    pt = jnp.maximum(page_table, 0)
    gathered = jnp.take_along_axis(
        kv_cache, pt[None, :, :, None, None, None], axis=2)  # (2,B,Psel,ps,H,D)
    if coopt.opt_kv:
        sg = jnp.take_along_axis(scale_cache, pt[None, :, :, None, None], axis=2)
        out = dequantize_fp8(gathered, sg, axis=-1, dtype=dtype)
    else:
        out = gathered.astype(dtype)
    valid = (page_table >= 0)[None, :, :, None, None, None]
    out = jnp.where(valid, out, 0)
    Psel = page_table.shape[1]
    return out.reshape(2, B, Psel * ps, H, D)


def window_page_table(cache_len, num_pages: int, page_size: int,
                      window: int, sink_pages: int):
    """Opt-KV SkipSet as block sparsity (DESIGN.md §5 long-context policy).

    Selects sink pages [0, sink) plus the trailing ``ceil(window/ps)+1`` pages
    covering the sliding window, for a scalar/array ``cache_len`` (inclusive
    count of tokens already cached). Returns (B, Psel) page ids, -1 = skipped.
    """
    wpages = window // page_size + 1
    # page holding the most recent token (cache_len is an inclusive count)
    last_page = jnp.maximum(jnp.asarray(cache_len) - 1, 0) // page_size  # (B,)
    start = jnp.maximum(last_page - (wpages - 1), 0)
    win = start[:, None] + jnp.arange(wpages)[None, :]        # (B, wpages)
    win = jnp.where(win <= last_page[:, None], win, -1)
    sink = jnp.broadcast_to(jnp.arange(sink_pages)[None, :],
                            (win.shape[0], sink_pages))
    sink = jnp.where(sink < jnp.minimum(start, sink_pages)[:, None], sink, -1)
    table = jnp.concatenate([sink, win], axis=1).astype(jnp.int32)
    return jnp.minimum(table, num_pages - 1)
