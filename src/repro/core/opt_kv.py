"""Opt-KV — KV-cache write/read path optimization (paper §3.1, Alg. 1).

Write phase (Eq. 5): a token's K/V are cached only if its slot index is valid:
``slot_idx_i < 0 or slot_idx_i in SkipSet`` => skip. We realise the SkipSet as
slots pre-marked -1 by the caller (engine policy: padding tokens, prefix-cache
hits, evicted/out-of-window tokens), so the write itself is a single scatter
with ``mode='drop'`` — negative indices never touch memory, exactly the
paper's "skip caching of K_i, V_i".

Read phase (Eq. 6): cached K/V are FP8 and dequantized on the fly
(``gather_cached_kv``). The Pallas kernel in ``repro.kernels`` fuses this into
the attention loop — on a single host and, through the ``kernels.sharded``
shard_map layer, per shard of a GSPMD mesh; this module is the
numerically-identical jnp parity reference used by tests.

Cache layout (one layer) — GLOBAL POOL, no batch dimension:
    kv (2, P_total, ps, Hkv, D) + scale (2, P_total, ps, Hkv).
All sequences share the pool; the host-side ``BlockManager`` hands each
sequence a disjoint set of pages (refcounted, prefix-cache shareable) and the
per-step batch carries *global* flat slot indices and per-lane page tables.
Writes only ever target exclusively-owned pages (copy-on-write by
construction), so lane isolation needs no device-side masking.

Direct (non-engine) callers get a static lane-identity layout: pool =
``batch * pages(max_len)`` pages, lane b owning the contiguous range
``[b * P_lane, (b+1) * P_lane)`` — see ``identity_page_table`` /
``identity_slots``. When the Pallas write kernel is used, the pool's very
last cache line doubles as the SkipSet sentinel; the engine's BlockManager
never allocates the final page, so skipped tokens land in reserved space.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cache.quant import dequantize_fp8, quantize_fp8
from repro.core.coopt import CoOptConfig


# ------------------------------------------------------- shard ownership --
# Pure-integer page-range math lives with the host-side allocator (which
# must stay importable without jax); re-exported here because the device
# side — models' ``init_cache`` pool sizing and mesh-aware page-table
# construction — keys off the same partition.
from repro.cache.block_manager import (padded_pool_pages,   # noqa: F401
                                       shard_page_ranges)

# Mesh axes the cache ``pages`` axis is sharded over — THE partition of the
# whole system: CACHE_RULES maps pages onto it, ``shard_page_ranges`` is its
# host mirror, ``launch.mesh.kv_shard_count`` takes its extent from it, and
# the ``kernels.sharded`` shard_map layer runs one kernel per shard of it.
# Lives here (not in the kernel package) so host-side tooling can read it
# without importing the Pallas stack.
PAGES_AXES = ("pod", "data")


def pool_layout(batch: int, max_len: int, coopt, num_shards: int = 1,
                cache_cfg=None):
    """Resolve the device pool's pages-axis layout -> ``(P, page_size)``.

    THE one sizing rule every model's ``cache_shape`` and the scheduler's
    BlockManager must agree on: ``P`` is the requested pool size —
    ``CacheConfig.num_pages`` when set, else ``batch * pages(max_len)`` —
    padded so the pages axis tiles evenly over the KV shards. (The engine
    reserves the final padded page as the write kernel's SkipSet sentinel,
    so the host allocator sees ``P - 1`` usable pages.)"""
    ps = coopt.page_size
    pages = 0
    if cache_cfg is not None:
        ps = cache_cfg.page_size or ps
        num_shards = cache_cfg.num_shards or num_shards
        pages = cache_cfg.num_pages
    if not pages:
        pages = batch * (-(-max_len // ps))
    return padded_pool_pages(pages, num_shards), ps


def global_to_local_pages(phys_table, first_page, num_local: int):
    """Translate a GLOBAL physical page table to one mesh shard's LOCAL page
    domain: entries inside the shard's contiguous range
    ``[first_page, first_page + num_local)`` become local indices, every
    other entry (other shards' pages, and -1 holes) becomes -1 — exactly the
    kernels' existing hole semantics, so non-owned pages are never DMA'd.
    Used inside the ``kernels.sharded`` shard_map bodies."""
    local = phys_table - first_page
    owned = (phys_table >= 0) & (local >= 0) & (local < num_local)
    return jnp.where(owned, local, -1).astype(jnp.int32)


def global_to_local_slots(slot_idx, first_slot, num_local: int):
    """Flat-slot analogue of ``global_to_local_pages``: GLOBAL flat slots
    (page * ps + offset) outside the shard's ``[first_slot, first_slot +
    num_local)`` slot range (or already -1 / SkipSet) become ``num_local`` —
    one PAST the shard's last line, so a ``mode='drop'`` scatter discards
    them as out of bounds (Eq. 5 semantics per shard). -1 would WRAP to the
    shard's last line (only the global pool reserves a sentinel there; a
    mid-pool shard's last line is live data)."""
    local = slot_idx - first_slot
    owned = (slot_idx >= 0) & (local >= 0) & (local < num_local)
    return jnp.where(owned, local, num_local).astype(jnp.int32)


def make_layer_cache(num_pages: int, page_size: int, num_kv_heads: int,
                     head_dim: int, coopt: CoOptConfig):
    """Zero-initialised single-layer GLOBAL paged cache (kv, scale|None)."""
    kv = jnp.zeros((2, num_pages, page_size, num_kv_heads, head_dim),
                   coopt.kv_dtype)
    scale = (jnp.zeros((2, num_pages, page_size, num_kv_heads), jnp.float32)
             if coopt.opt_kv else None)
    return kv, scale


# ------------------------------------------------------- identity layout --
def pages_per_lane(total_pages: int, batch: int) -> int:
    return max(total_pages // batch, 1)


def identity_page_table(batch: int, total_pages: int) -> jax.Array:
    """Static lane-partitioned page table (B, P_lane): lane b owns the
    contiguous page range [b*P_lane, (b+1)*P_lane). Default for direct
    (non-engine) callers of prefill/decode_step."""
    P_lane = pages_per_lane(total_pages, batch)
    return (jnp.arange(batch, dtype=jnp.int32)[:, None] * P_lane
            + jnp.arange(P_lane, dtype=jnp.int32)[None, :])


def identity_slots(batch: int, positions, total_pages: int,
                   page_size: int) -> jax.Array:
    """Logical positions (B, S) -> global flat slots under the lane-identity
    layout (slot == lane_offset + position)."""
    P_lane = pages_per_lane(total_pages, batch)
    off = jnp.arange(batch, dtype=jnp.int32)[:, None] * (P_lane * page_size)
    return (positions.astype(jnp.int32) + off)


def write_kv(kv_cache, scale_cache, k_new, v_new, slot_idx, coopt: CoOptConfig):
    """Write new tokens' K/V into the global paged cache.

    kv_cache: (2, P, ps, Hkv, D); k_new/v_new: (B, S, Hkv, D);
    slot_idx: (B, S) int32 — GLOBAL flat slot (= page * page_size + offset)
    in the shared pool; -1/SkipSet => skip. Returns updated
    (kv_cache, scale_cache).
    """
    _, P, ps, H, D = kv_cache.shape
    if coopt.use_kernel:
        from repro.kernels import ops
        return ops.kv_cache_write(kv_cache, scale_cache, k_new, v_new,
                                  slot_idx, opt_kv=coopt.opt_kv)
    flat = kv_cache.reshape(2, P * ps, H, D)
    new = jnp.stack([k_new, v_new])                      # (2,B,S,H,D)
    clipped = jnp.where(slot_idx < 0, -1, slot_idx)      # keep skip sentinel

    if coopt.opt_kv:
        q, s = quantize_fp8(new, axis=-1)                # (2,B,S,H,D),(2,B,S,H)
        flat = flat.at[:, clipped].set(q.astype(flat.dtype), mode="drop")
        sflat = scale_cache.reshape(2, P * ps, H)
        sflat = sflat.at[:, clipped].set(s, mode="drop")
        scale_cache = sflat.reshape(2, P, ps, H)
    else:
        flat = flat.at[:, clipped].set(new.astype(flat.dtype), mode="drop")
    return flat.reshape(2, P, ps, H, D), scale_cache


def dequant_pages(kv_pages, scale_pages, coopt: CoOptConfig, dtype=jnp.bfloat16):
    """Eq. 6 read path: fp8 pages -> compute dtype."""
    if coopt.opt_kv:
        return dequantize_fp8(kv_pages, scale_pages, axis=-1, dtype=dtype)
    return kv_pages.astype(dtype)


def gather_cached_kv(kv_cache, scale_cache, page_table, coopt: CoOptConfig,
                     dtype=jnp.bfloat16):
    """Reference of the paper's dedicated ``gather_cached_kv`` kernel.

    kv_cache: (2, P, ps, Hkv, D) global pool; page_table: (B, Psel) int32
    physical page ids in logical order (negative => zero page). Returns
    (2, B, Psel*ps, Hkv, D) dequantized — token j of the output is the lane's
    logical position j, so downstream masks index by position directly.
    """
    _, P, ps, H, D = kv_cache.shape
    B, Psel = page_table.shape
    pt = jnp.maximum(page_table, 0)
    gathered = jnp.take(kv_cache, pt, axis=1)            # (2,B,Psel,ps,H,D)
    if coopt.opt_kv:
        sg = jnp.take(scale_cache, pt, axis=1)
        out = dequantize_fp8(gathered, sg, axis=-1, dtype=dtype)
    else:
        out = gathered.astype(dtype)
    valid = (page_table >= 0)[None, :, :, None, None, None]
    out = jnp.where(valid, out, 0)
    return out.reshape(2, B, Psel * ps, H, D)


def window_page_table(cache_len, num_pages: int, page_size: int,
                      window: int, sink_pages: int):
    """Opt-KV SkipSet as block sparsity (DESIGN.md §5 long-context policy).

    Operates in the LOGICAL page domain of one sequence: selects sink pages
    [0, sink) plus the trailing ``ceil(window/ps)+1`` pages covering the
    sliding window, for a scalar/array ``cache_len`` (inclusive count of
    tokens already cached). Returns (B, Psel) logical page ids, -1 = skipped;
    callers translate to physical pages via the per-lane page table
    (``jnp.take_along_axis(page_table, ...)``).

    A logical page id beyond the lane's table width (``cache_len`` larger
    than the table can back) becomes -1 — a SKIP, never an alias: clamping
    it onto page ``num_pages - 1`` would silently attend the wrong page's
    content.
    """
    wpages = window // page_size + 1
    # page holding the most recent token (cache_len is an inclusive count)
    last_page = jnp.maximum(jnp.asarray(cache_len) - 1, 0) // page_size  # (B,)
    start = jnp.maximum(last_page - (wpages - 1), 0)
    win = start[:, None] + jnp.arange(wpages)[None, :]        # (B, wpages)
    win = jnp.where(win <= last_page[:, None], win, -1)
    sink = jnp.broadcast_to(jnp.arange(sink_pages)[None, :],
                            (win.shape[0], sink_pages))
    sink = jnp.where(sink < jnp.minimum(start, sink_pages)[:, None], sink, -1)
    table = jnp.concatenate([sink, win], axis=1).astype(jnp.int32)
    return jnp.where(table >= num_pages, -1, table)


def logical_to_physical(logical_table, page_table):
    """Map a (B, NSel) LOGICAL page selection (-1 = skipped) through the
    per-lane (B, P_lane) physical page table, preserving -1 sentinels."""
    phys = jnp.take_along_axis(page_table,
                               jnp.maximum(logical_table, 0), axis=1)
    return jnp.where(logical_table < 0, -1, phys).astype(jnp.int32)


def decode_page_select(cache_len, page_table, page_size: int, *,
                       window: int = 0, sink_pages: int = 1,
                       opt_pa: bool = True):
    """(physical, logical) page selection for ONE decode step against the
    pool — the table pair every fused decode kernel (dense/moe KV pages and
    the MLA latent layout alike) scalar-prefetches.

    Dense (``window == 0``): logical pages are simply ``arange``; under
    Opt-Pa, physical entries wholly beyond the live context are masked to
    -1 (Eq. 9 valid-block filtering, host-free — the kernel never DMAs
    them), while the Original baseline streams every allocated page.
    Windowed: the {sink + sliding-window} block-sparse policy is decided in
    the logical page domain (``window_page_table``) then mapped through the
    lane's table, -1 sentinels preserved (skips, never aliases)."""
    B, P = page_table.shape
    if window:
        logical = window_page_table(cache_len, P, page_size, window,
                                    sink_pages)
        return logical_to_physical(logical, page_table), logical
    logical = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None], (B, P))
    if opt_pa:
        beyond = logical * page_size >= cache_len[:, None]
        phys = jnp.where(beyond, -1, page_table)
    else:
        phys = page_table
    return phys.astype(jnp.int32), logical
