from repro.training.optimizer import AdamWState, adamw_init, adamw_update
from repro.training.train import Trainer, loss_fn, make_train_step

__all__ = ["AdamWState", "adamw_init", "adamw_update", "Trainer", "loss_fn",
           "make_train_step"]
