"""AdamW in raw JAX (no optax on the container).

Moments are kept f32 regardless of param dtype; the update is computed in f32
and cast back — standard mixed-precision training discipline. Moment tensors
inherit the parameter's sharding via the launcher's pspec tree (same logical
axes), so optimizer state is fully sharded (ZeRO-style) for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # f32 pytree like params
    nu: Any                  # f32 pytree like params


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, *, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(gf)))
    if grad_clip:
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        gf = jax.tree.map(lambda g: g * scale, gf)

    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(gf)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm
