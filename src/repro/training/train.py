"""Training loop: cross-entropy LM loss (+ MoE aux losses) + AdamW.

``make_train_step`` returns the pure step function the launcher lowers for
the train_4k dry-run shape; ``Trainer`` is the host-side loop used by the
end-to-end example (reduced model, a few hundred steps on CPU).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coopt import CoOptConfig, COOPT
from repro.models import get_model
from repro.training.optimizer import AdamWState, adamw_init, adamw_update


def loss_fn(model, params, batch, coopt: CoOptConfig,
            moe_lb_weight: float = 0.01, moe_z_weight: float = 1e-3):
    logits, aux = model.forward(params, batch, coopt)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    metrics = {"nll": loss}
    if aux and "load_balance" in aux:
        loss = loss + moe_lb_weight * aux["load_balance"] \
                    + moe_z_weight * aux["router_z"]
        metrics.update(load_balance=aux["load_balance"],
                       router_z=aux["router_z"],
                       dropped=aux.get("dropped", jnp.zeros(())))
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, coopt: CoOptConfig = COOPT, *,
                    lr: float = 3e-4, weight_decay: float = 0.1,
                    grad_clip: float = 1.0,
                    num_microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``num_microbatches > 1`` = gradient accumulation: the global batch is
    split on its leading axis and scanned, so per-step activation memory
    scales by 1/n while the optimizer math is unchanged (grads averaged in
    f32). EXPERIMENTS.md §Perf P0 — this is what makes the train_4k shapes
    fit v5e HBM.
    """
    model = get_model(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, coopt), has_aux=True)(params)

    def train_step(params, opt_state: AdamWState, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            n = num_microbatches
            micro = {k: v.reshape(n, v.shape[0] // n, *v.shape[1:])
                     for k, v in batch.items()}

            def body(acc, mb):
                (loss, metrics), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / n, acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, micro)
            metrics = jax.tree.map(lambda x: jnp.mean(x, 0), metricses)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            grad_clip=grad_clip)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


@dataclass
class Trainer:
    cfg: ModelConfig
    coopt: CoOptConfig = COOPT
    lr: float = 3e-4
    seed: int = 0

    def __post_init__(self):
        self.model = get_model(self.cfg)
        self.params = self.model.init(jax.random.PRNGKey(self.seed))
        self.opt_state = adamw_init(self.params)
        self._step = jax.jit(make_train_step(self.cfg, self.coopt,
                                             lr=self.lr))
        self.history = []

    def step(self, batch: Dict[str, Any]) -> Dict[str, float]:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.params, self.opt_state, metrics = self._step(
            self.params, self.opt_state, batch)
        out = {k: float(v) for k, v in metrics.items()}
        self.history.append(out)
        return out

    def fit(self, batches, steps: int, log_every: int = 10,
            log: Optional[Callable[[str], None]] = print):
        it = iter(batches)
        t0 = time.perf_counter()
        for i in range(steps):
            m = self.step(next(it))
            if log and (i % log_every == 0 or i == steps - 1):
                log(f"step {i:4d}  loss {m['loss']:.4f}  "
                    f"nll {m['nll']:.4f}  gnorm {m['grad_norm']:.3f}  "
                    f"({time.perf_counter() - t0:.1f}s)")
        return self.history
