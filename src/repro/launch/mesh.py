"""Production meshes (DESIGN.md §7).

Single pod: TPU v5e-256, mesh (data=16, model=16).
Multi-pod:  2 pods = 512 chips, mesh (pod=2, data=16, model=16) — pods are
data-parallel replicas; the "pod" axis only ever shards batch-like dims (or
KV pages for batch-1 long-context), so no tensor-parallel collective crosses
the inter-pod DCN link.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests on the CPU container."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_sim_mesh(data: int = 4, model: int = 2, pod: int = 1):
    """Simulated small mesh for CPU verification of the sharded KV pool
    (needs ``XLA_FLAGS=--xla_force_host_platform_device_count>=pod*data*model``
    set before the first jax import — see the CI mesh-matrix job)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def kv_shard_count(mesh) -> int:
    """Number of KV-pool page-range shards a mesh implies: the product of
    the mesh axes the cache ``pages`` axis is sharded over
    (``core.opt_kv.PAGES_AXES``, the same partition CACHE_RULES and the
    ``kernels.sharded`` shard_map layer use). Feed this to
    ``EngineConfig.num_shards`` so the host allocator's page ranges coincide
    with device shard boundaries — ``serving.Engine`` derives/checks this
    itself when handed a mesh."""
    from repro.core.opt_kv import PAGES_AXES
    return math.prod(mesh.shape[a] for a in PAGES_AXES if a in mesh.shape)


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~ per-chip usable)
VMEM_BYTES = 128 * 2 ** 20
HBM_BYTES = 16 * 2 ** 30
