"""Production meshes (DESIGN.md §7).

Single pod: TPU v5e-256, mesh (data=16, model=16).
Multi-pod:  2 pods = 512 chips, mesh (pod=2, data=16, model=16) — pods are
data-parallel replicas; the "pod" axis only ever shards batch-like dims (or
KV pages for batch-1 long-context), so no tensor-parallel collective crosses
the inter-pod DCN link.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests on the CPU container."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — roofline denominators.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~ per-chip usable)
VMEM_BYTES = 128 * 2 ** 20
HBM_BYTES = 16 * 2 ** 30
