import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf-iteration profiler: compile one (arch x shape) cell and dump the
top collective ops (with scan multipliers and jaxpr provenance) plus the
roofline terms — the 'profile' the §Perf loop reads (no real TPU here).

  python -m repro.launch.inspect_cell --arch mixtral-8x22b --shape train_4k
"""
import argparse

import jax

from repro.core.coopt import MODES
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_cost import HloCostModel
from repro.launch.steps import make_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="coopt", choices=list(MODES))
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--micro", type=int, default=None)
    args = ap.parse_args(argv)

    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    bundle = make_step(args.arch, args.shape, mesh, MODES[args.mode],
                       num_microbatches=args.micro)
    with mesh:
        compiled = bundle.lower().compile()
    model = HloCostModel(compiled.as_text())
    s = model.summary()
    print(f"flops/dev={s['flops']:.3e}  bytes/dev={s['bytes']:.3e}  "
          f"coll/dev={s['collective_bytes']:.3e}")
    print(f"terms: C={s['flops']/mesh_lib.PEAK_FLOPS_BF16:.2e}s "
          f"M={s['bytes']/mesh_lib.HBM_BW:.2e}s "
          f"X={s['collective_bytes']/mesh_lib.ICI_BW:.2e}s")
    mem = compiled.memory_analysis()
    print(f"temp/dev={mem.temp_size_in_bytes/2**30:.1f}GiB")
    print(f"\ntop {args.top} collectives by wire bytes:")
    for b, d in sorted(model.collective_ops, reverse=True)[:args.top]:
        print(f"  {b:.3e}B  {d}")


if __name__ == "__main__":
    main()
