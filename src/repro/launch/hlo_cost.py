"""HLO-text cost model with while-loop (lax.scan) trip-count resolution.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of its
trip count (verified on this jax build), so any scan-over-layers model is
undercounted by ~num_layers x. This analyzer walks the optimized per-device
HLO text instead:

  * builds a module-wide symbol table (%op -> output shape) so operand
    traffic and dot contraction sizes can be resolved (operand types are
    not printed inline in this HLO dialect),
  * traverses ENTRY and, recursively, every while body with a multiplier =
    the loop's trip count (largest integer constant in the loop condition),
  * FLOPs: 2 * prod(out) * contraction for every dot (+ convolutions via
    output x kernel), x the enclosing multipliers. Elementwise flops inside
    fusions are ignored — dots dominate; documented lower bound,
  * HBM bytes: every traversed top-level op is one fused kernel:
    traffic = output bytes + operand bytes. Plumbing ops (parameter /
    constant / tuple / get-tuple-element / bitcast) are skipped,
  * collective wire bytes: ring estimates on the output buffer (all-reduce
    2x, others 1x), x multipliers — collectives inside the layer scan DO
    run once per layer.

All numbers are per device (the module is already SPMD-partitioned).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}

Shape = List[Tuple[str, List[int]]]           # [(dtype, dims), ...]

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_OPNAME_RE = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_CALLEE_RE = re.compile(r"(\w+)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "after-all",
         "iota", "bitcast", "partition-id", "replica-id"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(txt: str) -> Shape:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes: Shape) -> float:
    return float(sum(math.prod(d) * _DTYPE_BYTES[dt] for dt, d in shapes))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        self.symbols: Dict[str, Shape] = {}
        self._parse(hlo_text)
        self.flops = 0.0
        self.bytes = 0.0
        self.collectives: Dict[str, float] = {}
        self.collective_ops: List[Tuple[float, str]] = []  # (bytes, descr)
        if self._entry:
            self._walk(self.computations[self._entry], 1.0)

    # ------------------------------------------------------------- parsing --
    def _parse(self, text: str) -> None:
        self._entry = None
        name, body = None, []
        for line in text.splitlines():
            s = line.rstrip()
            st = s.strip()
            if name is None:
                if st.endswith("{") and "(" in st:
                    hdr = st.split("(")[0].strip()
                    is_entry = hdr.startswith("ENTRY")
                    name = hdr.replace("ENTRY", "").strip().lstrip("%")
                    if is_entry:
                        self._entry = name
                    body = []
                continue
            if st.startswith("}"):
                self.computations[name] = body
                name = None
                continue
            body.append(st)
            dm = _DEF_RE.match(st)
            if dm:
                # output type = everything before the op name's paren
                om = _OPNAME_RE.match(dm.group(2))
                head = (dm.group(2)[:om.start(1)] if om else
                        dm.group(2).split(" ")[0])
                self.symbols[dm.group(1)] = _parse_shapes(head)
        # parameters: "%p = f32[..] parameter(0)" handled above.

    def _comp(self, ref: str) -> Optional[List[str]]:
        ref = ref.replace("%", "")
        if ref in self.computations:
            return self.computations[ref]
        for k in self.computations:
            if k.endswith(ref):
                return self.computations[k]
        return None

    def _trip_count(self, cond_ref: str) -> int:
        body = self._comp(cond_ref) or []
        consts = [int(m) for line in body for m in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    # --------------------------------------------------------------- walk ---
    def _walk(self, body: List[str], mult: float) -> None:
        for line in body:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            om = _OPNAME_RE.match(rhs)
            if not om:
                continue
            op = om.group(1)
            if op in _SKIP:
                continue

            if op == "while":
                callees = dict(_CALLEE_RE.findall(rhs))
                trip = self._trip_count(callees.get("condition", ""))
                child = self._comp(callees.get("body", ""))
                if child is not None:
                    self._walk(child, mult * trip)
                continue
            if op in ("call", "async-start"):
                callees = dict(_CALLEE_RE.findall(rhs))
                child = self._comp(callees.get("to_apply", ""))
                if child is not None:
                    self._walk(child, mult)
                continue
            if op == "conditional":
                for key, ref in _CALLEE_RE.findall(rhs):
                    if "computation" in key or "branch" in key:
                        child = self._comp(ref)
                        if child is not None:
                            self._walk(child, mult)
                continue

            out_shapes = _parse_shapes(rhs[:om.start(1)])
            paren = rhs[om.end(1):]
            depth, end = 0, len(paren)
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_names = _OPERAND_RE.findall(paren[:end])
            operand_shapes: Shape = []
            for nm in operand_names:
                operand_shapes.extend(self.symbols.get(nm, []))

            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                b = _bytes_of(out_shapes)
                factor = 2 if base == "all-reduce" else 1
                self.collectives[base] = self.collectives.get(base, 0.0) \
                    + b * factor * mult
                meta = re.search(r'op_name="([^"]*)"', rhs)
                self.collective_ops.append(
                    (b * factor * mult,
                     f"{base} x{mult:g} {rhs[:80]} "
                     f"[{meta.group(1)[-120:] if meta else ''}]"))
                self.bytes += (b + _bytes_of(operand_shapes)) * mult
                continue

            # fusions rooted in an in-place cache update: the pass-through
            # buffer (operand with the output's shape) is NOT streamed —
            # only the update region moves. Approximate its traffic by the
            # remaining operands (the update sources).
            if op == "fusion":
                callees = dict(_CALLEE_RE.findall(rhs))
                comp = self._comp(callees.get("calls", "")) or []
                has_dus = any("dynamic-update-slice(" in ln or
                              " scatter(" in ln for ln in comp)
                if has_dus and out_shapes:
                    out_b = _bytes_of(out_shapes)
                    kept = 0.0
                    skipped_buffer = False
                    for nm in operand_names:
                        sh = self.symbols.get(nm, [])
                        if not skipped_buffer and sh and \
                                _bytes_of(sh) == out_b:
                            skipped_buffer = True      # aliased buffer
                            continue
                        kept += _bytes_of(sh)
                    if skipped_buffer:
                        self.bytes += 2.0 * kept * mult
                        continue
                # fusions that READ a slice of a large buffer (paged cache
                # lookups): the buffer is not streamed whole — drop
                # operands >8x the output size, they are sliced.
                if any("dynamic-slice(" in ln for ln in comp) and out_shapes:
                    out_b = _bytes_of(out_shapes)
                    kept = sum(_bytes_of(self.symbols.get(nm, []))
                               for nm in operand_names
                               if _bytes_of(self.symbols.get(nm, []))
                               <= 8 * out_b)
                    self.bytes += (out_b + kept) * mult
                    continue
                # fall through to generic accounting

            # indexed ops: in-place / sliced access touches only the
            # update/output region, not the whole buffer operand
            if op in ("dynamic-slice", "gather"):
                self.bytes += 2.0 * _bytes_of(out_shapes) * mult
                continue
            if op == "dynamic-update-slice":
                upd = (self.symbols.get(operand_names[1], [])
                       if len(operand_names) > 1 else out_shapes)
                self.bytes += 2.0 * _bytes_of(upd) * mult
                continue
            if op == "scatter":
                upd = (self.symbols.get(operand_names[-1], [])
                       if operand_names else out_shapes)
                self.bytes += 2.0 * _bytes_of(upd) * mult
                continue

            self.bytes += (_bytes_of(out_shapes)
                           + _bytes_of(operand_shapes)) * mult

            if op == "dot":
                lhs = self.symbols.get(operand_names[0], []) \
                    if operand_names else []
                contract = 1
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if mcd and mcd.group(1) and lhs:
                    for i in (int(x) for x in mcd.group(1).split(",")):
                        if i < len(lhs[0][1]):
                            contract *= lhs[0][1][i]
                out_elems = sum(math.prod(d) for _, d in out_shapes)
                self.flops += 2.0 * out_elems * contract * mult
            elif op == "convolution":
                out_elems = sum(math.prod(d) for _, d in out_shapes)
                ker = (math.prod(operand_shapes[1][1])
                       if len(operand_shapes) > 1 else 1)
                self.flops += 2.0 * out_elems * ker * mult

    # ------------------------------------------------------------- report ---
    def summary(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": sum(self.collectives.values()),
            "collectives": dict(self.collectives),
        }


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    return HloCostModel(hlo_text).summary()
