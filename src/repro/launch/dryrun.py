import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# 512 host devices back the (2, 16, 16) multi-pod mesh; the (16, 16)
# single-pod mesh uses the first 256 of them.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove memory fits, and extract the roofline terms.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  python -m repro.launch.dryrun --arch yi-34b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] --out results.json

No device buffer is ever allocated: inputs are ShapeDtypeStructs and the
artifact is the compiled executable + its analyses (EXPERIMENTS.md §Dry-run).
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES
from repro.core.coopt import COOPT, MODES
from repro.launch import mesh as mesh_lib
from repro.launch.steps import ShapeSkipped, make_step

# ---------------------------------------------------------------------------
# collective parsing: sum wire bytes per device from the partitioned module
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective op (ring-algorithm estimates on
    the op's output buffer: all-reduce 2x, others 1x; '-done' ops skipped
    so async pairs are not double counted)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        if "-done(" in m.group(0):
            continue
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        factor = 2 if op == "all-reduce" else 1
        out[op] = out.get(op, 0) + b * factor
    return out


# ---------------------------------------------------------------------------
def run_one(arch: str, shape: str, *, multi_pod: bool, coopt=COOPT,
            verbose: bool = True) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16", "devices": n_dev}
    try:
        bundle = make_step(arch, shape, mesh, coopt)
    except ShapeSkipped as e:
        rec["status"] = "skipped"
        rec["reason"] = str(e)
        if verbose:
            print(f"[skip] {arch} x {shape}: {e}")
        return rec

    t0 = time.time()
    with mesh:
        lowered = bundle.lower()
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    rec["cost_raw"] = {k: v for k, v in cost.items()
                       if k in ("flops", "bytes accessed", "transcendentals",
                                "optimal_seconds")}
    # cost_analysis() counts while-loop (scan) bodies ONCE — correct totals
    # come from the trip-count-resolving HLO walker (launch/hlo_cost.py).
    from repro.launch.hlo_cost import analyze_hlo
    hlo_text = compiled.as_text()
    corrected = analyze_hlo(hlo_text)
    rec["cost"] = {"flops": corrected["flops"],
                   "bytes accessed": corrected["bytes"]}
    rec["collectives"] = corrected["collectives"]
    rec["collective_bytes"] = corrected["collective_bytes"]
    rec["collectives_uncorrected"] = collective_bytes(hlo_text)
    rec["status"] = "ok"
    rec["kind"] = bundle.kind
    if verbose:
        flops = rec["cost"].get("flops", 0.0)
        print(f"[ok] {arch} x {shape} ({rec['mesh']}, {bundle.kind}) "
              f"compile={rec['compile_s']}s flops/dev={flops:.3e} "
              f"coll/dev={rec['collective_bytes']:.3e}B "
              f"temp/dev={rec['memory']['temp_bytes']}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS + ["llama13b-gptq"])
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) combination")
    ap.add_argument("--multi-pod", action="store_true",
                    help="(pod=2, data=16, model=16) = 512 chips")
    ap.add_argument("--mode", default="coopt", choices=list(MODES),
                    help="LLM-CoOpt technique set (default: coopt)")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args(argv)

    coopt = MODES[args.mode]
    combos = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
              else [(args.arch, args.shape)])
    if not args.all and (args.arch is None or args.shape is None):
        ap.error("need --arch and --shape (or --all)")

    records, failures = [], 0
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod, coopt=coopt)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        records.append(rec)
        if args.out:  # append incrementally (compiles are slow)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    print(f"\n== dry-run: {ok} ok, {sk} skipped, {failures} failed, "
          f"{len(records)} total ==")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
