"""Launchers: mesh/dryrun/HLO-cost tooling, training and serving entry
points. Import submodules directly (``repro.launch.serve`` etc.) — they pull
in heavy deps (jax mesh setup) lazily."""
