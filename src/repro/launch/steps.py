"""Step builders for the multi-pod dry-run and the real launchers.

For every (architecture x input shape) this module produces:
  * the pure step function  — train_step / prefill_step / serve_step,
  * abstract inputs         — ShapeDtypeStructs (no allocation),
  * in/out shardings        — NamedShardings from the logical-axis rules.

Sharding rules (DESIGN.md §7):
  weights      d_in -> data, d_out -> model, vocab -> data, experts -> data
  activations  batch -> (pod, data), seq -> model (sequence parallelism)
  cache        batch -> (pod, data); pages -> (pod, data) when batch is 1
               (long_500k); kv_heads/head_dim/latent/heads -> model
Any rule whose dim is not divisible by its mesh axes is dropped per-tensor
(handles kv=1 MQA, 56-head yi, whisper's odd vocab, 8-expert mixtral...).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.core.coopt import CoOptConfig, COOPT
from repro.models import get_model
from repro.models.layers import (activation_sharding, make_shardings,
                                 shapes_tree)
from repro.training.train import loss_fn
from repro.training.optimizer import adamw_update, AdamWState

# block-sparse window for dense archs on long_500k (DESIGN.md §5)
LONG_WINDOW = 8192


class ShapeSkipped(Exception):
    """(arch x shape) cell excluded by DESIGN.md §5 (e.g. whisper long_500k)."""


# ---------------------------------------------------------------- rules ----
WEIGHT_RULES = {"d_in": "data", "d_out": "model", "vocab": "data",
                "experts": "data", "moe_d_in": "data"}
CACHE_RULES = {"batch": ("pod", "data"), "pages": ("pod", "data"),
               "kv_heads": "model", "head_dim": "model", "heads": "model",
               "latent": "model", "d_model": "model", "layers": None}
# kernel (shard_map) hot path: the pool leaves are partitioned ONLY along
# the pages axes — each shard streams its own page range through the
# unchanged Pallas kernels (kernels.sharded); heads/latent stay replicated
# on the pool (weights/activations keep their model parallelism), so no
# KV/latent bytes ever cross the interconnect.
KERNEL_CACHE_RULES = {"batch": ("pod", "data"), "pages": ("pod", "data")}
ACT_RULES_SEQ = {"batch": ("pod", "data"), "seq": "model", "ffn": "model",
                 "experts": None}
ACT_RULES_DECODE = {"batch": ("pod", "data"), "ffn": "model",
                    "latent": "model", "head_dim": "model"}
# serving keeps tensor-parallel-only weights: there is no optimizer state to
# shard away, so d_in -> data (ZeRO) would only add per-layer weight
# all-gathers to every decode step (§Perf P3.2)
WEIGHT_RULES_DECODE = {"d_in": None, "d_out": "model", "vocab": "model",
                       "experts": "data", "moe_d_in": "data"}


def axes_pspec(shape: Tuple[int, ...], axes, mesh: Mesh, rules) -> PS:
    """Logical axes -> PartitionSpec with divisibility + used-axis checks.
    Rule values may be a mesh axis name or a tuple of them."""
    entries, used = [], set()
    for dim, ax in zip(shape, axes):
        m = rules.get(ax) if ax else None
        if m is None:
            entries.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a in mesh.shape and a not in used)
        size = math.prod(mesh.shape[a] for a in ms) if ms else 1
        if ms and dim % size == 0:
            entries.append(ms if len(ms) > 1 else ms[0])
            used.update(ms)
        else:
            entries.append(None)
    return PS(*entries)


def cache_shardings(model, batch: int, max_len: int, coopt: CoOptConfig,
                    mesh: Mesh, rules=CACHE_RULES, num_shards: int = 1):
    shapes = model.cache_shape(batch, max_len, coopt, num_shards=num_shards)
    return ({k: jax.ShapeDtypeStruct(sh, dt)
             for k, (sh, dt, _) in shapes.items()},
            {k: NamedSharding(mesh, axes_pspec(sh, ax, mesh, rules))
             for k, (sh, dt, ax) in shapes.items()})


def batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh):
    out = {}
    for k, s in specs.items():
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        out[k] = NamedSharding(
            mesh, axes_pspec(s.shape, axes, mesh,
                             {"batch": ("pod", "data")}))
    return out


# ---------------------------------------------------------------- steps ----
@dataclass
class StepBundle:
    kind: str                       # train | prefill | decode
    fn: Callable                    # pure step function
    args: Tuple[Any, ...]           # abstract ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    cfg: ModelConfig
    shape: InputShape
    coopt: CoOptConfig
    long_window: int = 0

    def jitted(self):
        # donate the mutated state: train updates (params, opt), serving
        # updates the cache — halves the resident footprint of each
        donate = (0, 1) if self.kind == "train" else (2,)
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=donate)

    def lower(self):
        return self.jitted().lower(*self.args)


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long_500k policy (DESIGN.md §5)."""
    if shape.name != "long_500k":
        return cfg
    if cfg.family == "whisper":
        raise ShapeSkipped(
            "whisper-small x long_500k skipped: full-attention decoder, "
            "448-token native context (DESIGN.md §5)")
    return cfg


def long_window_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Window for the block-sparse SkipSet policy on long_500k decode."""
    if shape.name != "long_500k":
        return 0
    if cfg.family in ("rwkv6", "griffin"):
        return 0            # natively sub-quadratic (O(1)/O(window) state)
    if cfg.attn_window:
        return 0            # mixtral: native SWA already windowed
    return LONG_WINDOW      # dense/mla/vlm: Opt-KV SkipSet as block sparsity


def default_microbatches(cfg: ModelConfig) -> int:
    """Gradient-accumulation depth for train_4k (§Perf P0/P4): each extra
    microbatch costs one grad cross-data reduction, so use the fewest that
    fit 16 GiB HBM. MoE dispatch tensors are the hungriest."""
    if cfg.num_experts:
        return 8
    if cfg.family == "griffin":
        return 8        # associative-scan pyramid is the peak, scales ~1/n
    n = get_model(cfg).param_count()
    if n > 6e10:
        return 16       # deepseek-67b: 20.3 GiB at 8 -> 9.2 GiB at 16
    if n > 3e10:
        return 8
    if n > 8e9:
        return 4
    if n > 5e9:
        return 2
    return 1


def make_step(arch_id: str, shape_name: str, mesh: Mesh,
              coopt: CoOptConfig = COOPT, *, lr: float = 3e-4,
              num_microbatches: Optional[int] = None) -> StepBundle:
    kctx = None
    if coopt.use_kernel:
        # Pallas kernels run compiled on TPU, interpret-mode elsewhere;
        # a mesh with sharded pages axes gets the shard_map kernel layer
        from repro.kernels import ops
        ops.configure_for_backend()
        kctx = ops.make_mesh_ctx(mesh)
    cfg = get_config(arch_id)
    shape = get_shape(shape_name)
    cfg = effective_config(cfg, shape)
    model = get_model(cfg)
    lw = long_window_for(cfg, shape)

    params_abs = shapes_tree(model.param_specs())
    wrules = WEIGHT_RULES_DECODE if shape.kind == "decode" else WEIGHT_RULES
    params_sh = make_shardings(model.param_specs(), mesh, wrules)
    batch_abs = model.input_specs(shape)
    batch_sh = batch_shardings(batch_abs, mesh)
    act_rules = ACT_RULES_DECODE if shape.kind == "decode" else ACT_RULES_SEQ

    if shape.kind == "train":
        mu_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs)
        opt_abs = AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                             mu_abs, mu_abs)
        f32_sh = params_sh  # same pspecs; dtype lives in the avals
        opt_sh = AdamWState(NamedSharding(mesh, PS()), f32_sh, f32_sh)

        from repro.training.train import make_train_step
        nm = (num_microbatches if num_microbatches is not None
              else default_microbatches(cfg))
        inner = make_train_step(cfg, coopt, lr=lr, num_microbatches=nm)

        def train_step(params, opt_state, batch):
            with activation_sharding(mesh, act_rules):
                return inner(params, opt_state, batch)

        return StepBundle(
            "train", train_step, (params_abs, opt_abs, batch_abs),
            (params_sh, opt_sh, batch_sh), (params_sh, opt_sh, None),
            cfg, shape, coopt)

    # kernel path: pool pages axis padded to tile the mesh's KV shards and
    # partitioned ONLY along the pages axes (the shard_map layer's layout)
    if coopt.use_kernel:
        from repro.launch.mesh import kv_shard_count
        crules, ns = KERNEL_CACHE_RULES, kv_shard_count(mesh)
    else:
        crules, ns = CACHE_RULES, 1
    cache_abs, cache_sh = cache_shardings(
        model, shape.global_batch, shape.seq_len, coopt, mesh, rules=crules,
        num_shards=ns)

    if shape.kind == "prefill":

        def prefill_step(params, batch, cache):
            from repro.kernels import ops
            with ops.mesh_ctx_scope(kctx), \
                    activation_sharding(mesh, act_rules):
                return model.prefill(params, batch, cache, coopt)

        return StepBundle(
            "prefill", prefill_step, (params_abs, batch_abs, cache_abs),
            (params_sh, batch_sh, cache_sh), (None, cache_sh),
            cfg, shape, coopt)

    # decode: ONE new token against a cache of seq_len (serve_step)
    def serve_step(params, batch, cache):
        from repro.kernels import ops
        with ops.mesh_ctx_scope(kctx), \
                activation_sharding(mesh, act_rules):
            return model.decode_step(params, batch, cache, coopt,
                                     long_window=lw)

    return StepBundle(
        "decode", serve_step, (params_abs, batch_abs, cache_abs),
        (params_sh, batch_sh, cache_sh), (None, cache_sh),
        cfg, shape, coopt, long_window=lw)


# ------------------------------------------------ serving AOT warmup ----
def serving_warmup(engine) -> Dict[str, Any]:
    """AOT-compile the serving engine's whole step-shape lattice at launch
    time (``Engine.warmup``: prefill buckets x packed row buckets x decode,
    ``lower().compile()`` per shape) and return a summary for the launch
    report — after this, steady-state serving performs ZERO new traces
    (``engine.aot_misses`` stays 0)."""
    import time as _time
    t0 = _time.perf_counter()
    built = engine.warmup()
    kinds: Dict[str, int] = {}
    for key in engine._aot:
        kinds[key[0]] = kinds.get(key[0], 0) + 1
    return {"aot_executables": built,
            "aot_by_kind": kinds,
            "warmup_s": round(_time.perf_counter() - t0, 3)}
