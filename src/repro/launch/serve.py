"""Serving launcher: continuous-batching engine over a synthetic ShareGPT
request mix, reporting the paper's two metrics (Eq. 11 latency, Eq. 12
generation throughput).

  python -m repro.launch.serve --arch qwen3-4b --reduced --requests 16 \
      --mode coopt
"""
from __future__ import annotations

import argparse
import copy
import json

import numpy as np

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.data import RequestStream
from repro.serving import Engine, EngineConfig
from repro.serving.sampler import SamplingParams


def serve_workload(arch: str, mode: str, *, requests: int = 16,
                   num_lanes: int = 4, max_len: int = 512,
                   max_new_tokens: int = 24, scale: float = 0.15,
                   seed: int = 0, use_kernel: bool = False,
                   temperature: float = 0.0, num_shards: int = 1,
                   mesh=None):
    """``mesh``: optional jax Mesh — the engine derives/validates the KV
    shard count from its pages axes, places the cache, and (with
    ``use_kernel``) runs the pooled kernels through the shard_map layer."""
    # Pallas kernels run compiled on TPU, interpret-mode elsewhere
    from repro.kernels import ops
    ops.configure_for_backend()
    cfg = get_config(arch)
    coopt = MODES[mode].replace(use_kernel=use_kernel)
    ecfg = EngineConfig(
        num_lanes=num_lanes, max_len=max_len,
        prefill_buckets=(32, 64, 128, 256, max_len),
        sampling=SamplingParams(temperature=temperature), seed=seed,
        num_shards=num_shards)
    engine = Engine(cfg, coopt, ecfg, mesh=mesh)
    stream = RequestStream(cfg.vocab_size, seed=seed, scale=scale)
    reqs = stream.take(requests, max_new_tokens=max_new_tokens)
    for r in reqs:
        engine.add_request(copy.deepcopy(r))
    engine.run()
    s = engine.stats
    return {
        "arch": arch, "mode": mode, "requests": requests,
        "generated_tokens": s.generated_tokens,
        "prefill_time_s": round(s.prefill_time, 4),
        "decode_time_s": round(s.decode_time, 4),
        "latency_s": round(s.total_time, 4),          # Eq. 11
        "throughput_tok_s": round(s.throughput(), 2),  # Eq. 12
        # per-request latency percentiles (TTFT / mean TPOT per request)
        **s.latency_summary(),
        # shared-pool health (global refcounted allocator)
        "pool_pages": s.pool_pages,
        "peak_pool_utilization": round(
            s.peak_pages_in_use / max(s.pool_pages, 1), 4),
        "prefix_hit_rate": round(s.prefix_hit_rate(), 4),
        "preemptions": s.preemptions,
        "rejected": s.rejected,
        # per-shard page-range ownership (mesh (pod, data) axes)
        "kv_shards": s.num_shards,
        "shard_peak_utilization": [
            round(p / max(c, 1), 4)
            for p, c in zip(s.peak_shard_pages_in_use, s.shard_pages)],
        "shard_preemptions": list(s.shard_preemptions),
        "placement_prefix_hits": s.placement_prefix_hits,
        "placement_misses": s.placement_misses,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="coopt", choices=list(MODES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas hot path (interpret mode on CPU)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--shards", type=int, default=1,
                    help="KV-pool page-range shards (= mesh pod*data "
                         "extent; see launch.mesh.kv_shard_count)")
    ap.add_argument("--mesh", action="store_true",
                    help="serve on a simulated (data=--shards, model=1) "
                         "mesh — device cache pages-sharded, kernels via "
                         "the shard_map layer when --use-kernel (needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         ">=shards)")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_sim_mesh
        mesh = make_sim_mesh(data=args.shards, model=1)
    arch = args.arch + ("-reduced" if args.reduced else "")
    out = serve_workload(arch, args.mode, requests=args.requests,
                         num_lanes=args.lanes, max_len=args.max_len,
                         max_new_tokens=args.max_new_tokens,
                         use_kernel=args.use_kernel,
                         temperature=args.temperature,
                         num_shards=args.shards, mesh=mesh)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
