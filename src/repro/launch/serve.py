"""Serving launcher: continuous-batching engine over a synthetic ShareGPT
request mix, reporting the paper's two metrics (Eq. 11 latency, Eq. 12
generation throughput).

  python -m repro.launch.serve --arch qwen3-4b --reduced --requests 16 \
      --mode coopt

Async frontend (``serving.frontend.AsyncEngine``): ``--async`` serves the
same workload through the overlapped host/device pipeline —

  * the client API is ``submit(prompt, max_new_tokens, eos_token) ->
    TokenStream`` (iterate the stream for token ids as they arrive;
    ``cancel(stream)`` abandons a request and frees its pool pages), with
    a background emit worker owning the only host sync;
  * startup AOT-compiles EVERY step shape in the bucket lattice
    (``launch.steps.serving_warmup`` -> ``Engine.warmup``), so steady-state
    serving never traces — ``--assert-aot`` makes the run fail if a single
    step missed the AOT cache or re-traced (the CI warmup-smoke check);
  * ``--arrival-rate R`` replays the requests as a Poisson process with
    mean R requests/s (0 = all submitted up front), so reported TTFT/
    queue-wait percentiles — measured from SUBMISSION — reflect load, not
    just compute;
  * ``--pack`` additionally routes prefill chunks through concat-prefill
    packing (several prompts per row with segment-id isolation;
    dense/moe/mla families).
"""
from __future__ import annotations

import argparse
import copy
import json
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import CacheConfig
from repro.core.coopt import MODES
from repro.data import RequestStream
from repro.serving import AsyncEngine, Engine, EngineConfig
from repro.serving.sampler import SamplingParams


def poisson_offsets(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival offsets (s) for ``n`` requests at
    ``rate`` requests/s; zeros when rate is 0 (submit everything up
    front)."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


class ServeRunner:
    """One warmed serving configuration with a repeatable measured pass.

    Factored out of ``serve_workload`` so benchmarks can build SEVERAL
    configurations up front (sync / async / async+pack over the same
    Poisson arrivals) and interleave their measured passes round-robin —
    machine-speed drift between passes then cancels out of the comparison
    instead of biasing whichever cell ran during a slow minute."""

    def __init__(self, arch: str, mode: str, *, requests: int = 16,
                 num_lanes: int = 4, max_len: int = 512,
                 max_new_tokens: int = 24, scale: float = 0.15,
                 seed: int = 0, use_kernel: bool = False,
                 temperature: float = 0.0, num_shards: int = 1,
                 mesh=None, use_async: bool = False,
                 arrival_rate: float = 0.0, pack: bool = False,
                 assert_aot: bool = False, warmup_pass: bool = False,
                 deadline_s: float = 0.0, max_queue_depth=None,
                 max_queued_tokens=None, pool_pages: int = 0,
                 host_pages: int = 0, prefetch_depth: int = 2):
        # Pallas kernels run compiled on TPU, interpret-mode elsewhere
        from repro.kernels import ops
        ops.configure_for_backend()
        cfg = get_config(arch)
        coopt = MODES[mode].replace(use_kernel=use_kernel)
        # all cache knobs travel through ONE CacheConfig (shard count
        # included — EngineConfig.num_shards stays default so the two never
        # conflict); pool_pages=0 keeps the derived num_lanes*pages(max_len)
        ecfg = EngineConfig(
            num_lanes=num_lanes, max_len=max_len,
            prefill_buckets=(32, 64, 128, 256, max_len),
            sampling=SamplingParams(temperature=temperature), seed=seed,
            pack_prefill=pack,
            cache=CacheConfig(num_pages=pool_pages, num_shards=num_shards,
                              host_pages=host_pages,
                              prefetch_depth=prefetch_depth))
        self.engine = Engine(cfg, coopt, ecfg, mesh=mesh)
        stream = RequestStream(cfg.vocab_size, seed=seed, scale=scale)
        self.reqs = stream.take(requests, max_new_tokens=max_new_tokens)
        self.offsets = poisson_offsets(requests, arrival_rate, seed)
        self.use_async = use_async
        self.assert_aot = assert_aot
        self.deadline_s = deadline_s
        self.meta = {"arch": arch, "mode": mode, "requests": requests,
                     "async": use_async, "pack_prefill": pack,
                     "arrival_rate_req_s": arrival_rate,
                     "deadline_s": deadline_s,
                     "max_queue_depth": max_queue_depth,
                     "max_queued_tokens": max_queued_tokens,
                     "pool_pages_requested": pool_pages,
                     "host_tier_pages": host_pages}
        self.frontend = None
        self.last_streams = []          # TokenStreams of the last async pass
        if use_async:
            from repro.launch.steps import serving_warmup
            self.frontend = AsyncEngine(self.engine, warmup=False,
                                        max_queue_depth=max_queue_depth,
                                        max_queued_tokens=max_queued_tokens)
            self.meta.update(serving_warmup(self.engine))
        if warmup_pass:
            # one full pass of the identical workload before the clock
            # starts: sync compiles every bucket it will hit; async does
            # first-call executable setup / device-const caches on top of
            # the AOT warmup
            self._run_pass()
        self._traces_at_warmup = dict(self.engine.trace_counts)

    def measure(self) -> float:
        """One measured pass over the identical arrival process (stats
        reset first); returns the wall-clock seconds."""
        self.engine.stats.__init__()
        return self._run_pass()

    def metrics(self, wall: float) -> dict:
        """Stats snapshot for the LAST measured pass."""
        return _pass_metrics(self.engine.stats, wall)

    def trace_report(self) -> dict:
        """AOT health after measuring (async only): cache misses and any
        post-warmup retraces. Raises when ``assert_aot`` was set and a
        steady-state step traced."""
        if not self.use_async:
            return {}
        retraced = {k: v for k, v in self.engine.trace_counts.items()
                    if v != self._traces_at_warmup.get(k, 0)}
        rep = {"aot_misses": self.engine.aot_misses, "retraces": retraced}
        if self.assert_aot and (self.engine.aot_misses or retraced):
            raise RuntimeError(
                f"steady-state serve traced: aot_misses="
                f"{self.engine.aot_misses}, retraces={retraced}")
        return rep

    def outcome_report(self, wall: float) -> dict:
        """Terminal-status breakdown of the last async pass (resilience
        lane): per-``FinishReason`` counts plus goodput — tokens of
        requests that actually FINISHED per wall second, the number an
        overloaded deployment gets paid for (shed/expired work is load the
        resilience layer refused, so it never counts)."""
        from repro.serving import FinishReason
        streams = self.last_streams
        by_reason = {r.name.lower(): 0 for r in FinishReason}
        good_tokens = 0
        for s in streams:
            assert s.finish_reason is not None, \
                f"stream {s.req.req_id} left without a terminal status"
            by_reason[s.finish_reason.name.lower()] += 1
            if s.finish_reason is FinishReason.FINISHED:
                good_tokens += len(s.req.output)
        n = max(len(streams), 1)
        return {
            "outcomes": by_reason,
            "submitted": len(streams),
            "goodput_tok_s": round(good_tokens / max(wall, 1e-9), 2),
            "shed_rate": round(by_reason["shed"] / n, 4),
            "deadline_hit_rate": round(by_reason["finished"] / n, 4),
        }

    # ------------------------------------------------------------- passes --
    def _run_pass(self) -> float:
        return (self._async_pass() if self.use_async else self._sync_pass())

    def _async_pass(self) -> float:
        frontend = self.frontend
        pending = list(zip(self.offsets, self.reqs))
        self.last_streams = streams = []
        t0 = time.perf_counter()

        def _submit_due():
            while pending and time.perf_counter() - t0 >= pending[0][0]:
                _, r = pending.pop(0)
                streams.append(frontend.submit(
                    r.prompt, max_new_tokens=r.max_new_tokens,
                    eos_token=r.eos_token, deadline_s=self.deadline_s))

        _submit_due()
        while pending:
            # interleave submissions with serving turns at their offsets
            if frontend._has_work:
                frontend._loop_once()
            else:
                time.sleep(min(max(pending[0][0] -
                                   (time.perf_counter() - t0), 0), 0.001))
            _submit_due()
        frontend.run_until_idle()
        return time.perf_counter() - t0

    def _sync_pass(self) -> float:
        engine = self.engine
        pending = [(off, copy.deepcopy(r))
                   for off, r in zip(self.offsets, self.reqs)]
        t0 = time.perf_counter()

        def _add_due():
            while pending and time.perf_counter() - t0 >= pending[0][0]:
                _, rr = pending.pop(0)
                now = time.perf_counter()
                rr.arrival_time = rr.submit_time = now
                engine.add_request(rr)

        _add_due()
        while pending or engine.scheduler.has_work:
            if engine.scheduler.has_work:
                engine.step()
            else:
                time.sleep(min(max(pending[0][0] -
                                   (time.perf_counter() - t0), 0), 0.001))
            _add_due()
        return time.perf_counter() - t0


def serve_workload(arch: str, mode: str, *, repeats: int = 1,
                   assert_aot: bool = False, **kw):
    """``mesh``: optional jax Mesh — the engine derives/validates the KV
    shard count from its pages axes, places the cache, and (with
    ``use_kernel``) runs the pooled kernels through the shard_map layer.
    ``use_async`` drives the workload through ``AsyncEngine`` (AOT-warmed
    pipeline); ``arrival_rate`` > 0 spaces submissions as a Poisson
    process (both loops); ``pack`` enables concat-prefill packing.
    ``warmup_pass`` runs the identical workload once before the measured
    pass (stats reset) so the sync loop's wall clock excludes jit traces —
    the async loop's AOT warmup is excluded the same way. ``repeats`` runs
    the measured pass N times in-process (identical arrivals, stats reset
    each time) and reports the best-wall pass — serving steps are ~ms-scale
    so a single pass is dominated by scheduler/OS noise."""
    runner = ServeRunner(arch, mode, assert_aot=assert_aot, **kw)
    repeats = max(1, int(repeats))
    out = dict(runner.meta)
    out["repeats"] = repeats
    best: dict = {}
    walls = []
    for _ in range(repeats):
        wall = runner.measure()
        walls.append(round(wall, 4))
        if not best or wall < best["wall_s"]:
            best = runner.metrics(wall)
    out.update(best)
    out["repeat_wall_s"] = walls
    out.update(runner.trace_report())
    if runner.use_async and runner.last_streams:
        # terminal-status breakdown of the LAST pass (streams are per-pass)
        out.update(runner.outcome_report(walls[-1]))
    return out


def _pass_metrics(s, wall: float) -> dict:
    """Stats snapshot for one measured pass (``s`` = ``engine.stats``)."""
    return {
        "wall_s": round(wall, 4),
        "generated_tokens": s.generated_tokens,
        "prefill_time_s": round(s.prefill_time, 4),
        "decode_time_s": round(s.decode_time, 4),
        "latency_s": round(s.total_time, 4),          # Eq. 11
        "throughput_tok_s": round(s.throughput(), 2),  # Eq. 12
        "wall_throughput_tok_s": round(
            s.generated_tokens / max(wall, 1e-9), 2),
        # per-request latency percentiles, measured from SUBMISSION
        # (TTFT / mean TPOT / queue wait per request)
        **s.latency_summary(),
        "packed_steps": s.packed_steps,
        "packed_rows_saved": s.packed_rows_saved,
        # shared-pool health (global refcounted allocator)
        "pool_pages": s.pool_pages,
        "peak_pool_utilization": round(
            s.peak_pages_in_use / max(s.pool_pages, 1), 4),
        "prefix_hit_rate": round(s.prefix_hit_rate(), 4),
        "prefix_device_hit_rate": round(s.prefix_device_hit_rate(), 4),
        "prefix_host_hit_rate": round(s.prefix_host_hit_rate(), 4),
        "preemptions": s.preemptions,
        "rejected": s.rejected,
        # host-DRAM KV tier (all zeros when host_pages=0)
        "host_pages": s.host_pages,
        "host_pages_resident": s.host_pages_resident,
        "spilled_pages": s.spilled_pages,
        "host_evictions": s.host_evictions,
        "prefetch_committed": s.prefetch_committed,
        "prefetch_aborted": s.prefetch_aborted,
        "prefetch_held_turns": s.prefetch_held_turns,
        # per-shard page-range ownership (mesh (pod, data) axes)
        "kv_shards": s.num_shards,
        "shard_peak_utilization": [
            round(p / max(c, 1), 4)
            for p, c in zip(s.peak_shard_pages_in_use, s.shard_pages)],
        "shard_preemptions": list(s.shard_preemptions),
        "placement_prefix_hits": s.placement_prefix_hits,
        "placement_misses": s.placement_misses,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="coopt", choices=list(MODES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas hot path (interpret mode on CPU)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--shards", type=int, default=1,
                    help="KV-pool page-range shards (= mesh pod*data "
                         "extent; see launch.mesh.kv_shard_count)")
    ap.add_argument("--mesh", action="store_true",
                    help="serve on a simulated (data=--shards, model=1) "
                         "mesh — device cache pages-sharded, kernels via "
                         "the shard_map layer when --use-kernel (needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         ">=shards)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="AsyncEngine: overlapped host/device pipeline "
                         "with AOT bucket warmup")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request arrival rate (req/s; 0 = all "
                         "up front). Needs --async")
    ap.add_argument("--pack", action="store_true",
                    help="concat-prefill packing (dense/moe/mla)")
    ap.add_argument("--assert-aot", action="store_true",
                    help="fail if any steady-state step misses the AOT "
                         "cache or re-traces (CI warmup smoke)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request deadline (s from submission; 0 = "
                         "none). Queued requests past it are shed "
                         "TIMED_OUT. Needs --async")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="load-shed watermark: pending requests beyond "
                         "this are fast-rejected SHED at submit")
    ap.add_argument("--max-queued-tokens", type=int, default=None,
                    help="load-shed watermark: pending prompt tokens "
                         "beyond this fast-reject SHED at submit")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="device KV pool size in pages (0 = derive "
                         "lanes * pages(max_len)); small values force "
                         "memory pressure")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host-DRAM KV spill tier capacity in pages "
                         "(0 = tier off): LRU-evicted prefix pages spill "
                         "to pinned host memory and prefetch back on "
                         "re-match")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="queued requests scanned per turn for host->HBM "
                         "prefix prefetch")
    ap.add_argument("--repeats", type=int, default=1,
                    help="measured passes (best wall reported)")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_sim_mesh
        mesh = make_sim_mesh(data=args.shards, model=1)
    arch = args.arch + ("-reduced" if args.reduced else "")
    out = serve_workload(arch, args.mode, requests=args.requests,
                         num_lanes=args.lanes, max_len=args.max_len,
                         max_new_tokens=args.max_new_tokens,
                         use_kernel=args.use_kernel,
                         temperature=args.temperature,
                         num_shards=args.shards, mesh=mesh,
                         use_async=args.use_async,
                         arrival_rate=args.arrival_rate, pack=args.pack,
                         assert_aot=args.assert_aot, repeats=args.repeats,
                         deadline_s=args.deadline,
                         max_queue_depth=args.max_queue_depth,
                         max_queued_tokens=args.max_queued_tokens,
                         pool_pages=args.pool_pages,
                         host_pages=args.host_pages,
                         prefetch_depth=args.prefetch_depth)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
