"""Distributed training launcher.

On the CPU container this runs reduced configs on a 1x1 mesh (the e2e
example); on a real v5e pod the same code path lowers the full config on the
(16, 16) production mesh — only ``--mesh`` changes.

  python -m repro.launch.train --arch qwen3-4b --reduced --steps 100 \
      --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.data import TrainPipeline
from repro.launch import mesh as mesh_lib
from repro.launch.steps import (ACT_RULES_SEQ, WEIGHT_RULES, batch_shardings,
                                make_shardings)
from repro.models import get_model
from repro.models.layers import activation_sharding
from repro.training.optimizer import adamw_init
from repro.training.train import loss_fn, make_train_step
from repro.checkpoint import save_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="coopt", choices=list(MODES))
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = args.arch + ("-reduced" if args.reduced else "")
    cfg = get_config(arch)
    coopt = MODES[args.mode]
    mesh = {"host": mesh_lib.make_host_mesh,
            "single": mesh_lib.make_production_mesh,
            "multi": lambda: mesh_lib.make_production_mesh(multi_pod=True)
            }[args.mesh]()

    model = get_model(cfg)
    params_sh = make_shardings(model.param_specs(), mesh, WEIGHT_RULES)
    step_fn = make_train_step(cfg, coopt, lr=args.lr)

    def sharded_step(params, opt_state, batch):
        with activation_sharding(mesh, ACT_RULES_SEQ):
            return step_fn(params, opt_state, batch)

    with mesh:
        params = jax.jit(model.init, out_shardings=params_sh)(
            jax.random.PRNGKey(0))
        opt_state = adamw_init(params)
        jstep = jax.jit(sharded_step)

        pipe = TrainPipeline(cfg.vocab_size, args.batch, args.seq)
        t0 = time.perf_counter()
        for i, raw in zip(range(args.steps), pipe):
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
            if cfg.family == "whisper":
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.num_frames, cfg.d_model), jnp.bfloat16)
            params, opt_state, m = jstep(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"gnorm {float(m['grad_norm']):.3f}  "
                      f"({time.perf_counter() - t0:.1f}s)", flush=True)

    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
