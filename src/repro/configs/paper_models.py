"""The paper's five evaluation models (§4.1): LLaMa(-2)-7B/13B and
LLaMa-Pro-8B, all GPTQ checkpoints in the paper; bf16 weights here
(DESIGN.md §8.4 — weight quantization is orthogonal to the contribution).

All are MHA (kv == q heads): Opt-GQA's restructuring is exactly the paper's
Fig. 4 scenario. ``bench_reduced`` scales each model by the same factor so
Figs. 6-7's model-size trend survives the reduction (CPU benchmarks).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

LLAMA_7B = ModelConfig(
    name="llama7b-gptq", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
    vocab_size=32000, source="arXiv:2302.13971")

LLAMA2_7B = LLAMA_7B.replace(name="llama2-7b-gptq",
                             source="arXiv:2307.09288")

LLAMA_13B = ModelConfig(
    name="llama13b-gptq", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, head_dim=128, d_ff=13824,
    vocab_size=32000, source="arXiv:2302.13971")

LLAMA2_13B = LLAMA_13B.replace(name="llama2-13b-gptq",
                               source="arXiv:2307.09288")

LLAMA_PRO_8B = ModelConfig(  # block-expanded llama2-7b (+8 layers)
    name="llama-pro-8b-gptq", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
    vocab_size=32000, source="arXiv:2401.02415")

PAPER_MODELS = {m.name: m for m in
                (LLAMA_7B, LLAMA2_7B, LLAMA_13B, LLAMA2_13B, LLAMA_PRO_8B)}


def bench_reduced(cfg: ModelConfig, *, layer_div: int = 8,
                  width_div: int = 16, vocab: int = 2048) -> ModelConfig:
    """Proportionally scaled variant: relative model-size differences (the
    x-axis of Figs. 6-7) are preserved."""
    d = cfg.d_model // width_div
    heads = max(d // 64, 1)
    return cfg.replace(
        name=cfg.name + "-bench",
        num_layers=max(cfg.num_layers // layer_div, 2),
        d_model=d, num_heads=heads, num_kv_heads=heads, head_dim=64,
        d_ff=cfg.d_ff // width_div, vocab_size=vocab)
