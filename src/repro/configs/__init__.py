"""Config registry: ``get_config(arch_id)`` + assigned architecture list."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, reduced
from repro.configs.shapes import SHAPES, InputShape, get_shape

# arch-id -> module name
_ARCH_MODULES = {
    "yi-34b": "yi_34b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-small": "whisper_small",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-2b": "internvl2_2b",
    "qwen3-4b": "qwen3_4b",
    "qwen2.5-14b": "qwen2_5_14b",
    "deepseek-67b": "deepseek_67b",
    # the paper's own evaluation model
    "llama13b-gptq": "llama13b_gptq",
}

ARCH_IDS = [k for k in _ARCH_MODULES if k != "llama13b-gptq"]
ALL_IDS = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-reduced"):
        return reduced(get_config(arch_id[: -len("-reduced")]))
    try:
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return mod.CONFIG


__all__ = [
    "ModelConfig", "InputShape", "SHAPES", "ARCH_IDS", "ALL_IDS",
    "get_config", "get_shape", "reduced",
]
