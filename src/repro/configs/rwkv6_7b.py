"""rwkv6-7b — "Finch", attention-free, data-dependent decay [arXiv:2404.05892].

LLM-CoOpt's Opt-KV/Opt-GQA/Opt-Pa are inapplicable (no KV cache, no heads to
group, no pages): implemented WITHOUT the technique — see DESIGN.md §5.
Decode state is O(1): per-layer (H, D, D) wkv state + token-shift buffers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # wkv heads, head_dim 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    source="arXiv:2404.05892",
)
