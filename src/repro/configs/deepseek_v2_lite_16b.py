"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE 2 shared + 64 routed top-6
[arXiv:2405.04434].

Opt-KV applies to the *latent* cache (c_kv + k_rope are still a per-token KV
cache -> FP8 + paging). Opt-GQA degenerates: MLA already shares one latent
across all heads (extreme grouping). See DESIGN.md §5.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="mla",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,         # MLA: all heads read the shared latent
    head_dim=128,            # = qk_nope_head_dim
    d_ff=10944,              # dense FFN (first layer)
    moe_d_ff=1408,           # per assignment: d_ff=1408 per routed expert
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    kv_lora_rank=512,
    q_lora_rank=0,           # v2-lite has no q compression
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    source="arXiv:2405.04434",
)
