"""LLaMa-13B — the paper's own primary evaluation model (LLaMa-13B-GPTQ).

GPTQ int4 weight quantization is a property of the paper's checkpoints, not of
its contribution (DESIGN.md §8.4); we serve bf16 weights. MHA (kv == q heads):
Opt-GQA restructures this into grouped-query attention, which is exactly the
paper's Fig. 4 scenario.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama13b-gptq",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,        # MHA; Opt-GQA regroups to fewer KV heads
    head_dim=128,
    d_ff=13824,
    vocab_size=32000,
    source="arXiv:2302.13971",
)
