"""recurrentgemma-9b — RG-LRU + local attention hybrid, pattern 1 attn : 2 rec
[arXiv:2402.19427].

Opt-KV/Opt-Pa apply to the local-attention layers' (windowed) KV cache;
RG-LRU layers carry O(1) recurrent state (kept bf16/f32 — quantizing the
recurrence would compound error, not claimed by the paper). kv=1 -> MQA.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="griffin",
    num_layers=38,           # 12 x (rec, rec, attn) + (rec, rec)
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=4096,
    conv1d_width=4,
    source="arXiv:2402.19427",
)
