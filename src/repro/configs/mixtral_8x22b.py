"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,              # per-expert hidden dim
    moe_d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    top_k=2,
    attn_window=4096,        # SWA per assignment -> long_500k eligible
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)
