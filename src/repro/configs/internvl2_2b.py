"""internvl2-2b — InternViT (STUB) + InternLM2 language backbone [arXiv:2404.16821].

``input_specs`` provides precomputed patch embeddings (B, num_patches, d_model);
the ViT/projector are not implemented (per task carve-out). The LM backbone is
a llama-style GQA decoder and gets the full LLM-CoOpt treatment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    num_patches=1024,
    source="arXiv:2404.16821",
)
