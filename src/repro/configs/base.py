"""Model / input-shape configuration for the LLM-CoOpt reproduction.

Every assigned architecture is expressed as a frozen ``ModelConfig``.  The
``family`` field selects the model implementation in ``repro.models.registry``:

  dense    – llama-style decoder (yi-34b, qwen*, deepseek-67b, internvl2 LM)
  moe      – dense attention + mixture-of-experts FFN (mixtral)
  mla      – multi-head latent attention + MoE (deepseek-v2-lite)
  rwkv6    – attention-free RWKV-6 "Finch" (data-dependent decay)
  griffin  – RG-LRU + local-attention hybrid (recurrentgemma)
  whisper  – encoder-decoder with stub conv/mel frontend
  vlm      – dense LM consuming stub ViT patch embeddings (internvl2)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""                 # citation (arXiv / model card)

    # -- attention details ----------------------------------------------
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False           # qwen2.5-style bias on qkv projections
    attn_window: int = 0             # 0 = full causal; >0 sliding window
    sink_blocks: int = 1             # Opt-KV SkipSet: KV pages always kept
    rope_theta: float = 10000.0

    # -- MoE --------------------------------------------------------------
    num_experts: int = 0             # routed experts
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_dense_layers: int = 0      # leading layers with dense FFN

    # -- MLA (deepseek-v2) -------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # -- hybrid (griffin / recurrentgemma) ----------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    local_window: int = 0
    lru_width: int = 0
    conv1d_width: int = 4

    # -- encoder-decoder (whisper) ------------------------------------------
    encoder_layers: int = 0
    num_frames: int = 0              # stub frontend: encoder sequence length

    # -- vlm ------------------------------------------------------------------
    num_patches: int = 0             # stub ViT: patch embeddings per image

    # -- misc -----------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "rwkv6"

    @property
    def q_per_kv(self) -> int:
        """Opt-GQA Eq. 7: H_g = H_q / H_k (query heads per group)."""
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def has_subquadratic_path(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §5)."""
        if self.family in ("rwkv6", "griffin"):
            return True
        return self.attn_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models.registry import get_model
        return get_model(self).param_count()

    def active_param_count(self) -> int:
        from repro.models.registry import get_model
        return get_model(self).active_param_count()


@dataclass(frozen=True)
class CacheConfig:
    """Paged-KV pool geometry and hierarchical-cache policy in ONE place.

    Consolidates the knobs that previously crawled through ``BlockManager``,
    ``Scheduler``, ``EngineConfig`` and the model ``cache_shape`` signatures
    as loose positionals (``num_pages`` / ``page_size`` / ``num_shards`` /
    ``enable_prefix_cache``), plus the host-DRAM capacity tier added with
    the hierarchical cache.

    ``num_pages`` is the REQUESTED device pool size in pages, before shard
    padding; the pool actually allocated is
    ``padded_pool_pages(num_pages, num_shards)`` with the last page reserved
    as the SkipSet write sentinel, exactly as when the pool is derived from
    ``num_lanes * pages(max_len)`` (the ``num_pages == 0`` default).
    ``page_size == 0`` inherits ``CoOptConfig.page_size``; ``BlockManager``
    itself requires a resolved (> 0) value.

    ``host_pages > 0`` turns on the host-DRAM spill tier: LRU-evicted
    registered prefix pages are spilled host-side instead of destroyed and
    asynchronously prefetched back (see ``cache/block_manager.py`` module
    docstring for the residency state machine). ``prefetch_depth`` bounds
    how many queued requests the scheduler scans for prefetchable prefixes
    per turn. ``host_quant`` additionally fp8-encodes bf16 pool pages on
    spill (halves host bytes; breaks tier-on/off bit-identity, so it
    defaults off — with ``opt_kv`` pools the pages are already fp8 and the
    spill is byte-lossless either way).
    """
    num_pages: int = 0           # 0 = derive from num_lanes * pages(max_len)
    page_size: int = 0           # 0 = inherit CoOptConfig.page_size
    num_shards: int = 1
    enable_prefix_cache: bool = True
    host_pages: int = 0          # host-DRAM tier capacity in pages; 0 = off
    prefetch_depth: int = 2
    host_quant: bool = False

    def __post_init__(self):
        if self.num_pages < 0 or self.page_size < 0 or self.host_pages < 0:
            raise ValueError("CacheConfig sizes must be >= 0")
        if self.num_shards < 1:
            raise ValueError("CacheConfig.num_shards must be >= 1")

    def replace(self, **kw) -> "CacheConfig":
        return dataclasses.replace(self, **kw)

    def resolve(self, *, page_size: int, num_pages: int) -> "CacheConfig":
        """Fill the inherit-defaults (0) fields from the engine context."""
        return self.replace(page_size=self.page_size or page_size,
                            num_pages=self.num_pages or num_pages)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=2, moe_d_ff=128,
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.family == "mla":
        kw.update(kv_lora_rank=64, q_lora_rank=0, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64)
    if cfg.family == "griffin":
        # keep one full pattern period: (rec, rec, attn)
        kw.update(num_layers=3, lru_width=256, local_window=64)
    if cfg.family == "whisper":
        kw.update(encoder_layers=2, num_frames=32)
    if cfg.family == "vlm":
        kw.update(num_patches=16)
    if cfg.attn_window:
        kw.update(attn_window=64)
    return cfg.replace(**kw)
