"""whisper-small — encoder-decoder, conv/mel frontend is a STUB [arXiv:2212.04356].

``input_specs`` provides precomputed frame embeddings (B, num_frames, d_model);
the conv feature extractor + mel spectrogram are not implemented (per task
carve-out). long_500k is skipped (full-attention decoder, no window variant) —
DESIGN.md §5.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="whisper",
    num_layers=12,           # decoder layers
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,         # kv=12 -> GQA group size 1 (identity grouping)
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    num_frames=1500,         # 30 s audio after conv stride-2
    source="arXiv:2212.04356",
)
