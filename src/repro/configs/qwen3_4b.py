"""qwen3-4b — dense GQA with per-head q/k RMSNorm [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,            # decoupled from d_model (32*128 != 2560)
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
