"""MLA absorbed chunk-continuation-prefill Pallas kernel over the GLOBAL
paged LATENT pool — the chunk analogue of ``paged_latent_decode``, closing
the unified ragged step path for the MLA family.

A CHUNK of queries per lane (a decode lane is a chunk of length 1), each row
carrying its own absolute position, attends the lane's *already-cached*
latent history — prefix-cache hits, earlier chunks, and the chunk itself
(written before attention) — in matrix-absorption form. Queries arrive
already absorbed through ``w_uk`` (rows are (seq, head) pairs in LATENT
space), so every latent page is streamed into VMEM once per query tile and
shared by all H heads; K/V are never materialised per head, and the pool is
never gathered host-side (the ``jnp.take`` full-pool materialisation this
kernel replaces).

Latent pool addressing — identical to ``paged_latent_decode`` (see its
module docstring for the full scheme): ``lat_pages (P_total, ps, R+dr)``
packs ``[c_kv | k_rope]`` per token; ``scale_pages (P_total, ps, 2)`` holds
the DUAL FP8 scales (col 0 = c_kv, col 1 = k_rope — separate dynamic
ranges, Eq. 6); the lane's physical page table is scalar-prefetched and
dereferenced in the BlockSpec index_map (-1 = unallocated/SkipSet, never
DMA'd — the pool's sentinel last page never appears in a table).

Grid: (batch, q_group, logical_page). Per-row positions ride along as a
VMEM input blocked with the query tiles; the causal / sliding-window / sink
masks compare them against ``logical_page * ps + iota`` (Eq. 9's valid-block
filter in the logical page domain, Eq. 10's online softmax across pages).
Pages entirely in the future of a query tile are skipped by the same
``pl.when`` predicate using the tile's maximum position. The (m, l, acc)
accumulator is VMEM-resident with acc in LATENT space (rl, R); the ``w_uv``
expansion stays outside so weights never enter VMEM.

Tile-resident chunk streaming: the page dim is innermost and every row-side
block (ql, qr, positions, out, state, scratch) is keyed on the RESIDENT
GROUP index only, so the group stays VMEM-resident across the inner page
loop and a latent page is DMA'd once per group, not once per small query
tile. ``resident_rows`` sizes the group (largest divisor of RW = S * H
under ``RESIDENT_ROWS`` that keeps a token's H head rows together); latent
rows are ~4x wider than dense ones (R + 3*128 floats vs 2*D + 3*128), so
the cap is 512 rows (~7.0 MiB double-buffered at R = 512) and the page
re-stream factor is RW / rl instead of the former fixed RW / 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

_NEG = -1e30

# VMEM-resident query-group row budget — half the dense kernel's cap: a
# latent row carries R = kv_lora_rank (typ. 512) accumulator floats, so 512
# rows keep blocks + (m, l, acc) scratch inside the 8 MiB VMEM budget.
RESIDENT_ROWS = 512


def resident_rows(RW: int, H: int, cap: int = 0) -> int:
    """Rows per VMEM-resident query group: the largest multiple of ``H``
    <= cap (default ``RESIDENT_ROWS``) that divides ``RW`` (a token's H head
    rows stay together; ``H`` always qualifies, so the search terminates).
    The page re-stream factor of the chunk kernel is ``RW // rl``."""
    rl = H * max(min(cap or RESIDENT_ROWS, RW) // H, 1)
    while RW % rl:
        rl -= H
    return rl


def _latent_chunk_kernel(phys_ref,                   # scalar prefetch
                         ql_ref, qr_ref, pos_ref, lat_ref, sc_ref,
                         o_ref, *refs,
                         ps: int, R: int, sm_scale: float, opt_kv: bool,
                         window: int, sink: int, num_pages: int,
                         return_state: bool):
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(2)                             # page-table slot
    rl = ql_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = phys_ref[0, b, j]                         # physical page to DMA
    base = phys_ref[1, b, j]                         # in-segment logical page
    pseg = phys_ref[2, b, j]                         # page's segment id
    qpos = pos_ref[0, 0].astype(jnp.int32)           # (rl,) per-row position
    qseg = pos_ref[0, 1].astype(jnp.int32)           # (rl,) per-row segment
    # causal page skip: the page is dead if its first key position is beyond
    # every query row in the tile
    live = jnp.logical_and(page >= 0, base * ps <= jnp.max(qpos))

    @pl.when(live)
    def _compute():
        ql = ql_ref[0].astype(jnp.float32)           # (rl, R)  absorbed q
        qr = qr_ref[0].astype(jnp.float32)           # (rl, dr)
        lat = lat_ref[0]                             # (ps, R+dr)
        c = lat[:, :R]
        r = lat[:, R:]
        if opt_kv:  # Eq. 6: fused dual-scale dequant at the VMEM boundary
            c = c.astype(jnp.float32) * sc_ref[0][:, 0].reshape(ps, 1)
            r = r.astype(jnp.float32) * sc_ref[0][:, 1].reshape(ps, 1)
        else:
            c = c.astype(jnp.float32)
            r = r.astype(jnp.float32)
        s = jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s += jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        s = s * sm_scale                             # (rl, ps)
        kpos = base * ps + jax.lax.broadcasted_iota(jnp.int32, (rl, ps), 1)
        qp = jnp.broadcast_to(qpos[:, None], (rl, ps))
        mask = (kpos <= qp) & (qseg[:, None] == pseg)
        if window:
            mask &= (kpos > qp - window) | (kpos < sink * ps)
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        # hard-zero masked lanes: with packing a page can be ENTIRELY masked
        # for a row (other segment) while m is still _NEG, where exp(s-m_new)
        # would be exp(0)=1 and corrupt (l, acc). Unpacked this is a no-op
        # (exp(_NEG - m) underflows to exactly 0.0 in f32).
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == num_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        if return_state:
            # per-shard partial softmax state for the shard_map lse merge
            mo_ref[0] = m_ref[...]
            lo_ref[0] = l_ref[...]


def latent_chunk_prefill(q_lat, q_rope, positions, lat_pages, scale_pages,
                         phys_table, *, sm_scale: float, opt_kv: bool,
                         window: int = 0, sink_pages: int = 0,
                         block_q: int = 0, return_state: bool = False,
                         interpret: bool = True, seg_q=None, page_seg=None,
                         page_base=None):
    """q_lat: (B, S, H, R) W_uk-absorbed chunk queries; q_rope: (B, S, H, dr);
    positions: (B, S) absolute per-row positions; lat_pages: (P_total, ps,
    R+dr) GLOBAL latent pool [fp8 if opt_kv]; scale_pages: (P_total, ps, 2)
    f32 dual scales or None; phys_table: (B, NP) int32 physical pages in
    logical order (-1 = skip, never DMA'd). The chunk's own latents must
    already be written to the pool. Returns o_lat (B, S, H, R) f32; the
    caller applies the ``w_uv`` expansion. With ``return_state`` also the
    final online-softmax (m, l) as (B, S, H) f32 for the cross-shard
    log-sum-exp merge (``kernels.sharded``).

    Concat-prefill packing: ``seg_q`` (B, S) int32 per-query segment ids,
    ``page_seg`` (B, NP) int32 per-slot segment ids, ``page_base`` (B, NP)
    int32 per-slot IN-SEGMENT logical page index. A query attends a key only
    when segments match; key positions come from ``page_base`` so every
    segment restarts its position domain. Defaults (no packing) reduce to
    the exact previous math: base == slot index, one segment everywhere."""
    B, S, H, R = q_lat.shape
    P, ps, W = lat_pages.shape
    dr = q_rope.shape[-1]
    NP = phys_table.shape[1]
    RW = S * H                                       # row r = s*H + h

    # resident-group sizing: rows stay VMEM-resident across the whole inner
    # page loop, so NQ is the page re-stream factor. block_q = 0 means "as
    # large as the VMEM budget allows" (RESIDENT_ROWS).
    rl = resident_rows(RW, H, block_q)
    NQ = RW // rl

    if seg_q is None:
        seg_q = jnp.zeros((B, S), jnp.int32)
    if page_seg is None:
        page_seg = jnp.zeros((B, NP), jnp.int32)
    if page_base is None:
        page_base = jnp.broadcast_to(jnp.arange(NP, dtype=jnp.int32), (B, NP))

    qlf = q_lat.reshape(B, RW, R)
    qrf = q_rope.reshape(B, RW, dr)
    pos_rep = jnp.repeat(positions.astype(jnp.int32), H, axis=1)  # (B, RW)
    seg_rep = jnp.repeat(seg_q.astype(jnp.int32), H, axis=1)      # (B, RW)
    pos_rep = jnp.stack([pos_rep, seg_rep], axis=1)               # (B, 2, RW)
    table3 = jnp.stack([phys_table.astype(jnp.int32),
                        page_base.astype(jnp.int32),
                        page_seg.astype(jnp.int32)])              # (3, B, NP)

    if scale_pages is None:
        scale_pages = jnp.zeros((P, ps, 2), jnp.float32)

    def lat_idx(b, i, j, phys):
        return (jnp.maximum(phys[0, b, j], 0), 0, 0)

    out_blk = pl.BlockSpec((1, rl, R), lambda b, i, j, phys: (b, i, 0))
    st_blk = pl.BlockSpec((1, rl, 128), lambda b, i, j, phys: (b, i, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((B, RW, R), jnp.float32)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((B, RW, 128), jnp.float32)] * 2

    kern = functools.partial(_latent_chunk_kernel, ps=ps, R=R,
                             sm_scale=sm_scale, opt_kv=opt_kv, window=window,
                             sink=sink_pages, num_pages=NP,
                             return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, NQ, NP),
            in_specs=[
                pl.BlockSpec((1, rl, R), lambda b, i, j, phys: (b, i, 0)),
                pl.BlockSpec((1, rl, dr), lambda b, i, j, phys: (b, i, 0)),
                pl.BlockSpec((1, 2, rl), lambda b, i, j, phys: (b, 0, i)),
                pl.BlockSpec((1, ps, W), lat_idx),
                pl.BlockSpec((1, ps, 2), lat_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((rl, 128), jnp.float32),
                pltpu.VMEM((rl, 128), jnp.float32),
                pltpu.VMEM((rl, R), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table3, qlf, qrf, pos_rep, lat_pages, scale_pages)
    out = res[0].reshape(B, S, H, R)
    if not return_state:
        return out
    return (out, res[1][..., 0].reshape(B, S, H),
            res[2][..., 0].reshape(B, S, H))
