"""Pure-jnp oracles for every Pallas kernel (flat softmax, no blocking, no
online accumulation) — the ground truth for the per-kernel allclose sweeps.
Deliberately written in the most naive form so a kernel bug cannot be
mirrored here. Layouts follow the GLOBAL paged pool: kv pages carry no batch
dimension; lanes address the pool through (physical, logical) page tables.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax

from repro.cache.quant import FP8_MAX

_NEG = -1e30


def _dq(pages, scales, opt_kv):
    if opt_kv:
        return pages.astype(jnp.float32) * scales[..., None]
    return pages.astype(jnp.float32)


def paged_pool_decode_ref(q, k_pages, v_pages, k_scale, v_scale, cache_len,
                          phys_table, log_table, *, opt_kv: bool,
                          window: int = 0, sink_pages: int = 0):
    """Flat-softmax oracle of the fused pooled decode kernel.

    q (B,Hq,D); k/v_pages (P_total, ps, Hkv, D); phys/log_table (B, NSel),
    -1 = skipped. Gathers each lane's selected pages, places token j of
    logical page L at position L*ps+j, and reduces with one flat softmax —
    the kernel's online accumulation must match this exactly (modes agree
    numerically; Opt-Pa/Opt-GQA only change the compute schedule).
    """
    B, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    pt = jnp.maximum(phys_table, 0)
    k = _dq(jnp.take(k_pages, pt, axis=0),
            None if k_scale is None else jnp.take(k_scale, pt, axis=0),
            opt_kv)                                     # (B,NSel,ps,Hkv,D)
    v = _dq(jnp.take(v_pages, pt, axis=0),
            None if v_scale is None else jnp.take(v_scale, pt, axis=0),
            opt_kv)
    NSel = phys_table.shape[1]
    k = k.reshape(B, NSel * ps, Hkv, D)
    v = v.reshape(B, NSel * ps, Hkv, D)
    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qf, k) / math.sqrt(D)
    pos = (jnp.maximum(log_table, 0)[:, :, None] * ps
           + jnp.arange(ps)[None, None]).reshape(B, -1)
    ok = (pos < cache_len[:, None]) & jnp.repeat(phys_table >= 0, ps, axis=1)
    if window:
        ok &= ((pos >= jnp.maximum(cache_len[:, None] - window, 0))
               | (pos < sink_pages * ps))
    s = jnp.where(ok[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return o.reshape(B, Hq, D).astype(q.dtype)


def kv_cache_write_ref(k_new, v_new, slot_idx, k_cache, v_cache, k_scale,
                       v_scale, *, opt_kv: bool):
    """Scatter-with-drop oracle over the GLOBAL flat pool (NSlot, Hkv, D)
    (sentinel line NSlot-1 is dont-care — the kernel routes SkipSet tokens
    there; callers must compare only real lines)."""
    B, S, Hkv, D = k_new.shape
    slots = jnp.where(slot_idx < 0, -1, slot_idx)       # (B, S)

    def put(cache, scale, new):
        newf = new.astype(jnp.float32)
        if opt_kv:
            amax = jnp.max(jnp.abs(newf), axis=-1)
            sc = jnp.maximum(amax, 1e-12) / FP8_MAX
            qv = (newf / sc[..., None]).astype(cache.dtype)
            cache = cache.at[slots].set(qv, mode="drop")
            scale = scale.at[slots].set(sc, mode="drop")
        else:
            cache = cache.at[slots].set(newf.astype(cache.dtype),
                                        mode="drop")
        return cache, scale

    k_cache, k_scale = put(k_cache, k_scale, k_new)
    v_cache, v_scale = put(v_cache, v_scale, v_new)
    return k_cache, v_cache, k_scale, v_scale


def flash_prefill_ref(q, k, v, *, window: int = 0, q_offset: int = 0):
    """Naive full-matrix causal (windowed) GQA attention."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bshgd,bthd->bhgst", qf, k.astype(jnp.float32)) \
        / math.sqrt(D)
    spos = q_offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = spos >= kpos
    if window:
        mask &= (spos - kpos) < window
    s = jnp.where(mask[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)
