"""Pure-jnp oracles for every Pallas kernel (flat softmax, no blocking, no
online accumulation) — the ground truth for the per-kernel allclose sweeps.
Deliberately written in the most naive form so a kernel bug cannot be
mirrored here. Layouts follow the GLOBAL paged pool: kv pages carry no batch
dimension; lanes address the pool through (physical, logical) page tables.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax

from repro.cache.quant import FP8_MAX

_NEG = -1e30


def _dq(pages, scales, opt_kv):
    if opt_kv:
        return pages.astype(jnp.float32) * scales[..., None]
    return pages.astype(jnp.float32)


def paged_pool_decode_ref(q, k_pages, v_pages, k_scale, v_scale, cache_len,
                          phys_table, log_table, *, opt_kv: bool,
                          window: int = 0, sink_pages: int = 0):
    """Flat-softmax oracle of the fused pooled decode kernel.

    q (B,Hq,D); k/v_pages (P_total, ps, Hkv, D); phys/log_table (B, NSel),
    -1 = skipped. Gathers each lane's selected pages, places token j of
    logical page L at position L*ps+j, and reduces with one flat softmax —
    the kernel's online accumulation must match this exactly (modes agree
    numerically; Opt-Pa/Opt-GQA only change the compute schedule).
    """
    B, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    pt = jnp.maximum(phys_table, 0)
    k = _dq(jnp.take(k_pages, pt, axis=0),
            None if k_scale is None else jnp.take(k_scale, pt, axis=0),
            opt_kv)                                     # (B,NSel,ps,Hkv,D)
    v = _dq(jnp.take(v_pages, pt, axis=0),
            None if v_scale is None else jnp.take(v_scale, pt, axis=0),
            opt_kv)
    NSel = phys_table.shape[1]
    k = k.reshape(B, NSel * ps, Hkv, D)
    v = v.reshape(B, NSel * ps, Hkv, D)
    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qf, k) / math.sqrt(D)
    pos = (jnp.maximum(log_table, 0)[:, :, None] * ps
           + jnp.arange(ps)[None, None]).reshape(B, -1)
    ok = (pos < cache_len[:, None]) & jnp.repeat(phys_table >= 0, ps, axis=1)
    if window:
        ok &= ((pos >= jnp.maximum(cache_len[:, None] - window, 0))
               | (pos < sink_pages * ps))
    s = jnp.where(ok[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return o.reshape(B, Hq, D).astype(q.dtype)


def _dq_latent(lat, scales, lora_rank, opt_kv):
    """Dual-scale latent dequant, written out naively: col 0 scales the
    c_kv segment, col 1 the k_rope segment."""
    lat = lat.astype(jnp.float32)
    if not opt_kv:
        return lat
    c = lat[..., :lora_rank] * scales[..., 0:1]
    r = lat[..., lora_rank:] * scales[..., 1:2]
    return jnp.concatenate([c, r], axis=-1)


def paged_latent_decode_ref(q_lat, q_rope, lat_pages, scale_pages, cache_len,
                            phys_table, log_table, *, sm_scale: float,
                            opt_kv: bool, window: int = 0,
                            sink_pages: int = 0):
    """Flat-softmax oracle of the fused MLA latent decode kernel.

    q_lat (B,H,R) absorbed queries; q_rope (B,H,dr); lat_pages (P_total, ps,
    R+dr) [c_kv|k_rope]; scale_pages (P_total, ps, 2) dual scales | None;
    phys/log_table (B, NSel), -1 = skipped. Gathers each lane's selected
    pages, places token j of logical page L at position L*ps+j, and reduces
    with one flat softmax over the latent-space scores. Returns o_lat
    (B,H,R) f32 — the w_uv expansion stays with the caller."""
    B, H, R = q_lat.shape
    P, ps, W = lat_pages.shape
    NSel = phys_table.shape[1]
    pt = jnp.maximum(phys_table, 0)
    lat = _dq_latent(jnp.take(lat_pages, pt, axis=0),
                     None if scale_pages is None
                     else jnp.take(scale_pages, pt, axis=0),
                     R, opt_kv).reshape(B, NSel * ps, W)
    s = (jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32), lat[..., :R])
         + jnp.einsum("bhe,bte->bht", q_rope.astype(jnp.float32),
                      lat[..., R:])) * sm_scale
    pos = (jnp.maximum(log_table, 0)[:, :, None] * ps
           + jnp.arange(ps)[None, None]).reshape(B, -1)
    ok = (pos < cache_len[:, None]) & jnp.repeat(phys_table >= 0, ps, axis=1)
    if window:
        ok &= ((pos >= jnp.maximum(cache_len[:, None] - window, 0))
               | (pos < sink_pages * ps))
    s = jnp.where(ok[:, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,btr->bhr", p, lat[..., :R])


def latent_chunk_prefill_ref(q_lat, q_rope, positions, lat_pages,
                             scale_pages, phys_table, *, sm_scale: float,
                             opt_kv: bool, window: int = 0,
                             sink_pages: int = 0):
    """Flat-softmax oracle of the MLA latent chunk-prefill kernel: chunk
    queries q_lat (B,S,H,R) / q_rope (B,S,H,dr) with per-row ``positions``
    (B,S) against the gathered latent history. Returns o_lat (B,S,H,R)."""
    B, S, H, R = q_lat.shape
    P, ps, W = lat_pages.shape
    NP = phys_table.shape[1]
    pt = jnp.maximum(phys_table, 0)
    lat = _dq_latent(jnp.take(lat_pages, pt, axis=0),
                     None if scale_pages is None
                     else jnp.take(scale_pages, pt, axis=0),
                     R, opt_kv).reshape(B, NP * ps, W)
    s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                    lat[..., :R])
         + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32),
                      lat[..., R:])) * sm_scale
    kpos = jnp.arange(NP * ps, dtype=jnp.int32)[None, None, :]
    qpos = positions[:, :, None]
    ok = (kpos <= qpos) & jnp.repeat(phys_table >= 0, ps, axis=1)[:, None, :]
    if window:
        ok &= (kpos > qpos - window) | (kpos < sink_pages * ps)
    s = jnp.where(ok[:, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,btr->bshr", p, lat[..., :R])


def kv_cache_write_ref(k_new, v_new, slot_idx, k_cache, v_cache, k_scale,
                       v_scale, *, opt_kv: bool):
    """Scatter-with-drop oracle over the GLOBAL flat pool (NSlot, Hkv, D)
    (sentinel line NSlot-1 is dont-care — the kernel routes SkipSet tokens
    there; callers must compare only real lines)."""
    B, S, Hkv, D = k_new.shape
    slots = jnp.where(slot_idx < 0, -1, slot_idx)       # (B, S)

    def put(cache, scale, new):
        newf = new.astype(jnp.float32)
        if opt_kv:
            amax = jnp.max(jnp.abs(newf), axis=-1)
            sc = jnp.maximum(amax, 1e-12) / FP8_MAX
            qv = (newf / sc[..., None]).astype(cache.dtype)
            cache = cache.at[slots].set(qv, mode="drop")
            scale = scale.at[slots].set(sc, mode="drop")
        else:
            cache = cache.at[slots].set(newf.astype(cache.dtype),
                                        mode="drop")
        return cache, scale

    k_cache, k_scale = put(k_cache, k_scale, k_new)
    v_cache, v_scale = put(v_cache, v_scale, v_new)
    return k_cache, v_cache, k_scale, v_scale


def flash_prefill_ref(q, k, v, *, window: int = 0, q_offset: int = 0):
    """Naive full-matrix causal (windowed) GQA attention."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bshgd,bthd->bhgst", qf, k.astype(jnp.float32)) \
        / math.sqrt(D)
    spos = q_offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = spos >= kpos
    if window:
        mask &= (spos - kpos) < window
    s = jnp.where(mask[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)
