"""Cross-lane shared-prefix visit planning for the pooled decode kernels.

The refcounted ``BlockManager`` pool stores a prefix shared by N lanes ONCE
(copy-on-write page sharing), yet the per-lane decode grid ``(B, heads,
NSel)`` still streams every shared page into VMEM N times per step — the
exact class of redundant KV traffic the paper's Opt-KV/Opt-GQA modes exist
to eliminate, reintroduced one level up by the batch dimension. This module
plans the deduplicated *visit list* that lets one kernel grid step serve
every sharer at once.

Visit-list plan format (the step-plan structure consumed by the
``*_decode_visits`` kernels, documented here alongside its producers):

  ``plan_visits(phys_table, log_table) -> (visit_page, visit_lanes,
  visit_log)`` maps the per-lane ``(B, NSel)`` physical/logical page tables
  onto three flat ``(B * NSel,)`` int32 vectors, one entry per *visit*:

  * ``visit_page``  — physical pool page to DMA, or -1 = skip (padding /
    non-owner duplicate / dead table entry). Exactly one visit per distinct
    live (slot, physical, logical) triple survives; duplicates of a page
    across lanes at the same slot collapse into their lowest-lane *owner*.
  * ``visit_lanes`` — int32 bitmask of member lanes (bit b set ⇔ lane b's
    table holds this same entry). The no-sharing case degenerates to
    one-hot masks and the kernel's per-row updates become bit-identical to
    the per-lane grid. Bitmask width caps the batched path at B <= 32
    lanes; ``ops`` falls back to the per-lane grid beyond that.
  * ``visit_log``   — logical page id (token positions = log * ps + i),
    shared by construction between all members of a visit.

  Visits are ordered slot-major (visit v = s * B + b), so each lane's member
  visits occur in ascending-slot order — the same page order the per-lane
  grid walks, which is what makes the running (m, l, acc) softmax states
  match the per-lane kernel update-for-update.

Dedup keys on (slot, physical, logical) rather than physical id alone:
entries only merge when every member reads the SAME tokens at the SAME
positions, so correctness never depends on how the scheduler laid pages
out. Prefix sharing from the BlockManager is slot-aligned (a shared prefix
occupies the same leading slots in every sharer's table, under both dense
``decode_page_select`` and the windowed sink+window selection), so shared
prefixes are exactly what this key collapses.

The planner is pure ``jnp`` and runs at trace time inside the jitted decode
step — inside ``kernels.sharded``'s shard_map bodies it runs AFTER
``global_to_local_pages``, so each shard plans over its OWN local page
domain and visit lists respect shard-local page ranges for free (non-owned
pages are already -1 there). No new host->device transfer and no new AOT
warmup axis: the visit vectors' shapes are functions of (B, NSel) only,
which the bucket lattice already keys on.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# int32 lane bitmask: the batched-visit kernels address lanes by bit index.
MAX_VISIT_LANES = 32


def plan_visits(phys_table, log_table):
    """Plan the deduplicated visit list for one decode step.

    phys_table/log_table: (B, NSel) int32 per-lane page tables (-1 = skip,
    exactly as fed to the per-lane kernels). Returns (visit_page,
    visit_lanes, visit_log), each (B * NSel,) int32 — see module docstring
    for the plan format. Requires B <= MAX_VISIT_LANES (callers gate this).
    """
    B, _ = phys_table.shape
    lane = jnp.arange(B, dtype=jnp.int32)
    live = phys_table >= 0                                     # (B, NSel)
    # same[b, b2, s]: lanes b and b2 hold the identical live entry at slot s
    same = ((phys_table[:, None, :] == phys_table[None, :, :]) &
            (log_table[:, None, :] == log_table[None, :, :]) &
            live[:, None, :] & live[None, :, :])
    # owner = lowest member lane: no earlier lane b2 < b shares the entry
    earlier = same & (lane[None, :, None] < lane[:, None, None])
    is_owner = live & ~jnp.any(earlier, axis=1)                # (B, NSel)
    bit = jnp.left_shift(jnp.int32(1), lane)                   # (B,)
    bits = jnp.sum(jnp.where(same, bit[None, :, None], 0),
                   axis=1).astype(jnp.int32)                   # (B, NSel)
    visit_page = jnp.where(is_owner, phys_table, -1)
    visit_lanes = jnp.where(is_owner, bits, 0)
    visit_log = jnp.where(is_owner, log_table, -1)
    # slot-major flatten: visit v = s * B + b (ascending slots per lane)
    return (visit_page.T.reshape(-1), visit_lanes.T.reshape(-1),
            visit_log.T.reshape(-1))


def sharing_stats(page_table: np.ndarray) -> dict:
    """Host-side (numpy) sharing observability for ``EngineStats``.

    page_table: (B, NP) int32 physical page table rows for the lanes of one
    decode step (-1 = pad). Dedup is slot-aligned like ``plan_visits`` (a
    BlockManager-shared prefix occupies the same slots in every sharer).
    Returns counts for this step:
      shared_page_visits     — distinct (slot, page) entries held by >1 lane
      dup_page_streams_saved — per-lane page streams the visit grid
                               eliminates: sum over shared entries of
                               (members - 1)
      lanes_per_shared_page  — {member-count: number of shared entries}
    """
    stats = {"shared_page_visits": 0, "dup_page_streams_saved": 0,
             "lanes_per_shared_page": {}}
    if page_table.size == 0:
        return stats
    for s in range(page_table.shape[1]):
        col = page_table[:, s]
        pages, counts = np.unique(col[col >= 0], return_counts=True)
        for n in counts[counts > 1]:
            n = int(n)
            stats["shared_page_visits"] += 1
            stats["dup_page_streams_saved"] += n - 1
            hist = stats["lanes_per_shared_page"]
            hist[n] = hist.get(n, 0) + 1
    return stats
