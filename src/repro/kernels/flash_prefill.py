"""Blockwise causal GQA flash-attention Pallas kernel — the Opt-Pa strategy
("first segment long sequences into manageable chunks, then apply lazy ...
computation", paper §3.3) applied to the prefill phase.

Queries arrive grouped (Opt-GQA): rows are (seq, group) pairs for one KV head,
so each KV tile is streamed once per group of G query heads. The online
softmax across KV blocks is the same Eq. 10 block-wise reduction as decode.
Causal skipping: KV blocks entirely in the future of a query block are
predicated off; with a sliding window, KV blocks entirely before the window
are skipped too — Eq. 9's valid-block filter in both directions.

Tiles: q (block_q rows, D lanes), kv (block_k, D). block_q rows span
block_q // G sequence positions; both default to 128/256 (MXU-aligned).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

_NEG = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                    *, block_q: int, block_k: int, G: int, window: int,
                    num_kv_blocks: int, q_offset: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    D = q_ref.shape[-1]

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # query rows r = s*G + g  ->  seq position s = r // G
    row0 = qb * block_q
    q_first = q_offset + row0 // G                     # first seq pos in tile
    q_last = q_offset + (row0 + block_q - 1) // G
    k0 = kb * block_k
    live = k0 <= q_last                                 # some key <= some query
    if window:
        live = jnp.logical_and(live, k0 + block_k - 1 >= q_first - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (block_q, D)
        k = k_ref[0, 0].astype(jnp.float32)             # (block_k, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        spos = q_offset + rows // G
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = spos >= kpos
        if window:
            mask &= (spos - kpos) < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, window: int = 0, block_q: int = 256,
                  block_k: int = 256, q_offset: int = 0,
                  interpret: bool = True):
    """q: (B, S, Hq, D); k, v: (B, T, Hkv, D). Causal (optionally windowed)
    grouped-query flash attention. Returns (B, S, Hq, D) in q.dtype."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    R = S * G                                           # grouped query rows
    # rows of one seq position must stay in one tile => block_q % G == 0
    bq = min(block_q, R)
    while R % bq or bq % G:
        bq -= 1
    bk = min(block_k, T)
    while T % bk:
        bk //= 2
    bk = max(bk, 1)
    NQ, NK = R // bq, T // bk

    # (B,S,Hq,D) -> (B,Hkv,S*G,D): row r = s*G + g
    qf = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 1, 3, 4) \
          .reshape(B, Hkv, R, D)
    kf = k.transpose(0, 2, 1, 3)                        # (B,Hkv,T,D)
    vf = v.transpose(0, 2, 1, 3)

    kern = functools.partial(_prefill_kernel, block_q=bq, block_k=bk, G=G,
                             window=window, num_kv_blocks=NK,
                             q_offset=q_offset)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, NQ, NK),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hkv, S, G, D).transpose(0, 2, 1, 3, 4) \
              .reshape(B, S, Hq, D)
