"""Fused MLA absorbed-decode Pallas kernel over the GLOBAL paged LATENT pool.

This is ``paged_gqa_decode`` taken to the Opt-GQA limit G = H: MLA caches ONE
shared latent stream per token — the compressed c_kv (R = kv_lora_rank floats)
concatenated with the shared rotary key k_rope (dr floats) — and ALL H query
heads attend it in matrix-absorption form. Each latent page is therefore
streamed into VMEM exactly ONCE per decode step and shared by every absorbed
query head; there is no per-head KV expansion anywhere on the path (Eq. 7/8's
sharing argument with a group of size H).

Latent pool addressing (one layer):
  * ``lat_pages (P_total, ps, R+dr)`` — NO batch dimension; every lane shares
    the pool. A token's cache line packs ``[c_kv | k_rope]`` back to back, so
    one DMA fetches both score streams.
  * ``scale_pages (P_total, ps, 2)`` — DUAL per-token FP8 scales (Eq. 6):
    column 0 dequantizes the c_kv segment, column 1 the k_rope segment. The
    two segments come from different projections with different dynamic
    ranges; a shared scale would crush the smaller segment's mantissa.
  * Each lane's *physical* page table is scalar-prefetched and dereferenced
    inside the BlockSpec index_map, so the block DMA'd at grid step (b, i)
    IS lane b's i-th selected page — lazy page mapping as data-dependent
    prefetch (Opt-Pa). A parallel *logical* table supplies token positions.
    Entries of -1 (unallocated, SkipSet, beyond-context under Eq. 9
    filtering, or outside the {sink + sliding-window} policy) are predicated
    off with ``pl.when``: neither DMA'd (index_map redirects to page 0) nor
    computed. The pool's final page is the write path's SkipSet sentinel —
    the BlockManager never allocates it, so it never appears in a table.

The kernel fuses: dual-scale FP8 dequant at the HBM->VMEM boundary (Eq. 6),
the absorbed score ``s_h(t) = <q_lat_h, c_t> + <q_rope_h, k_rope_t>``, and a
VMEM-resident running (m, l, acc) block-wise softmax across the page grid
dim (Eq. 10). The accumulator lives in LATENT space (H, R) — the ``w_uk``
absorption and ``w_uv`` expansion stay OUTSIDE the kernel, so weight
matrices never enter VMEM and the output projection remains one dense
einsum per step.

The windowed variant (block-sparse long-context policy) is the same kernel
with ``window``/``sink_pages`` static parameters, matching
``opt_kv.window_page_table`` semantics: the caller passes the {sink +
sliding-window} page selection, positions come from the logical table, and
out-of-policy tokens are masked in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

_NEG = -1e30


def _latent_kernel(len_ref, phys_ref, log_ref,       # scalar prefetch
                   ql_ref, qr_ref, lat_ref, sc_ref,
                   o_ref, *refs,
                   ps: int, R: int, sm_scale: float, opt_kv: bool,
                   window: int, sink: int, num_sel: int,
                   return_state: bool):
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    s_i = pl.program_id(1)
    H = ql_ref.shape[1]
    length = len_ref[b]
    page = phys_ref[b, s_i]
    lpage = log_ref[b, s_i]

    @pl.when(s_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Eq. 9 Phase 1: -1 pages (unallocated / beyond context / out of policy)
    # are predicated off — never DMA'd, never computed.
    @pl.when(page >= 0)
    def _compute():
        ql = ql_ref[0].astype(jnp.float32)               # (H, R)  absorbed q
        qr = qr_ref[0].astype(jnp.float32)               # (H, dr)
        lat = lat_ref[0]                                 # (ps, R+dr)
        c = lat[:, :R]
        r = lat[:, R:]
        if opt_kv:  # Eq. 6: fused DUAL-scale dequant at the VMEM boundary
            c = c.astype(jnp.float32) * sc_ref[0][:, 0].reshape(ps, 1)
            r = r.astype(jnp.float32) * sc_ref[0][:, 1].reshape(ps, 1)
        else:
            c = c.astype(jnp.float32)
            r = r.astype(jnp.float32)
        s = jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s += jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        s = s * sm_scale                                 # (H, ps)
        pos = lpage * ps + jax.lax.broadcasted_iota(jnp.int32, (H, ps), 1)
        mask = pos < length
        if window:
            in_win = pos >= jnp.maximum(length - window, 0)
            in_sink = pos < sink * ps
            mask &= in_win | in_sink
        s = jnp.where(mask, s, _NEG)

        # Eq. 10 Phase 2: block-wise softmax, VMEM running reduce — the
        # accumulator stays in latent space (H, R).
        m_prev = m_ref[:, 0:1]                           # (H, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # (H, ps)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s_i == num_sel - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if return_state:
            # per-shard partial softmax state for the shard_map lse merge
            mo_ref[0] = m_ref[...]
            lo_ref[0] = l_ref[...]


def paged_latent_decode(q_lat, q_rope, lat_pages, scale_pages, cache_len,
                        phys_table, log_table, *, sm_scale: float,
                        opt_kv: bool, window: int = 0, sink_pages: int = 0,
                        return_state: bool = False, interpret: bool = True):
    """q_lat: (B, H, R) W_uk-absorbed queries; q_rope: (B, H, dr); lat_pages:
    (P_total, ps, R+dr) GLOBAL latent pool [fp8 if opt_kv]; scale_pages:
    (P_total, ps, 2) f32 dual c/k_rope scales or None; cache_len: (B,) int32;
    phys_table/log_table: (B, NSel) int32 — physical page to DMA / logical
    page id for positions; -1 = skip (never DMA'd). ``sm_scale`` is the
    softmax scale 1/sqrt(dn+dr) — NOT derivable from R (absorption changes
    the contraction width, not the score scale). Returns o_lat (B, H, R) f32;
    the caller applies the ``w_uv`` expansion. With ``return_state`` also
    the final online-softmax (m, l) as (B, H) f32 for the cross-shard
    log-sum-exp merge (``kernels.sharded``)."""
    B, H, R = q_lat.shape
    P, ps, W = lat_pages.shape
    NSel = phys_table.shape[1]

    if scale_pages is None:
        scale_pages = jnp.zeros((P, ps, 2), jnp.float32)

    def lat_idx(b, s, L, phys, log):
        return (jnp.maximum(phys[b, s], 0), 0, 0)

    out_blk = pl.BlockSpec((1, H, R), lambda b, s, L, phys, log: (b, 0, 0))
    st_blk = pl.BlockSpec((1, H, 128), lambda b, s, L, phys, log: (b, 0, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((B, H, R), jnp.float32)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((B, H, 128), jnp.float32)] * 2

    kern = functools.partial(_latent_kernel, ps=ps, R=R, sm_scale=sm_scale,
                             opt_kv=opt_kv, window=window, sink=sink_pages,
                             num_sel=NSel, return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, NSel),
            in_specs=[
                pl.BlockSpec((1, H, R), lambda b, s, L, phys, log: (b, 0, 0)),
                pl.BlockSpec((1, H, q_rope.shape[-1]),
                             lambda b, s, L, phys, log: (b, 0, 0)),
                pl.BlockSpec((1, ps, W), lat_idx),
                pl.BlockSpec((1, ps, 2), lat_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((H, 128), jnp.float32),
                pltpu.VMEM((H, 128), jnp.float32),
                pltpu.VMEM((H, R), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, phys_table, log_table, q_lat, q_rope, lat_pages,
      scale_pages)
    if not return_state:
        return res[0]
    return res[0], res[1][..., 0], res[2][..., 0]


def _latent_visit_kernel(vp_ref, vm_ref, vl_ref,     # scalar prefetch
                         ql_ref, qr_ref, len_ref, lat_ref, sc_ref,
                         o_ref, *refs,
                         ps: int, R: int, H: int, sm_scale: float,
                         opt_kv: bool, window: int, sink: int,
                         num_visits: int, return_state: bool):
    """Cross-lane visit grid for the absorbed-MLA decode (see
    ``paged_gqa_decode._visit_kernel`` for the scheme). Rows of all lanes'
    absorbed queries ride VMEM-resident as one (BH, R) tile (BH = B * H,
    row r = lane * H + head); each deduplicated visit streams and
    dual-dequantizes its latent page ONCE and updates every member lane's
    running (m, l, acc) state; non-member rows take exact identity updates
    so the no-sharing plan is bit-identical to ``_latent_kernel``."""
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    v_i = pl.program_id(0)
    BH = ql_ref.shape[0]
    page = vp_ref[v_i]
    lpage = vl_ref[v_i]
    lanes = vm_ref[v_i]

    @pl.when(v_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(page >= 0)
    def _compute():
        ql = ql_ref[...].astype(jnp.float32)             # (BH, R)
        qr = qr_ref[...].astype(jnp.float32)             # (BH, dr)
        lat = lat_ref[0]                                 # (ps, R+dr)
        c = lat[:, :R]
        r = lat[:, R:]
        if opt_kv:  # Eq. 6 dual-scale dequant — ONCE per visit, not per lane
            c = c.astype(jnp.float32) * sc_ref[0][:, 0].reshape(ps, 1)
            r = r.astype(jnp.float32) * sc_ref[0][:, 1].reshape(ps, 1)
        else:
            c = c.astype(jnp.float32)
            r = r.astype(jnp.float32)
        s = jax.lax.dot_general(ql, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s += jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        s = s * sm_scale                                 # (BH, ps)
        lane_r = jax.lax.broadcasted_iota(jnp.int32, (BH, 1), 0) // H
        member = jnp.equal(
            jnp.bitwise_and(jnp.right_shift(lanes, lane_r), 1), 1)
        length = len_ref[:, 0:1]                         # (BH, 1)
        pos = lpage * ps + jax.lax.broadcasted_iota(jnp.int32, (BH, ps), 1)
        mask = member & (pos < length)
        if window:
            in_win = pos >= jnp.maximum(length - window, 0)
            in_sink = pos < sink * ps
            mask &= in_win | in_sink
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, 0:1]                           # (BH, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(member, jnp.exp(s - m_new), 0.0)   # (BH, ps)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(v_i == num_visits - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if return_state:
            mo_ref[...] = m_ref[...]
            lo_ref[...] = l_ref[...]


def paged_latent_decode_visits(q_lat, q_rope, lat_pages, scale_pages,
                               cache_len, visit_page, visit_lanes, visit_log,
                               *, sm_scale: float, opt_kv: bool,
                               window: int = 0, sink_pages: int = 0,
                               return_state: bool = False,
                               interpret: bool = True):
    """Batched-visit twin of ``paged_latent_decode``: the page grid dim
    iterates a deduplicated cross-lane visit list (``kernels.visits``) so a
    latent page shared by N lanes is streamed/dequantized once per step.
    visit_page/visit_lanes/visit_log: (NV,) int32 plan vectors; requires
    B <= visits.MAX_VISIT_LANES."""
    B, H, R = q_lat.shape
    P, ps, W = lat_pages.shape
    dr = q_rope.shape[-1]
    NV = visit_page.shape[0]
    BH = B * H
    # rows r = b * H + h: the natural reshape is already lane-contiguous
    qlf = q_lat.reshape(BH, R)
    qrf = q_rope.reshape(BH, dr)
    len_rows = jnp.broadcast_to(
        cache_len.astype(jnp.int32)[:, None, None], (B, H, 128)
    ).reshape(BH, 128)

    if scale_pages is None:
        scale_pages = jnp.zeros((P, ps, 2), jnp.float32)

    def lat_idx(v, vp, vl, vm):
        return (jnp.maximum(vp[v], 0), 0, 0)

    out_blk = pl.BlockSpec((BH, R), lambda v, vp, vl, vm: (0, 0))
    st_blk = pl.BlockSpec((BH, 128), lambda v, vp, vl, vm: (0, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((BH, R), jnp.float32)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((BH, 128), jnp.float32)] * 2

    kern = functools.partial(_latent_visit_kernel, ps=ps, R=R, H=H,
                             sm_scale=sm_scale, opt_kv=opt_kv, window=window,
                             sink=sink_pages, num_visits=NV,
                             return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(NV,),
            in_specs=[
                pl.BlockSpec((BH, R), lambda v, vp, vl, vm: (0, 0)),
                pl.BlockSpec((BH, dr), lambda v, vp, vl, vm: (0, 0)),
                pl.BlockSpec((BH, 128), lambda v, vp, vl, vm: (0, 0)),
                pl.BlockSpec((1, ps, W), lat_idx),
                pl.BlockSpec((1, ps, 2), lat_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((BH, 128), jnp.float32),
                pltpu.VMEM((BH, 128), jnp.float32),
                pltpu.VMEM((BH, R), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(visit_page, visit_lanes, visit_log, qlf, qrf, len_rows,
      lat_pages, scale_pages)
    out = res[0].reshape(B, H, R)
    if not return_state:
        return out
    m = res[1][..., 0].reshape(B, H)
    l = res[2][..., 0].reshape(B, H)
    return out, m, l
