"""jit'd public wrappers around the Pallas kernels + cache-layout adapters.

The engine-facing cache layout is the GLOBAL paged pool — per-layer leaves
``(2, P_total, ps, Hkv, D)`` with NO batch dimension, shared by every lane;
these wrappers slice it into the kernels' (P_total, ps, Hkv, D) k/v views
(zero-copy) and plug into ``repro.core`` when ``CoOptConfig.use_kernel``.
Lanes address the pool through scalar-prefetched page tables (physical page
to DMA + logical page for positions) dereferenced inside BlockSpec
index_maps, and the write path scatters to global flat slots (the pool's
last cache line is the reserved SkipSet sentinel).

ONE hot path, single-host AND distributed: when a ``sharded.ShardCtx`` is
installed (``set_mesh_ctx`` — the engine and ``launch.steps`` bind it at
trace time from their mesh), every wrapper dispatches to the ``shard_map``
layer in ``kernels.sharded`` — the same kernels run per mesh shard against
their owned page range, partial softmax states are lse-merged across the
pages axes, and writes stay shard-local. With no ctx (no mesh, or a mesh
whose pages axes have extent 1) the single-device kernels run unchanged.

On this container the kernels run in interpret mode (CPU); on TPU hardware
``configure_for_backend()`` flips ``INTERPRET`` off — the launchers
(``launch.serve.serve_workload``, ``launch.steps.make_step`` engine setup,
``benchmarks.run``) call it at startup.
"""
from __future__ import annotations

from contextlib import contextmanager
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_chunk_prefill as _fc
from repro.kernels import flash_prefill as _fp
from repro.kernels import kv_cache_write as _kw
from repro.kernels import latent_chunk_prefill as _lc
from repro.kernels import paged_gqa_decode as _pd
from repro.kernels import paged_latent_decode as _ld
from repro.kernels import sharded as _sh
from repro.kernels import visits as _vs

INTERPRET = True

# pages-axis shard_map context — None = single-device hot path. Installed at
# TRACE time by whoever owns the mesh (serving.Engine step impls,
# launch.steps step fns), so jit-cached traces can never leak a stale mesh.
_MESH_CTX: Optional[_sh.ShardCtx] = None


def configure_for_backend() -> None:
    global INTERPRET
    INTERPRET = jax.default_backend() != "tpu"


def make_mesh_ctx(mesh) -> Optional[_sh.ShardCtx]:
    """ShardCtx for ``mesh`` (None when its pages axes have extent 1 — an
    unsharded mesh takes the identical code path as no mesh)."""
    return _sh.make_ctx(mesh)


def set_mesh_ctx(ctx: Optional[_sh.ShardCtx]) -> None:
    """Install (or clear) the pages-axis shard_map dispatch context."""
    global _MESH_CTX
    _MESH_CTX = ctx


def mesh_ctx() -> Optional[_sh.ShardCtx]:
    return _MESH_CTX


@contextmanager
def mesh_ctx_scope(ctx: Optional[_sh.ShardCtx]):
    """Bind the dispatch ctx for the duration of a trace and RESTORE the
    previous one after — mesh owners (engine step impls, launch.steps step
    fns) wrap their model calls in this so a trace can neither leak its
    mesh to later direct ops calls nor clobber a ctx a direct caller
    installed."""
    prev = _MESH_CTX
    set_mesh_ctx(ctx)
    try:
        yield
    finally:
        set_mesh_ctx(prev)


# ---------------------------------------------------------------------------
# NOTE: every jitted wrapper below takes `interpret` as a STATIC argument
# fed from the unjitted public dispatcher at call time. Reading the module
# global INTERPRET inside the jitted body would bake its trace-time value
# into the cached executable, so configure_for_backend()'s post-import flip
# would be silently ignored (COOPT004, `python -m repro.analysis`).
def _use_visits(share_visits: bool, B: int) -> bool:
    # the batched-visit grid pays off only with >1 lane, and its int32 lane
    # bitmask caps membership at MAX_VISIT_LANES; beyond either bound the
    # per-lane grid is the degenerate (and bit-identical) fallback
    return bool(share_visits) and 1 < B <= _vs.MAX_VISIT_LANES


@partial(jax.jit, static_argnames=("opt_kv", "opt_gqa", "window",
                                   "sink_pages", "share_visits", "interpret"))
def _paged_pool_decode_single(q, kv_pages, scale_pages, cache_len,
                              phys_table, log_table, *, opt_kv: bool,
                              opt_gqa: bool, window: int, sink_pages: int,
                              share_visits: bool, interpret: bool):
    ks = scale_pages[0] if scale_pages is not None else None
    vs = scale_pages[1] if scale_pages is not None else None
    if _use_visits(share_visits, q.shape[0]):
        # trace-time dedup: pages shared across lanes stream into VMEM once
        vp, vm, vl = _vs.plan_visits(phys_table.astype(jnp.int32),
                                     log_table.astype(jnp.int32))
        return _pd.paged_pool_decode_visits(
            q, kv_pages[0], kv_pages[1], ks, vs, cache_len.astype(jnp.int32),
            vp, vm, vl, opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
            sink_pages=sink_pages, interpret=interpret)
    return _pd.paged_pool_decode(
        q, kv_pages[0], kv_pages[1], ks, vs, cache_len.astype(jnp.int32),
        phys_table.astype(jnp.int32), log_table.astype(jnp.int32),
        opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
        sink_pages=sink_pages, interpret=interpret)


def paged_pool_decode(q, kv_pages, scale_pages, cache_len, phys_table,
                      log_table, *, opt_kv: bool, opt_gqa: bool,
                      window: int = 0, sink_pages: int = 0,
                      share_visits: bool = False):
    """Fused decode over the global pool. q (B,Hq,D); kv_pages
    (2,P_total,ps,Hkv,D); scale_pages (2,P_total,ps,Hkv)|None; phys/log_table
    (B,NSel) int32 (-1 = never DMA'd). ``share_visits`` batches cross-lane
    shared pages through the deduplicated visit grid
    (``kernels.visits.plan_visits``); with no sharing present the result is
    bit-identical to the per-lane grid."""
    if _MESH_CTX is not None:
        return _sh.paged_pool_decode(
            _MESH_CTX, q, kv_pages, scale_pages, cache_len, phys_table,
            log_table, opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
            sink_pages=sink_pages, share_visits=share_visits,
            interpret=INTERPRET)
    return _paged_pool_decode_single(
        q, kv_pages, scale_pages, cache_len, phys_table, log_table,
        opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
        sink_pages=sink_pages, share_visits=share_visits,
        interpret=INTERPRET)


@partial(jax.jit, static_argnames=("opt_kv", "interpret"))
def _kv_cache_write_single(kv_cache, scale_cache, k_new, v_new, slot_idx, *,
                           opt_kv: bool, interpret: bool):
    _, Pt, ps, Hkv, D = kv_cache.shape
    flat_k = kv_cache[0].reshape(Pt * ps, Hkv, D)
    flat_v = kv_cache[1].reshape(Pt * ps, Hkv, D)
    if scale_cache is not None:
        s_k = scale_cache[0].reshape(Pt * ps, Hkv)
        s_v = scale_cache[1].reshape(Pt * ps, Hkv)
    else:
        s_k = jnp.zeros((Pt * ps, Hkv), jnp.float32)
        s_v = s_k
    k_c, v_c, ks_c, vs_c = _kw.kv_cache_write(
        k_new, v_new, slot_idx.astype(jnp.int32), flat_k, flat_v, s_k, s_v,
        opt_kv=opt_kv, interpret=interpret)
    kv = jnp.stack([k_c.reshape(Pt, ps, Hkv, D),
                    v_c.reshape(Pt, ps, Hkv, D)])
    if scale_cache is not None:
        scale_cache = jnp.stack([ks_c.reshape(Pt, ps, Hkv),
                                 vs_c.reshape(Pt, ps, Hkv)])
    return kv, scale_cache


def kv_cache_write(kv_cache, scale_cache, k_new, v_new, slot_idx, *,
                   opt_kv: bool):
    """Engine-layout adapter for the write kernel. kv_cache
    (2,P_total,ps,Hkv,D) global pool (its LAST flat line is the SkipSet
    sentinel — the BlockManager never allocates the final page); returns
    updated (kv_cache, scale_cache). Under a mesh ctx the scatter runs
    shard-local (no sentinel needed: out-of-range slots simply drop)."""
    if _MESH_CTX is not None:
        return _sh.kv_pool_write(_MESH_CTX, kv_cache, scale_cache, k_new,
                                 v_new, slot_idx, opt_kv=opt_kv)
    return _kv_cache_write_single(kv_cache, scale_cache, k_new, v_new,
                                  slot_idx, opt_kv=opt_kv,
                                  interpret=INTERPRET)


def latent_pool_write(lat_cache, scale_cache, latent, slot_idx, *,
                      opt_kv: bool, lora_rank: int):
    """MLA latent write path: dual-scale quantization + flat-slot scatter
    into the global latent pool (lat_cache (P,ps,R+dr); latent (B,S,R+dr);
    -1 slots drop). Under a mesh ctx the scatter runs shard-local; otherwise
    this is the plain jnp scatter (there is no Pallas latent write kernel —
    the write is already one fused scatter)."""
    if _MESH_CTX is not None:
        return _sh.latent_pool_write(_MESH_CTX, lat_cache, scale_cache,
                                     latent, slot_idx, opt_kv=opt_kv,
                                     lora_rank=lora_rank)
    Pt, ps, W = lat_cache.shape
    flat = lat_cache.reshape(Pt * ps, W)
    clipped = jnp.where(slot_idx < 0, -1, slot_idx)
    if opt_kv:
        from repro.cache.quant import quantize_latent
        qv, s = quantize_latent(latent, lora_rank)
        flat = flat.at[clipped].set(qv.astype(flat.dtype), mode="drop")
        sf = scale_cache.reshape(Pt * ps, 2)
        sf = sf.at[clipped].set(s, mode="drop")
        scale_cache = sf.reshape(Pt, ps, 2)
    else:
        flat = flat.at[clipped].set(latent.astype(flat.dtype), mode="drop")
    return flat.reshape(Pt, ps, W), scale_cache


@partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                   "q_offset", "interpret"))
def _flash_prefill_single(q, k, v, *, window: int, block_q: int,
                          block_k: int, q_offset: int, interpret: bool):
    return _fp.flash_prefill(q, k, v, window=window, block_q=block_q,
                             block_k=block_k, q_offset=q_offset,
                             interpret=interpret)


def flash_prefill(q, k, v, *, window: int = 0, block_q: int = 256,
                  block_k: int = 256, q_offset: int = 0):
    """Self-attention prefill over in-chunk K/V (no pool paging)."""
    return _flash_prefill_single(q, k, v, window=window, block_q=block_q,
                                 block_k=block_k, q_offset=q_offset,
                                 interpret=INTERPRET)


@partial(jax.jit, static_argnames=("sm_scale", "opt_kv", "window",
                                   "sink_pages", "share_visits", "interpret"))
def _paged_latent_decode_single(q_lat, q_rope, lat_pages, scale_pages,
                                cache_len, phys_table, log_table, *,
                                sm_scale: float, opt_kv: bool, window: int,
                                sink_pages: int, share_visits: bool,
                                interpret: bool):
    if _use_visits(share_visits, q_lat.shape[0]):
        vp, vm, vl = _vs.plan_visits(phys_table.astype(jnp.int32),
                                     log_table.astype(jnp.int32))
        return _ld.paged_latent_decode_visits(
            q_lat, q_rope, lat_pages, scale_pages,
            cache_len.astype(jnp.int32), vp, vm, vl, sm_scale=sm_scale,
            opt_kv=opt_kv, window=window, sink_pages=sink_pages,
            interpret=interpret)
    return _ld.paged_latent_decode(
        q_lat, q_rope, lat_pages, scale_pages, cache_len.astype(jnp.int32),
        phys_table.astype(jnp.int32), log_table.astype(jnp.int32),
        sm_scale=sm_scale, opt_kv=opt_kv, window=window,
        sink_pages=sink_pages, interpret=interpret)


def paged_latent_decode(q_lat, q_rope, lat_pages, scale_pages, cache_len,
                        phys_table, log_table, *, sm_scale: float,
                        opt_kv: bool, window: int = 0, sink_pages: int = 0,
                        share_visits: bool = False):
    """Fused MLA absorbed decode over the global latent pool. q_lat
    (B,H,R) W_uk-absorbed queries; q_rope (B,H,dr); lat_pages
    (P_total,ps,R+dr) [c_kv|k_rope] packed; scale_pages (P_total,ps,2) dual
    c/k_rope scales | None; phys/log_table (B,NSel) int32 (-1 = never
    DMA'd). Returns o_lat (B,H,R) f32 — w_uv expansion stays outside."""
    if _MESH_CTX is not None:
        return _sh.paged_latent_decode(
            _MESH_CTX, q_lat, q_rope, lat_pages, scale_pages, cache_len,
            phys_table, log_table, sm_scale=sm_scale, opt_kv=opt_kv,
            window=window, sink_pages=sink_pages,
            share_visits=share_visits, interpret=INTERPRET)
    return _paged_latent_decode_single(
        q_lat, q_rope, lat_pages, scale_pages, cache_len, phys_table,
        log_table, sm_scale=sm_scale, opt_kv=opt_kv, window=window,
        sink_pages=sink_pages, share_visits=share_visits,
        interpret=INTERPRET)


@partial(jax.jit, static_argnames=("sm_scale", "opt_kv", "window",
                                   "sink_pages", "interpret"))
def _latent_chunk_prefill_single(q_lat, q_rope, positions, lat_pages,
                                 scale_pages, phys_table, seg_q, page_seg,
                                 page_base, *, sm_scale: float,
                                 opt_kv: bool, window: int, sink_pages: int,
                                 interpret: bool):
    return _lc.latent_chunk_prefill(
        q_lat, q_rope, positions.astype(jnp.int32), lat_pages, scale_pages,
        phys_table.astype(jnp.int32), sm_scale=sm_scale, opt_kv=opt_kv,
        window=window, sink_pages=sink_pages, interpret=interpret,
        seg_q=seg_q, page_seg=page_seg, page_base=page_base)


def latent_chunk_prefill(q_lat, q_rope, positions, lat_pages, scale_pages,
                         phys_table, *, sm_scale: float, opt_kv: bool,
                         window: int = 0, sink_pages: int = 0, seg_q=None,
                         page_seg=None, page_base=None):
    """MLA absorbed continuation-prefill over the global latent pool: a
    chunk of absorbed queries q_lat (B,S,H,R) / q_rope (B,S,H,dr) with
    absolute ``positions`` (B,S) attends the lane's cached latent pages
    named by the scalar-prefetched ``phys_table`` (B,NP; -1 = never DMA'd).
    The chunk's own latents must already be written. Returns o_lat
    (B,S,H,R) f32. ``seg_q``/``page_seg``/``page_base`` enable concat-
    prefill packing (several prompts per row, see the kernel docstring);
    None = unpacked."""
    if _MESH_CTX is not None:
        return _sh.latent_chunk_prefill(
            _MESH_CTX, q_lat, q_rope, positions, lat_pages, scale_pages,
            phys_table, sm_scale=sm_scale, opt_kv=opt_kv, window=window,
            sink_pages=sink_pages, interpret=INTERPRET, seg_q=seg_q,
            page_seg=page_seg, page_base=page_base)
    return _latent_chunk_prefill_single(
        q_lat, q_rope, positions, lat_pages, scale_pages, phys_table,
        seg_q, page_seg, page_base, sm_scale=sm_scale, opt_kv=opt_kv,
        window=window, sink_pages=sink_pages, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("opt_kv", "opt_gqa", "window",
                                   "sink_pages", "interpret"))
def _paged_chunk_prefill_single(q, positions, kv_pages, scale_pages,
                                phys_table, seg_q, page_seg, page_base, *,
                                opt_kv: bool, opt_gqa: bool,
                                window: int, sink_pages: int,
                                interpret: bool):
    ks = scale_pages[0] if scale_pages is not None else None
    vs = scale_pages[1] if scale_pages is not None else None
    return _fc.flash_chunk_prefill(
        q, positions.astype(jnp.int32), kv_pages[0], kv_pages[1], ks, vs,
        phys_table.astype(jnp.int32), opt_kv=opt_kv, opt_gqa=opt_gqa,
        window=window, sink_pages=sink_pages, interpret=interpret,
        seg_q=seg_q, page_seg=page_seg, page_base=page_base)


def paged_chunk_prefill(q, positions, kv_pages, scale_pages, phys_table, *,
                        opt_kv: bool, opt_gqa: bool, window: int = 0,
                        sink_pages: int = 0, seg_q=None, page_seg=None,
                        page_base=None):
    """Continuation-prefill attention over the global pool: a chunk of
    queries (B,S,Hq,D) with absolute ``positions`` (B,S) attends the lane's
    cached pages named by the scalar-prefetched ``phys_table`` (B,NP; -1 =
    never DMA'd). The chunk's own K/V must already be written.
    ``seg_q``/``page_seg``/``page_base`` enable concat-prefill packing
    (several prompts per row, see the kernel docstring); None = unpacked."""
    if _MESH_CTX is not None:
        return _sh.paged_chunk_prefill(
            _MESH_CTX, q, positions, kv_pages, scale_pages, phys_table,
            opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
            sink_pages=sink_pages, interpret=INTERPRET, seg_q=seg_q,
            page_seg=page_seg, page_base=page_base)
    return _paged_chunk_prefill_single(
        q, positions, kv_pages, scale_pages, phys_table, seg_q, page_seg,
        page_base, opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
        sink_pages=sink_pages, interpret=INTERPRET)
