"""jit'd public wrappers around the Pallas kernels + cache-layout adapters.

The engine-facing cache layout is the GLOBAL paged pool — per-layer leaves
``(2, P_total, ps, Hkv, D)`` with NO batch dimension, shared by every lane;
these wrappers slice it into the kernels' (P_total, ps, Hkv, D) k/v views
(zero-copy) and plug into ``repro.core`` when ``CoOptConfig.use_kernel``.
Lanes address the pool through scalar-prefetched page tables (physical page
to DMA + logical page for positions) dereferenced inside BlockSpec
index_maps, and the write path scatters to global flat slots (the pool's
last cache line is the reserved SkipSet sentinel).

On this container the kernels run in interpret mode (CPU); on TPU hardware
``configure_for_backend()`` flips ``INTERPRET`` off — the launchers
(``launch.serve.serve_workload``, ``launch.steps.make_step`` engine setup,
``benchmarks.run``) call it at startup.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_chunk_prefill as _fc
from repro.kernels import flash_prefill as _fp
from repro.kernels import kv_cache_write as _kw
from repro.kernels import latent_chunk_prefill as _lc
from repro.kernels import paged_gqa_decode as _pd
from repro.kernels import paged_latent_decode as _ld

INTERPRET = True


def configure_for_backend() -> None:
    global INTERPRET
    INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("opt_kv", "opt_gqa", "window",
                                   "sink_pages"))
def paged_pool_decode(q, kv_pages, scale_pages, cache_len, phys_table,
                      log_table, *, opt_kv: bool, opt_gqa: bool,
                      window: int = 0, sink_pages: int = 0):
    """Fused decode over the global pool. q (B,Hq,D); kv_pages
    (2,P_total,ps,Hkv,D); scale_pages (2,P_total,ps,Hkv)|None; phys/log_table
    (B,NSel) int32 (-1 = never DMA'd)."""
    ks = scale_pages[0] if scale_pages is not None else None
    vs = scale_pages[1] if scale_pages is not None else None
    return _pd.paged_pool_decode(
        q, kv_pages[0], kv_pages[1], ks, vs, cache_len.astype(jnp.int32),
        phys_table.astype(jnp.int32), log_table.astype(jnp.int32),
        opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
        sink_pages=sink_pages, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("opt_kv",))
def kv_cache_write(kv_cache, scale_cache, k_new, v_new, slot_idx, *,
                   opt_kv: bool):
    """Engine-layout adapter for the write kernel. kv_cache
    (2,P_total,ps,Hkv,D) global pool (its LAST flat line is the SkipSet
    sentinel — the BlockManager never allocates the final page); returns
    updated (kv_cache, scale_cache)."""
    _, P, ps, Hkv, D = kv_cache.shape
    flat_k = kv_cache[0].reshape(P * ps, Hkv, D)
    flat_v = kv_cache[1].reshape(P * ps, Hkv, D)
    if scale_cache is not None:
        s_k = scale_cache[0].reshape(P * ps, Hkv)
        s_v = scale_cache[1].reshape(P * ps, Hkv)
    else:
        s_k = jnp.zeros((P * ps, Hkv), jnp.float32)
        s_v = s_k
    k_c, v_c, ks_c, vs_c = _kw.kv_cache_write(
        k_new, v_new, slot_idx.astype(jnp.int32), flat_k, flat_v, s_k, s_v,
        opt_kv=opt_kv, interpret=INTERPRET)
    kv = jnp.stack([k_c.reshape(P, ps, Hkv, D),
                    v_c.reshape(P, ps, Hkv, D)])
    if scale_cache is not None:
        scale_cache = jnp.stack([ks_c.reshape(P, ps, Hkv),
                                 vs_c.reshape(P, ps, Hkv)])
    return kv, scale_cache


@partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                   "q_offset"))
def flash_prefill(q, k, v, *, window: int = 0, block_q: int = 256,
                  block_k: int = 256, q_offset: int = 0):
    return _fp.flash_prefill(q, k, v, window=window, block_q=block_q,
                             block_k=block_k, q_offset=q_offset,
                             interpret=INTERPRET)


@partial(jax.jit, static_argnames=("sm_scale", "opt_kv", "window",
                                   "sink_pages"))
def paged_latent_decode(q_lat, q_rope, lat_pages, scale_pages, cache_len,
                        phys_table, log_table, *, sm_scale: float,
                        opt_kv: bool, window: int = 0, sink_pages: int = 0):
    """Fused MLA absorbed decode over the global latent pool. q_lat
    (B,H,R) W_uk-absorbed queries; q_rope (B,H,dr); lat_pages
    (P_total,ps,R+dr) [c_kv|k_rope] packed; scale_pages (P_total,ps,2) dual
    c/k_rope scales | None; phys/log_table (B,NSel) int32 (-1 = never
    DMA'd). Returns o_lat (B,H,R) f32 — w_uv expansion stays outside."""
    return _ld.paged_latent_decode(
        q_lat, q_rope, lat_pages, scale_pages, cache_len.astype(jnp.int32),
        phys_table.astype(jnp.int32), log_table.astype(jnp.int32),
        sm_scale=sm_scale, opt_kv=opt_kv, window=window,
        sink_pages=sink_pages, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("sm_scale", "opt_kv", "window",
                                   "sink_pages"))
def latent_chunk_prefill(q_lat, q_rope, positions, lat_pages, scale_pages,
                         phys_table, *, sm_scale: float, opt_kv: bool,
                         window: int = 0, sink_pages: int = 0):
    """MLA absorbed continuation-prefill over the global latent pool: a
    chunk of absorbed queries q_lat (B,S,H,R) / q_rope (B,S,H,dr) with
    absolute ``positions`` (B,S) attends the lane's cached latent pages
    named by the scalar-prefetched ``phys_table`` (B,NP; -1 = never DMA'd).
    The chunk's own latents must already be written. Returns o_lat
    (B,S,H,R) f32."""
    return _lc.latent_chunk_prefill(
        q_lat, q_rope, positions.astype(jnp.int32), lat_pages, scale_pages,
        phys_table.astype(jnp.int32), sm_scale=sm_scale, opt_kv=opt_kv,
        window=window, sink_pages=sink_pages, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("opt_kv", "opt_gqa", "window",
                                   "sink_pages"))
def paged_chunk_prefill(q, positions, kv_pages, scale_pages, phys_table, *,
                        opt_kv: bool, opt_gqa: bool, window: int = 0,
                        sink_pages: int = 0):
    """Continuation-prefill attention over the global pool: a chunk of
    queries (B,S,Hq,D) with absolute ``positions`` (B,S) attends the lane's
    cached pages named by the scalar-prefetched ``phys_table`` (B,NP; -1 =
    never DMA'd). The chunk's own K/V must already be written."""
    ks = scale_pages[0] if scale_pages is not None else None
    vs = scale_pages[1] if scale_pages is not None else None
    return _fc.flash_chunk_prefill(
        q, positions.astype(jnp.int32), kv_pages[0], kv_pages[1], ks, vs,
        phys_table.astype(jnp.int32), opt_kv=opt_kv, opt_gqa=opt_gqa,
        window=window, sink_pages=sink_pages, interpret=INTERPRET)
