"""jit'd public wrappers around the Pallas kernels + cache-layout adapters.

The engine-facing cache layout is the models' (2, B, P, ps, Hkv, D) paged
pool; these wrappers slice it into the kernels' (B, P, ps, Hkv, D) k/v views
(zero-copy) and plug into ``repro.core`` when ``CoOptConfig.use_kernel``.

On this container the kernels run in interpret mode (CPU); on TPU hardware
set ``repro.kernels.ops.INTERPRET = False`` (the launcher does this when
jax.default_backend() == 'tpu').
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_prefill as _fp
from repro.kernels import kv_cache_write as _kw
from repro.kernels import paged_gqa_decode as _pd

INTERPRET = True


def configure_for_backend() -> None:
    global INTERPRET
    INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("opt_kv", "opt_pa", "opt_gqa",
                                   "page_group"))
def paged_gqa_decode(q, kv_pages, scale_pages, cache_len, *, opt_kv: bool,
                     opt_pa: bool, opt_gqa: bool, page_group: int = 8):
    """Fused decode over the engine cache layout.
    q (B,Hq,D); kv_pages (2,B,P,ps,Hkv,D); scale_pages (2,B,P,ps,Hkv)|None."""
    ks = scale_pages[0] if scale_pages is not None else None
    vs = scale_pages[1] if scale_pages is not None else None
    return _pd.paged_gqa_decode(
        q, kv_pages[0], kv_pages[1], ks, vs, cache_len.astype(jnp.int32),
        opt_kv=opt_kv, opt_pa=opt_pa, opt_gqa=opt_gqa,
        page_group=page_group, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("opt_kv", "window", "sink_pages"))
def paged_gqa_decode_window(q, kv_pages, scale_pages, cache_len, page_table,
                            *, opt_kv: bool, window: int, sink_pages: int):
    ks = scale_pages[0] if scale_pages is not None else None
    vs = scale_pages[1] if scale_pages is not None else None
    return _pd.paged_gqa_decode_window(
        q, kv_pages[0], kv_pages[1], ks, vs, cache_len.astype(jnp.int32),
        page_table.astype(jnp.int32), opt_kv=opt_kv, window=window,
        sink_pages=sink_pages, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("opt_kv",))
def kv_cache_write(kv_cache, scale_cache, k_new, v_new, slot_idx, *,
                   opt_kv: bool):
    """Engine-layout adapter for the write kernel. kv_cache (2,B,P,ps,Hkv,D)
    (the pool's LAST line of the last page is reserved as the SkipSet
    sentinel by the engine); returns updated (kv_cache, scale_cache)."""
    _, B, P, ps, Hkv, D = kv_cache.shape
    flat_k = kv_cache[0].reshape(B, P * ps, Hkv, D)
    flat_v = kv_cache[1].reshape(B, P * ps, Hkv, D)
    if scale_cache is not None:
        s_k = scale_cache[0].reshape(B, P * ps, Hkv)
        s_v = scale_cache[1].reshape(B, P * ps, Hkv)
    else:
        s_k = jnp.zeros((B, P * ps, Hkv), jnp.float32)
        s_v = s_k
    k_c, v_c, ks_c, vs_c = _kw.kv_cache_write(
        k_new, v_new, slot_idx.astype(jnp.int32), flat_k, flat_v, s_k, s_v,
        opt_kv=opt_kv, interpret=INTERPRET)
    kv = jnp.stack([k_c.reshape(B, P, ps, Hkv, D),
                    v_c.reshape(B, P, ps, Hkv, D)])
    if scale_cache is not None:
        scale_cache = jnp.stack([ks_c.reshape(B, P, ps, Hkv),
                                 vs_c.reshape(B, P, ps, Hkv)])
    return kv, scale_cache


@partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                   "q_offset"))
def flash_prefill(q, k, v, *, window: int = 0, block_q: int = 256,
                  block_k: int = 256, q_offset: int = 0):
    return _fp.flash_prefill(q, k, v, window=window, block_q=block_q,
                             block_k=block_k, q_offset=q_offset,
                             interpret=INTERPRET)
