"""Fused LLM-CoOpt decode-attention Pallas kernel (the paper's hot path).

One kernel fuses all three techniques (DESIGN.md §2):
  Opt-KV  — KV pages stored FP8 e4m3 + per-(token, head) scale; dequantized
            on the fly at the HBM->VMEM boundary (Eq. 6 ``gather_cached_kv``).
  Opt-GQA — queries arrive folded (B, Hkv, G, D); each KV tile is streamed
            into VMEM ONCE and shared by the G query heads of its group
            (Eq. 7/8). The Original (MHA-semantics) mode re-streams KV per
            query head — the redundancy the paper measures.
  Opt-Pa  — Phase 1 valid-block filtering (Eq. 9): page groups wholly outside
            the live context are predicated off with ``pl.when`` (compute +
            VREG traffic skipped); Phase 2 block-wise softmax (Eq. 10): the
            DCU ``block_sum`` shared-memory reduction becomes a VMEM-resident
            running (max, sum, acc) carried across the page-group grid dim.

TPU adaptation notes (DESIGN.md §3): grid = (batch, kv_head, page_group);
page-group tiles are (pg * page_size, head_dim) — lane dim = head_dim
(128-aligned for every assigned arch), sublane = tokens. Scratch lives in
VMEM; (m, l) are kept lane-replicated (G, 128) as on-chip reduction tiles.

The windowed variant (block-sparse long-context policy, DESIGN.md §5) adds a
scalar-prefetched *page table*: the BlockSpec index_map dereferences it so
only {sink + sliding-window} pages are ever DMA'd — the paper's "lazy memory
mapping" realised as data-dependent prefetch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


# ---------------------------------------------------------------------------
# dense (full-context) paged decode
# ---------------------------------------------------------------------------
def _decode_kernel(len_ref,                      # scalar prefetch (B,)
                   q_ref, k_ref, v_ref, ks_ref, vs_ref,   # inputs
                   o_ref,                        # output
                   m_ref, l_ref, acc_ref,        # VMEM scratch
                   *, pg: int, ps: int, opt_kv: bool, opt_pa: bool,
                   num_groups: int):
    b = pl.program_id(0)
    g = pl.program_id(2)
    T = pg * ps
    G, D = q_ref.shape[2], q_ref.shape[3]
    length = len_ref[b]

    @pl.when(g == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Opt-Pa Phase 1 (Eq. 9): skip page groups beyond the live context.
    # Original mode computes every allocated page group ("all KVs loaded
    # regardless of whether they are actually useful", paper §2).
    active = (g * T < length) if opt_pa else (g >= 0)

    @pl.when(active)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        k = k_ref[0, :, :, 0, :].reshape(T, D)
        v = v_ref[0, :, :, 0, :].reshape(T, D)
        if opt_kv:  # Opt-KV Eq. 6: fused dequant at the VMEM boundary
            k = k.astype(jnp.float32) * ks_ref[0].reshape(T, 1)
            v = v.astype(jnp.float32) * vs_ref[0].reshape(T, 1)
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))                         # (G, T)
        pos = g * T + jax.lax.broadcasted_iota(jnp.int32, (G, T), 1)
        s = jnp.where(pos < length, s, _NEG)

        # Opt-Pa Phase 2 (Eq. 10): block-wise softmax, VMEM running reduce.
        m_prev = m_ref[:, 0:1]                               # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # (G, T)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(g == num_groups - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_gqa_decode(q, k_pages, v_pages, k_scale, v_scale, cache_len, *,
                     opt_kv: bool, opt_pa: bool, opt_gqa: bool,
                     page_group: int = 8, interpret: bool = True):
    """q: (B, Hq, D); k/v_pages: (B, P, ps, Hkv, D) [fp8 if opt_kv];
    k/v_scale: (B, P, ps, Hkv) f32 or None; cache_len: (B,) int32.
    Returns (B, Hq, D) in q.dtype."""
    B, Hq, D = q.shape
    _, P, ps, Hkv, _ = k_pages.shape
    pg = page_group
    while P % pg:
        pg //= 2
    pg = max(pg, 1)
    NG = P // pg

    if opt_gqa:
        G = Hq // Hkv
        heads, kv_of_head = Hkv, lambda h: h
    else:
        # Original MHA semantics: every query head re-streams its KV head.
        G = 1
        heads, kv_of_head = Hq, lambda h: h // max(Hq // Hkv, 1)
    qf = q.reshape(B, heads, G, D)

    if k_scale is None:
        k_scale = jnp.zeros((B, P, ps, Hkv), jnp.float32)
        v_scale = k_scale

    grid = (B, heads, NG)
    kern = functools.partial(_decode_kernel, pg=pg, ps=ps, opt_kv=opt_kv,
                             opt_pa=opt_pa, num_groups=NG)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, g, L: (b, h, 0, 0)),
                pl.BlockSpec((1, pg, ps, 1, D),
                             lambda b, h, g, L: (b, g, 0, kv_of_head(h), 0)),
                pl.BlockSpec((1, pg, ps, 1, D),
                             lambda b, h, g, L: (b, g, 0, kv_of_head(h), 0)),
                pl.BlockSpec((1, pg, ps, 1),
                             lambda b, h, g, L: (b, g, 0, kv_of_head(h))),
                pl.BlockSpec((1, pg, ps, 1),
                             lambda b, h, g, L: (b, g, 0, kv_of_head(h))),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, g, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, heads, G, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, qf, k_pages, v_pages, k_scale, v_scale)
    return out.reshape(B, Hq, D)


# ---------------------------------------------------------------------------
# windowed (block-sparse SkipSet) paged decode — long_500k policy
# ---------------------------------------------------------------------------
def _window_kernel(len_ref, tbl_ref,             # scalar prefetch
                   q_ref, k_ref, v_ref, ks_ref, vs_ref,
                   o_ref, m_ref, l_ref, acc_ref,
                   *, ps: int, opt_kv: bool, window: int, sink: int,
                   num_sel: int):
    b = pl.program_id(0)
    s_i = pl.program_id(2)
    G, D = q_ref.shape[2], q_ref.shape[3]
    length = len_ref[b]
    page = tbl_ref[b, s_i]

    @pl.when(s_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(page >= 0)  # SkipSet pages (Eq. 5) never compute
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0, :, 0, :]
        v = v_ref[0, 0, :, 0, :]
        if opt_kv:
            k = k.astype(jnp.float32) * ks_ref[0, 0].reshape(ps, 1)
            v = v.astype(jnp.float32) * vs_ref[0, 0].reshape(ps, 1)
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))
        pos = page * ps + jax.lax.broadcasted_iota(jnp.int32, (G, ps), 1)
        in_ctx = pos < length
        in_win = pos >= jnp.maximum(length - window, 0)
        in_sink = pos < sink * ps
        s = jnp.where(in_ctx & (in_win | in_sink), s, _NEG)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s_i == num_sel - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_gqa_decode_window(q, k_pages, v_pages, k_scale, v_scale, cache_len,
                            page_table, *, opt_kv: bool, window: int,
                            sink_pages: int, interpret: bool = True):
    """Block-sparse decode: only pages named in ``page_table`` (B, NSel;
    -1 = skipped) are DMA'd. Queries always grouped (Opt-GQA)."""
    B, Hq, D = q.shape
    _, P, ps, Hkv, _ = k_pages.shape
    NSel = page_table.shape[1]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, D)
    if k_scale is None:
        k_scale = jnp.zeros((B, P, ps, Hkv), jnp.float32)
        v_scale = k_scale

    def kv_idx(b, h, s, L, tbl):
        return (b, jnp.maximum(tbl[b, s], 0), 0, h, 0)

    def sc_idx(b, h, s, L, tbl):
        return (b, jnp.maximum(tbl[b, s], 0), 0, h)

    kern = functools.partial(_window_kernel, ps=ps, opt_kv=opt_kv,
                             window=window, sink=sink_pages, num_sel=NSel)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, NSel),
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, s, L, tbl: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, 1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, 1, ps, 1), sc_idx),
                pl.BlockSpec((1, 1, ps, 1), sc_idx),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, s, L, tbl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, page_table, qf, k_pages, v_pages, k_scale, v_scale)
    return out.reshape(B, Hq, D)
