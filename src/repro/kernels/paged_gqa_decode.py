"""Fused LLM-CoOpt decode-attention Pallas kernel (the paper's hot path),
over the GLOBAL paged-KV pool.

One kernel fuses all three techniques (DESIGN.md §2):
  Opt-KV  — KV pages stored FP8 e4m3 + per-(token, head) scale; dequantized
            on the fly at the HBM->VMEM boundary (Eq. 6 ``gather_cached_kv``).
  Opt-GQA — queries arrive folded (B, Hkv, G, D); each KV tile is streamed
            into VMEM ONCE and shared by the G query heads of its group
            (Eq. 7/8). The Original (MHA-semantics) mode re-streams KV per
            query head — the redundancy the paper measures.
  Opt-Pa  — Phase 1 valid-block filtering (Eq. 9): the caller masks page-
            table entries wholly outside the live context to -1, and the
            kernel predicates them off with ``pl.when`` (neither DMA'd nor
            computed); Phase 2 block-wise softmax (Eq. 10): the DCU
            ``block_sum`` shared-memory reduction becomes a VMEM-resident
            running (max, sum, acc) carried across the page grid dim.

Pool addressing: the cache has NO batch dimension — ``k/v_pages`` are
``(P_total, ps, Hkv, D)`` shared by every lane. Each lane's *physical* page
table is scalar-prefetched and dereferenced inside the BlockSpec index_map,
so the block DMA'd at grid step (b, h, i) IS lane b's i-th logical page —
the paper's "lazy memory mapping" realised as data-dependent prefetch. A
parallel *logical* table supplies token positions (logical page id) for the
causal / sliding-window masks; for dense decode it is simply ``arange``.

TPU adaptation notes (DESIGN.md §3): grid = (batch, kv_head, page); page
tiles are (page_size, head_dim) — lane dim = head_dim (128-aligned for every
assigned arch), sublane = tokens. Scratch lives in VMEM; (m, l) are kept
lane-replicated (G, 128) as on-chip reduction tiles.

The windowed variant (block-sparse long-context policy, DESIGN.md §5) is the
same kernel with ``window``/``sink_pages`` static parameters: the caller
passes a {sink + sliding-window} page selection, positions come from the
logical table, and out-of-policy tokens are masked in-register.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30

from repro.kernels._compat import CompilerParams as _CompilerParams


def _pool_kernel(len_ref, phys_ref, log_ref,     # scalar prefetch
                 q_ref, k_ref, v_ref, ks_ref, vs_ref,
                 o_ref, *refs,
                 ps: int, opt_kv: bool, window: int, sink: int,
                 num_sel: int, return_state: bool):
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    s_i = pl.program_id(2)
    G, D = q_ref.shape[2], q_ref.shape[3]
    length = len_ref[b]
    page = phys_ref[b, s_i]
    lpage = log_ref[b, s_i]

    @pl.when(s_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Eq. 9 Phase 1: SkipSet / unallocated / beyond-context pages (-1) are
    # predicated off — their DMA was redirected to page 0 by the index_map
    # but neither compute nor the running reduction ever sees them.
    @pl.when(page >= 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        k = k_ref[0, :, 0, :]                                # (ps, D)
        v = v_ref[0, :, 0, :]
        if opt_kv:  # Opt-KV Eq. 6: fused dequant at the VMEM boundary
            k = k.astype(jnp.float32) * ks_ref[0].reshape(ps, 1)
            v = v.astype(jnp.float32) * vs_ref[0].reshape(ps, 1)
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))                         # (G, ps)
        pos = lpage * ps + jax.lax.broadcasted_iota(jnp.int32, (G, ps), 1)
        mask = pos < length
        if window:
            in_win = pos >= jnp.maximum(length - window, 0)
            in_sink = pos < sink * ps
            mask &= in_win | in_sink
        s = jnp.where(mask, s, _NEG)

        # Eq. 10 Phase 2: block-wise softmax, VMEM running reduce.
        m_prev = m_ref[:, 0:1]                               # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # (G, ps)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s_i == num_sel - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if return_state:
            # per-shard partial softmax state for the shard_map lse merge:
            # lane-replicated (G, 128) tiles, column 0 is the value
            mo_ref[0, 0] = m_ref[...]
            lo_ref[0, 0] = l_ref[...]


def paged_pool_decode(q, k_pages, v_pages, k_scale, v_scale, cache_len,
                      phys_table, log_table, *, opt_kv: bool, opt_gqa: bool,
                      window: int = 0, sink_pages: int = 0,
                      return_state: bool = False, interpret: bool = True):
    """q: (B, Hq, D); k/v_pages: (P_total, ps, Hkv, D) GLOBAL pool [fp8 if
    opt_kv]; k/v_scale: (P_total, ps, Hkv) f32 or None; cache_len: (B,) int32;
    phys_table/log_table: (B, NSel) int32 — physical page to DMA / logical
    page id for positions; -1 = skip (never DMA'd). Returns (B, Hq, D);
    with ``return_state`` also the final online-softmax (m, l) as (B, Hq)
    f32 — a shard holding NONE of a lane's pages reports (m=-1e30, l=0), so
    its contribution vanishes in the cross-shard log-sum-exp merge
    (``kernels.sharded``)."""
    B, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    NSel = phys_table.shape[1]

    if opt_gqa:
        G = Hq // Hkv
        heads, kv_of_head = Hkv, lambda h: h
    else:
        # Original MHA semantics: every query head re-streams its KV head.
        G = 1
        heads, kv_of_head = Hq, lambda h: h // max(Hq // Hkv, 1)
    qf = q.reshape(B, heads, G, D)

    if k_scale is None:
        k_scale = jnp.zeros((P, ps, Hkv), jnp.float32)
        v_scale = k_scale

    def kv_idx(b, h, s, L, phys, log):
        return (jnp.maximum(phys[b, s], 0), 0, kv_of_head(h), 0)

    def sc_idx(b, h, s, L, phys, log):
        return (jnp.maximum(phys[b, s], 0), 0, kv_of_head(h))

    out_blk = pl.BlockSpec((1, 1, G, D),
                           lambda b, h, s, L, phys, log: (b, h, 0, 0))
    st_blk = pl.BlockSpec((1, 1, G, 128),
                          lambda b, h, s, L, phys, log: (b, h, 0, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((B, heads, G, D), q.dtype)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((B, heads, G, 128), jnp.float32)] * 2

    kern = functools.partial(_pool_kernel, ps=ps, opt_kv=opt_kv,
                             window=window, sink=sink_pages, num_sel=NSel,
                             return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, heads, NSel),
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, s, L, phys, log: (b, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, phys_table, log_table, qf, k_pages, v_pages,
      k_scale, v_scale)
    out = res[0].reshape(B, Hq, D)
    if not return_state:
        return out
    m = res[1][..., 0].reshape(B, Hq)
    l = res[2][..., 0].reshape(B, Hq)
    return out, m, l


def _visit_kernel(vp_ref, vm_ref, vl_ref,            # scalar prefetch
                  q_ref, len_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, *refs,
                  ps: int, G: int, opt_kv: bool, window: int, sink: int,
                  num_visits: int, return_state: bool):
    """Cross-lane visit grid: one step per deduplicated (page, lane-set).

    Query rows of ALL lanes ride VMEM-resident as one (BG, D) tile
    (BG = B * G, row r = lane * G + group-head); each visit DMAs /
    dequantizes its page ONCE and scatters scores into every member lane's
    running (m, l, acc) state. Non-member rows take an exact identity
    update (corr = exp(0) = 1, hard-zeroed p contributes +0.0), and a
    lane's member visits arrive in the same ascending-slot order the
    per-lane grid walks (``kernels.visits``), so per-row softmax state
    evolves update-for-update like ``_pool_kernel`` — the no-sharing plan
    is bit-identical, a shared plan saves (members - 1) page streams.
    """
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    v_i = pl.program_id(1)
    BG = q_ref.shape[1]
    page = vp_ref[v_i]
    lpage = vl_ref[v_i]
    lanes = vm_ref[v_i]

    @pl.when(v_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(page >= 0)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (BG, D)
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        if opt_kv:  # Opt-KV Eq. 6: fused dequant — ONCE per visit, not per lane
            k = k.astype(jnp.float32) * ks_ref[0].reshape(ps, 1)
            v = v.astype(jnp.float32) * vs_ref[0].reshape(ps, 1)
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q_ref.shape[2]))            # (BG, ps)
        # row r belongs to lane r // G; membership = lane's bit in the mask
        lane_r = jax.lax.broadcasted_iota(jnp.int32, (BG, 1), 0) // G
        member = jnp.equal(
            jnp.bitwise_and(jnp.right_shift(lanes, lane_r), 1), 1)
        length = len_ref[:, 0:1]                             # (BG, 1)
        pos = lpage * ps + jax.lax.broadcasted_iota(jnp.int32, (BG, ps), 1)
        mask = member & (pos < length)
        if window:
            in_win = pos >= jnp.maximum(length - window, 0)
            in_sink = pos < sink * ps
            mask &= in_win | in_sink
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[:, 0:1]                               # (BG, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        # member rows follow _pool_kernel verbatim (no hard zero on the
        # positional mask — exp underflow self-corrects identically);
        # non-member rows hard-zero so their (m, l, acc) are untouched
        p = jnp.where(member, jnp.exp(s - m_new), 0.0)       # (BG, ps)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(v_i == num_visits - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if return_state:
            mo_ref[0] = m_ref[...]
            lo_ref[0] = l_ref[...]


def paged_pool_decode_visits(q, k_pages, v_pages, k_scale, v_scale,
                             cache_len, visit_page, visit_lanes, visit_log,
                             *, opt_kv: bool, opt_gqa: bool, window: int = 0,
                             sink_pages: int = 0, return_state: bool = False,
                             interpret: bool = True):
    """Batched-visit twin of ``paged_pool_decode``: same pool/query/window
    semantics, but the page grid dim iterates a deduplicated cross-lane
    visit list (``kernels.visits.plan_visits``) instead of (lane x page) —
    each page shared by N lanes is streamed into VMEM once, not N times.
    visit_page/visit_lanes/visit_log: (NV,) int32 plan vectors. Requires
    B <= visits.MAX_VISIT_LANES (int32 lane bitmask); ``ops`` dispatches
    back to the per-lane grid beyond that."""
    B, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    NV = visit_page.shape[0]

    if opt_gqa:
        G = Hq // Hkv
        heads, kv_of_head = Hkv, lambda h: h
    else:
        G = 1
        heads, kv_of_head = Hq, lambda h: h // max(Hq // Hkv, 1)
    BG = B * G
    # rows r = b * G + g per head plane: lane-contiguous row blocks
    qf = q.reshape(B, heads, G, D).transpose(1, 0, 2, 3).reshape(heads, BG, D)
    len_rows = jnp.broadcast_to(
        cache_len.astype(jnp.int32)[:, None, None], (B, G, 128)
    ).reshape(BG, 128)

    if k_scale is None:
        k_scale = jnp.zeros((P, ps, Hkv), jnp.float32)
        v_scale = k_scale

    def kv_idx(h, v, vp, vl, vm):
        return (jnp.maximum(vp[v], 0), 0, kv_of_head(h), 0)

    def sc_idx(h, v, vp, vl, vm):
        return (jnp.maximum(vp[v], 0), 0, kv_of_head(h))

    out_blk = pl.BlockSpec((1, BG, D), lambda h, v, vp, vl, vm: (h, 0, 0))
    st_blk = pl.BlockSpec((1, BG, 128), lambda h, v, vp, vl, vm: (h, 0, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((heads, BG, D), q.dtype)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((heads, BG, 128), jnp.float32)] * 2

    kern = functools.partial(_visit_kernel, ps=ps, G=G, opt_kv=opt_kv,
                             window=window, sink=sink_pages, num_visits=NV,
                             return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(heads, NV),
            in_specs=[
                pl.BlockSpec((1, BG, D), lambda h, v, vp, vl, vm: (h, 0, 0)),
                pl.BlockSpec((BG, 128), lambda h, v, vp, vl, vm: (0, 0)),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((BG, 128), jnp.float32),
                pltpu.VMEM((BG, 128), jnp.float32),
                pltpu.VMEM((BG, D), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(visit_page, visit_lanes, visit_log, qf, len_rows,
      k_pages, v_pages, k_scale, v_scale)

    def unrows(x, last):
        return x.reshape(heads, B, G, last).transpose(1, 0, 2, 3) \
                .reshape(B, Hq, last)
    out = unrows(res[0], D)
    if not return_state:
        return out
    m = unrows(res[1], 128)[..., 0]
    l = unrows(res[2], 128)[..., 0]
    return out, m, l
