"""Fused LLM-CoOpt decode-attention Pallas kernel (the paper's hot path),
over the GLOBAL paged-KV pool.

One kernel fuses all three techniques (DESIGN.md §2):
  Opt-KV  — KV pages stored FP8 e4m3 + per-(token, head) scale; dequantized
            on the fly at the HBM->VMEM boundary (Eq. 6 ``gather_cached_kv``).
  Opt-GQA — queries arrive folded (B, Hkv, G, D); each KV tile is streamed
            into VMEM ONCE and shared by the G query heads of its group
            (Eq. 7/8). The Original (MHA-semantics) mode re-streams KV per
            query head — the redundancy the paper measures.
  Opt-Pa  — Phase 1 valid-block filtering (Eq. 9): the caller masks page-
            table entries wholly outside the live context to -1, and the
            kernel predicates them off with ``pl.when`` (neither DMA'd nor
            computed); Phase 2 block-wise softmax (Eq. 10): the DCU
            ``block_sum`` shared-memory reduction becomes a VMEM-resident
            running (max, sum, acc) carried across the page grid dim.

Pool addressing: the cache has NO batch dimension — ``k/v_pages`` are
``(P_total, ps, Hkv, D)`` shared by every lane. Each lane's *physical* page
table is scalar-prefetched and dereferenced inside the BlockSpec index_map,
so the block DMA'd at grid step (b, h, i) IS lane b's i-th logical page —
the paper's "lazy memory mapping" realised as data-dependent prefetch. A
parallel *logical* table supplies token positions (logical page id) for the
causal / sliding-window masks; for dense decode it is simply ``arange``.

TPU adaptation notes (DESIGN.md §3): grid = (batch, kv_head, page); page
tiles are (page_size, head_dim) — lane dim = head_dim (128-aligned for every
assigned arch), sublane = tokens. Scratch lives in VMEM; (m, l) are kept
lane-replicated (G, 128) as on-chip reduction tiles.

The windowed variant (block-sparse long-context policy, DESIGN.md §5) is the
same kernel with ``window``/``sink_pages`` static parameters: the caller
passes a {sink + sliding-window} page selection, positions come from the
logical table, and out-of-policy tokens are masked in-register.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30

from repro.kernels._compat import CompilerParams as _CompilerParams


def _pool_kernel(len_ref, phys_ref, log_ref,     # scalar prefetch
                 q_ref, k_ref, v_ref, ks_ref, vs_ref,
                 o_ref, *refs,
                 ps: int, opt_kv: bool, window: int, sink: int,
                 num_sel: int, return_state: bool):
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    s_i = pl.program_id(2)
    G, D = q_ref.shape[2], q_ref.shape[3]
    length = len_ref[b]
    page = phys_ref[b, s_i]
    lpage = log_ref[b, s_i]

    @pl.when(s_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Eq. 9 Phase 1: SkipSet / unallocated / beyond-context pages (-1) are
    # predicated off — their DMA was redirected to page 0 by the index_map
    # but neither compute nor the running reduction ever sees them.
    @pl.when(page >= 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        k = k_ref[0, :, 0, :]                                # (ps, D)
        v = v_ref[0, :, 0, :]
        if opt_kv:  # Opt-KV Eq. 6: fused dequant at the VMEM boundary
            k = k.astype(jnp.float32) * ks_ref[0].reshape(ps, 1)
            v = v.astype(jnp.float32) * vs_ref[0].reshape(ps, 1)
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))                         # (G, ps)
        pos = lpage * ps + jax.lax.broadcasted_iota(jnp.int32, (G, ps), 1)
        mask = pos < length
        if window:
            in_win = pos >= jnp.maximum(length - window, 0)
            in_sink = pos < sink * ps
            mask &= in_win | in_sink
        s = jnp.where(mask, s, _NEG)

        # Eq. 10 Phase 2: block-wise softmax, VMEM running reduce.
        m_prev = m_ref[:, 0:1]                               # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # (G, ps)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s_i == num_sel - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if return_state:
            # per-shard partial softmax state for the shard_map lse merge:
            # lane-replicated (G, 128) tiles, column 0 is the value
            mo_ref[0, 0] = m_ref[...]
            lo_ref[0, 0] = l_ref[...]


def paged_pool_decode(q, k_pages, v_pages, k_scale, v_scale, cache_len,
                      phys_table, log_table, *, opt_kv: bool, opt_gqa: bool,
                      window: int = 0, sink_pages: int = 0,
                      return_state: bool = False, interpret: bool = True):
    """q: (B, Hq, D); k/v_pages: (P_total, ps, Hkv, D) GLOBAL pool [fp8 if
    opt_kv]; k/v_scale: (P_total, ps, Hkv) f32 or None; cache_len: (B,) int32;
    phys_table/log_table: (B, NSel) int32 — physical page to DMA / logical
    page id for positions; -1 = skip (never DMA'd). Returns (B, Hq, D);
    with ``return_state`` also the final online-softmax (m, l) as (B, Hq)
    f32 — a shard holding NONE of a lane's pages reports (m=-1e30, l=0), so
    its contribution vanishes in the cross-shard log-sum-exp merge
    (``kernels.sharded``)."""
    B, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    NSel = phys_table.shape[1]

    if opt_gqa:
        G = Hq // Hkv
        heads, kv_of_head = Hkv, lambda h: h
    else:
        # Original MHA semantics: every query head re-streams its KV head.
        G = 1
        heads, kv_of_head = Hq, lambda h: h // max(Hq // Hkv, 1)
    qf = q.reshape(B, heads, G, D)

    if k_scale is None:
        k_scale = jnp.zeros((P, ps, Hkv), jnp.float32)
        v_scale = k_scale

    def kv_idx(b, h, s, L, phys, log):
        return (jnp.maximum(phys[b, s], 0), 0, kv_of_head(h), 0)

    def sc_idx(b, h, s, L, phys, log):
        return (jnp.maximum(phys[b, s], 0), 0, kv_of_head(h))

    out_blk = pl.BlockSpec((1, 1, G, D),
                           lambda b, h, s, L, phys, log: (b, h, 0, 0))
    st_blk = pl.BlockSpec((1, 1, G, 128),
                          lambda b, h, s, L, phys, log: (b, h, 0, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((B, heads, G, D), q.dtype)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((B, heads, G, 128), jnp.float32)] * 2

    kern = functools.partial(_pool_kernel, ps=ps, opt_kv=opt_kv,
                             window=window, sink=sink_pages, num_sel=NSel,
                             return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, heads, NSel),
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, s, L, phys, log: (b, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, 128), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len, phys_table, log_table, qf, k_pages, v_pages,
      k_scale, v_scale)
    out = res[0].reshape(B, Hq, D)
    if not return_state:
        return out
    m = res[1][..., 0].reshape(B, Hq)
    l = res[2][..., 0].reshape(B, Hq)
    return out, m, l
