"""shard_map layer over the pooled Pallas kernels — ONE kernel hot path for
single-host AND distributed (GSPMD mesh) serving.

The device cache's ``pages`` axis is sharded over the mesh ``PAGES_AXES``
(``(pod, data)`` — the same partition PR 2 mirrored host-side as
``opt_kv.shard_page_ranges``). This module wraps each pooled kernel in a
``shard_map`` over those axes so every mesh shard runs the UNCHANGED
single-host kernel against only its owned contiguous page range:

  * the per-lane GLOBAL physical page table is translated to the shard's
    LOCAL page domain (``opt_kv.global_to_local_pages``) — entries outside
    the shard's range become -1 and are never DMA'd, exactly the kernels'
    existing hole semantics, so no page crosses the interconnect;
  * each shard's kernel emits its final online-softmax state
    (``return_state=True`` -> normalized partial output + (m, l)), and the
    partials are combined with the standard log-sum-exp merge across the
    pages axes:  m* = pmax(m);  w_s = exp(m_s - m*) * l_s;
    out = psum(w_s * o_s) / psum(w_s).  A shard holding none of a lane's
    pages reports (m = -1e30, l = 0) and so contributes nothing;
  * the write path stays shard-local too: global flat slots are translated
    to the shard's slot range (others dropped via ``mode='drop'``), so the
    pool is scattered into in place with NO cross-shard traffic and no
    sentinel-line aliasing (a -1 simply never lands).

The engine-facing contract is unchanged: callers pass GLOBAL pools, GLOBAL
tables/slots, and get replicated outputs — ``kernels.ops`` dispatches here
whenever a ``ShardCtx`` is installed (``ops.set_mesh_ctx``), and an
unsharded mesh (pages-axes extent 1) yields ``make_ctx(...) is None`` so a
1-device mesh takes the *identical* code path as no mesh at all.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.cache.quant import quantize_fp8, quantize_latent
# PAGES_AXES — the mesh axes the pages axis is sharded over — lives with
# the shard-ownership math in core.opt_kv (re-exported here for kernel-side
# callers); host tooling reads it without importing the Pallas stack.
from repro.core.opt_kv import (PAGES_AXES,                  # noqa: F401
                               global_to_local_pages, global_to_local_slots)
from repro.kernels import flash_chunk_prefill as _fc
from repro.kernels import latent_chunk_prefill as _lc
from repro.kernels import paged_gqa_decode as _pd
from repro.kernels import paged_latent_decode as _ld
from repro.kernels import visits as _vs


@dataclass(frozen=True)
class ShardCtx:
    """Static description of the pages-axis partition of one mesh — the
    ``jax.jit``-static handle the ops wrappers key their dispatch on."""
    mesh: jax.sharding.Mesh
    axes: Tuple[str, ...]          # PAGES_AXES members present in the mesh
    num_shards: int                # product of their extents


def make_ctx(mesh) -> Optional[ShardCtx]:
    """ShardCtx for ``mesh``, or None when the pages axes have extent 1 —
    an unsharded (or absent) mesh takes the identical single-host path."""
    if mesh is None:
        return None
    axes = tuple(a for a in PAGES_AXES if a in mesh.shape)
    n = int(math.prod(mesh.shape[a] for a in axes)) if axes else 1
    if n <= 1:
        return None
    return ShardCtx(mesh=mesh, axes=axes, num_shards=n)


def _shard_index(ctx: ShardCtx):
    """Linear shard id along the pages axes, major-to-minor in mesh-axis
    order — matches both the device layout of ``PartitionSpec(ctx.axes)``
    and the host ``shard_page_ranges`` ordering."""
    idx = jnp.int32(0)
    for a in ctx.axes:
        idx = idx * ctx.mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _lse_merge(ctx: ShardCtx, o, m, l, out_dtype):
    """Combine per-shard normalized partials across the pages axes.
    o (..., D) f32-able; m/l (...,) f32. Standard log-sum-exp merge."""
    m_all = jax.lax.pmax(m, ctx.axes)
    w = jnp.exp(m - m_all) * l                     # 0 for page-less shards
    den = jax.lax.psum(w, ctx.axes)
    num = jax.lax.psum(o.astype(jnp.float32) * w[..., None], ctx.axes)
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(out_dtype)


def _pages_spec(ndim: int, pages_dim: int, ctx: ShardCtx) -> P:
    entries = [None] * ndim
    entries[pages_dim] = ctx.axes if len(ctx.axes) > 1 else ctx.axes[0]
    return P(*entries)


# ------------------------------------------------------------- read path --
@partial(jax.jit, static_argnames=("ctx", "opt_kv", "opt_gqa", "window",
                                   "sink_pages", "share_visits", "interpret"))
def paged_pool_decode(ctx: ShardCtx, q, kv_pages, scale_pages, cache_len,
                      phys_table, log_table, *, opt_kv: bool, opt_gqa: bool,
                      window: int = 0, sink_pages: int = 0,
                      share_visits: bool = False, interpret: bool = True):
    """Distributed ``paged_gqa_decode``: kv_pages (2, P_total, ps, Hkv, D)
    pages-sharded over ``ctx.axes``; q/tables/cache_len replicated; returns
    the replicated (B, Hq, D) attention output. With ``share_visits`` each
    shard plans its visit list AFTER the global->local page translation, so
    visits are deduplicated within (and never cross) the shard's own page
    range."""
    P_total = kv_pages.shape[1]
    P_local = P_total // ctx.num_shards
    _, _, ps, Hkv, _ = kv_pages.shape
    if scale_pages is None:
        scale_pages = jnp.zeros((2, P_total, ps, Hkv), jnp.float32)
    use_visits = share_visits and 1 < q.shape[0] <= _vs.MAX_VISIT_LANES

    def body(q, kv, sc, cl, phys, log):
        first = _shard_index(ctx) * P_local
        lphys = global_to_local_pages(phys, first, P_local)
        if use_visits:
            vp, vm, vl = _vs.plan_visits(lphys, log)
            o, m, l = _pd.paged_pool_decode_visits(
                q, kv[0], kv[1], sc[0], sc[1], cl, vp, vm, vl,
                opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
                sink_pages=sink_pages, return_state=True,
                interpret=interpret)
        else:
            o, m, l = _pd.paged_pool_decode(
                q, kv[0], kv[1], sc[0], sc[1], cl, lphys, log,
                opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
                sink_pages=sink_pages, return_state=True,
                interpret=interpret)
        return _lse_merge(ctx, o, m, l, q.dtype)

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), _pages_spec(5, 1, ctx), _pages_spec(4, 1, ctx),
                  P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q, kv_pages, scale_pages, cache_len.astype(jnp.int32),
      phys_table.astype(jnp.int32), log_table.astype(jnp.int32))


@partial(jax.jit, static_argnames=("ctx", "opt_kv", "opt_gqa", "window",
                                   "sink_pages", "interpret"))
def paged_chunk_prefill(ctx: ShardCtx, q, positions, kv_pages, scale_pages,
                        phys_table, *, opt_kv: bool, opt_gqa: bool,
                        window: int = 0, sink_pages: int = 0,
                        interpret: bool = True, seg_q=None, page_seg=None,
                        page_base=None):
    """Distributed ``flash_chunk_prefill``: chunk queries (B, S, Hq, D)
    replicated, pool pages-sharded; per-shard partials lse-merged. The
    packing tables (seg/base) live in the LOGICAL page domain, so they ride
    along replicated and untranslated — only the physical table is mapped
    into each shard's local range."""
    B, S = positions.shape
    P_total = kv_pages.shape[1]
    NP = phys_table.shape[1]
    P_local = P_total // ctx.num_shards
    _, _, ps, Hkv, _ = kv_pages.shape
    if scale_pages is None:
        scale_pages = jnp.zeros((2, P_total, ps, Hkv), jnp.float32)
    if seg_q is None:
        seg_q = jnp.zeros((B, S), jnp.int32)
    if page_seg is None:
        page_seg = jnp.zeros((B, NP), jnp.int32)
    if page_base is None:
        page_base = jnp.broadcast_to(jnp.arange(NP, dtype=jnp.int32), (B, NP))

    def body(q, pos, kv, sc, phys, sq, pseg, pbase):
        first = _shard_index(ctx) * P_local
        lphys = global_to_local_pages(phys, first, P_local)
        o, m, l = _fc.flash_chunk_prefill(
            q, pos, kv[0], kv[1], sc[0], sc[1], lphys,
            opt_kv=opt_kv, opt_gqa=opt_gqa, window=window,
            sink_pages=sink_pages, return_state=True, interpret=interpret,
            seg_q=sq, page_seg=pseg, page_base=pbase)
        return _lse_merge(ctx, o, m, l, q.dtype)

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), P(), _pages_spec(5, 1, ctx), _pages_spec(4, 1, ctx),
                  P(), P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q, positions.astype(jnp.int32), kv_pages, scale_pages,
      phys_table.astype(jnp.int32), seg_q.astype(jnp.int32),
      page_seg.astype(jnp.int32), page_base.astype(jnp.int32))


@partial(jax.jit, static_argnames=("ctx", "sm_scale", "opt_kv", "window",
                                   "sink_pages", "share_visits", "interpret"))
def paged_latent_decode(ctx: ShardCtx, q_lat, q_rope, lat_pages, scale_pages,
                        cache_len, phys_table, log_table, *, sm_scale: float,
                        opt_kv: bool, window: int = 0, sink_pages: int = 0,
                        share_visits: bool = False, interpret: bool = True):
    """Distributed ``paged_latent_decode``: latent pool (P_total, ps, R+dr)
    pages-sharded; absorbed queries replicated; returns o_lat (B, H, R) f32.
    With ``share_visits`` each shard plans its visit list AFTER the
    global->local translation (shard-local visit lists, see
    ``paged_pool_decode``)."""
    P_total, ps, _ = lat_pages.shape
    P_local = P_total // ctx.num_shards
    if scale_pages is None:
        scale_pages = jnp.zeros((P_total, ps, 2), jnp.float32)
    use_visits = share_visits and 1 < q_lat.shape[0] <= _vs.MAX_VISIT_LANES

    def body(ql, qr, lat, sc, cl, phys, log):
        first = _shard_index(ctx) * P_local
        lphys = global_to_local_pages(phys, first, P_local)
        if use_visits:
            vp, vm, vl = _vs.plan_visits(lphys, log)
            o, m, l = _ld.paged_latent_decode_visits(
                ql, qr, lat, sc, cl, vp, vm, vl, sm_scale=sm_scale,
                opt_kv=opt_kv, window=window, sink_pages=sink_pages,
                return_state=True, interpret=interpret)
        else:
            o, m, l = _ld.paged_latent_decode(
                ql, qr, lat, sc, cl, lphys, log, sm_scale=sm_scale,
                opt_kv=opt_kv, window=window, sink_pages=sink_pages,
                return_state=True, interpret=interpret)
        return _lse_merge(ctx, o, m, l, jnp.float32)

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), P(), _pages_spec(3, 0, ctx), _pages_spec(3, 0, ctx),
                  P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q_lat, q_rope, lat_pages, scale_pages, cache_len.astype(jnp.int32),
      phys_table.astype(jnp.int32), log_table.astype(jnp.int32))


@partial(jax.jit, static_argnames=("ctx", "sm_scale", "opt_kv", "window",
                                   "sink_pages", "interpret"))
def latent_chunk_prefill(ctx: ShardCtx, q_lat, q_rope, positions, lat_pages,
                         scale_pages, phys_table, *, sm_scale: float,
                         opt_kv: bool, window: int = 0, sink_pages: int = 0,
                         interpret: bool = True, seg_q=None, page_seg=None,
                         page_base=None):
    """Distributed ``latent_chunk_prefill``: chunk of absorbed queries
    (B, S, H, R) replicated, latent pool pages-sharded; returns o_lat
    (B, S, H, R) f32. Packing tables (seg/base) are logical-domain and ride
    along replicated — only the physical table is shard-translated."""
    B, S = positions.shape
    NP = phys_table.shape[1]
    P_total, ps, _ = lat_pages.shape
    P_local = P_total // ctx.num_shards
    if scale_pages is None:
        scale_pages = jnp.zeros((P_total, ps, 2), jnp.float32)
    if seg_q is None:
        seg_q = jnp.zeros((B, S), jnp.int32)
    if page_seg is None:
        page_seg = jnp.zeros((B, NP), jnp.int32)
    if page_base is None:
        page_base = jnp.broadcast_to(jnp.arange(NP, dtype=jnp.int32), (B, NP))

    def body(ql, qr, pos, lat, sc, phys, sq, pseg, pbase):
        first = _shard_index(ctx) * P_local
        lphys = global_to_local_pages(phys, first, P_local)
        o, m, l = _lc.latent_chunk_prefill(
            ql, qr, pos, lat, sc, lphys, sm_scale=sm_scale, opt_kv=opt_kv,
            window=window, sink_pages=sink_pages, return_state=True,
            interpret=interpret, seg_q=sq, page_seg=pseg, page_base=pbase)
        return _lse_merge(ctx, o, m, l, jnp.float32)

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(), P(), P(), _pages_spec(3, 0, ctx),
                  _pages_spec(3, 0, ctx), P(), P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q_lat, q_rope, positions.astype(jnp.int32), lat_pages, scale_pages,
      phys_table.astype(jnp.int32), seg_q.astype(jnp.int32),
      page_seg.astype(jnp.int32), page_base.astype(jnp.int32))


# ------------------------------------------------------------ write path --
@partial(jax.jit, static_argnames=("ctx", "opt_kv"))
def kv_pool_write(ctx: ShardCtx, kv_cache, scale_cache, k_new, v_new,
                  slot_idx, *, opt_kv: bool):
    """Shard-local write into the pages-sharded KV pool: quantization runs
    replicated on the (small) new tokens, then each shard scatters only the
    slots inside its own page range (others mapped one PAST the shard's
    range by ``global_to_local_slots`` and OOB-dropped — never -1, which
    would wrap onto the shard's live last line). No cross-shard traffic, no
    sentinel line needed — live lines match ``opt_kv.write_kv``'s jnp
    scatter exactly. Returns updated (kv_cache, scale_cache)."""
    _, Pt, ps, H, D = kv_cache.shape
    P_local = Pt // ctx.num_shards
    new = jnp.stack([k_new, v_new])                      # (2,B,S,H,D)
    if opt_kv:
        vals, scl = quantize_fp8(new, axis=-1)
    else:
        vals, scl = new, jnp.zeros(new.shape[:-1], jnp.float32)
    has_scale = scale_cache is not None
    if not has_scale:
        scale_cache = jnp.zeros((2, Pt, ps, H), jnp.float32)

    def body(kv, sc, vals, scl, slots):
        first = _shard_index(ctx) * (P_local * ps)
        ls = global_to_local_slots(slots, first, P_local * ps)
        flat = kv.reshape(2, P_local * ps, H, D)
        flat = flat.at[:, ls].set(vals.astype(flat.dtype), mode="drop")
        sflat = sc.reshape(2, P_local * ps, H)
        sflat = sflat.at[:, ls].set(scl, mode="drop")
        return (flat.reshape(2, P_local, ps, H, D),
                sflat.reshape(2, P_local, ps, H))

    kv, sc = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(_pages_spec(5, 1, ctx), _pages_spec(4, 1, ctx),
                  P(), P(), P()),
        out_specs=(_pages_spec(5, 1, ctx), _pages_spec(4, 1, ctx)),
        check_rep=False,
    )(kv_cache, scale_cache, vals, scl, slot_idx.astype(jnp.int32))
    return kv, (sc if has_scale else None)


@partial(jax.jit, static_argnames=("ctx", "opt_kv", "lora_rank"))
def latent_pool_write(ctx: ShardCtx, lat_cache, scale_cache, latent,
                      slot_idx, *, opt_kv: bool, lora_rank: int):
    """Shard-local write into the pages-sharded MLA latent pool (dual-scale
    quantization replicated, scatter shard-local). lat_cache (P, ps, R+dr);
    latent (B, S, R+dr). Returns updated (lat_cache, scale_cache)."""
    Pt, ps, W = lat_cache.shape
    P_local = Pt // ctx.num_shards
    if opt_kv:
        vals, scl = quantize_latent(latent, lora_rank)
    else:
        vals, scl = latent, jnp.zeros(latent.shape[:-1] + (2,), jnp.float32)
    has_scale = scale_cache is not None
    if not has_scale:
        scale_cache = jnp.zeros((Pt, ps, 2), jnp.float32)

    def body(lat, sc, vals, scl, slots):
        first = _shard_index(ctx) * (P_local * ps)
        ls = global_to_local_slots(slots, first, P_local * ps)
        flat = lat.reshape(P_local * ps, W)
        flat = flat.at[ls].set(vals.astype(flat.dtype), mode="drop")
        sflat = sc.reshape(P_local * ps, 2)
        sflat = sflat.at[ls].set(scl, mode="drop")
        return flat.reshape(P_local, ps, W), sflat.reshape(P_local, ps, 2)

    lat, sc = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(_pages_spec(3, 0, ctx), _pages_spec(3, 0, ctx), P(), P(),
                  P()),
        out_specs=(_pages_spec(3, 0, ctx), _pages_spec(3, 0, ctx)),
        check_rep=False,
    )(lat_cache, scale_cache, vals, scl, slot_idx.astype(jnp.int32))
    return lat, (sc if has_scale else None)
