"""Paged continuation-prefill Pallas kernel — chunked prefill (Sarathi-style
mixed step) attending over the GLOBAL paged-KV pool.

This is the missing piece between ``flash_prefill`` (contiguous in-flight K/V,
whole-prompt causal tiles) and ``paged_gqa_decode`` (one query token against
the pool): a CHUNK of queries per lane, each with an absolute position, whose
keys are the lane's *already-cached* pages — earlier chunks, prefix-cache
hits, and the chunk itself (written before attention). The lane's physical
page table is scalar-prefetched and dereferenced inside the BlockSpec
index_map, so a chunk's queries attend over prior cached pages without the
host gathering the whole history into a contiguous buffer (Opt-Pa "lazy
memory mapping", paper §3.3, applied to the prefill continuation).

Grid: (batch, kv_head, q_group, logical_page). Queries arrive grouped
(Opt-GQA): rows are (seq, group) pairs, so each KV page is streamed into VMEM
once per G query heads. Per-row absolute positions ride along as a VMEM
input blocked with the query tiles; the causal / sliding-window / sink masks
compare them against ``logical_page * ps + iota`` — Eq. 9's valid-block
filter in the logical page domain, Eq. 10's online softmax across pages.

Tile-resident chunk streaming: the page dim is innermost and every row-side
block (q, positions, out, state, scratch) is keyed on the RESIDENT GROUP
index only, so the whole group stays VMEM-resident across the inner page
loop and a page is DMA'd once per group — not once per small query tile.
The group is sized by ``resident_rows`` (largest divisor of R under
``RESIDENT_ROWS`` rows that keeps (seq, group) rows together), so a typical
chunk (R <= 1024 rows) streams each cached page exactly ONCE per (b, h);
the page re-stream factor is ceil(R / rq) instead of the former fixed
R / 256. VMEM stays under the 8 MiB budget: rows cost (2*D + 3*128) * 4 B
each double-buffered (~5.9 MiB at rq = 1024, D = 128).

Page skipping: table entries of -1 (unallocated, or masked beyond the lane's
``cache_len`` by the caller) are predicated off with ``pl.when`` — neither
DMA'd (index_map redirects to page 0) nor computed. Pages entirely in the
future of the query tile are skipped by the same predicate using the tile's
maximum position.

Concat-prefill packing: a row may hold SEVERAL prompts' chunks (segments).
The scalar-prefetch table then carries three planes per (row, slot) —
physical page, in-segment logical page index, and segment id — and each
query row carries its segment id alongside its position. Key positions are
computed from the in-segment page index (``base * ps + iota``) and the mask
additionally requires segment equality, so attention can NEVER leak across
packed prompts: a cross-segment page contributes exactly zero (its
probabilities are hard-zeroed, not just exp(-inf), so the online-softmax
state is bit-identical to the unpacked run). Defaults (no packing) reduce
to the exact previous math: base == slot index, one segment per row.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

_NEG = -1e30

# VMEM-resident query-group row budget: sized so the group's q/out/state
# blocks plus (m, l, acc) scratch stay well inside the 8 MiB VMEM budget at
# D = 128 while letting a typical chunk's rows (R = S * G) fit in ONE group
# — the page streamed per group is then streamed per CHUNK.
RESIDENT_ROWS = 1024


def resident_rows(R: int, G: int, cap: int = 0) -> int:
    """Rows per VMEM-resident query group: the largest divisor of ``R``
    that is <= cap (default ``RESIDENT_ROWS``) and keeps a sequence row's G
    grouped heads together. ``G`` always qualifies, so the search
    terminates. The page re-stream factor of the chunk kernel is
    ``R // resident_rows(R, G)``."""
    rq = min(cap or RESIDENT_ROWS, R)
    while R % rq or rq % G:
        rq -= 1
    return rq


def _chunk_kernel(phys_ref,                          # scalar prefetch
                  q_ref, pos_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, *refs,
                  ps: int, opt_kv: bool, window: int, sink: int,
                  num_pages: int, return_state: bool):
    if return_state:
        mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(3)                             # page-table slot
    rq, D = q_ref.shape[2], q_ref.shape[3]
    page = phys_ref[0, b, j]                         # physical page to DMA
    base = phys_ref[1, b, j]                         # in-segment logical page
    pseg = phys_ref[2, b, j]                         # page's segment id

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = pos_ref[0, 0].astype(jnp.int32)           # (rq,) per-row position
    qseg = pos_ref[0, 1].astype(jnp.int32)           # (rq,) per-row segment
    # causal page skip: the page is dead if its first key position is beyond
    # every query in the tile (positions are non-decreasing per lane only
    # within a chunk, so use the tile max)
    live = jnp.logical_and(page >= 0, base * ps <= jnp.max(qpos))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (rq, D)
        k = k_ref[0, :, 0, :]                        # (ps, D)
        v = v_ref[0, :, 0, :]
        if opt_kv:                                   # Eq. 6 fused dequant
            k = k.astype(jnp.float32) * ks_ref[0].reshape(ps, 1)
            v = v.astype(jnp.float32) * vs_ref[0].reshape(ps, 1)
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))                 # (rq, ps)
        kpos = base * ps + jax.lax.broadcasted_iota(jnp.int32, (rq, ps), 1)
        qp = jnp.broadcast_to(qpos[:, None], (rq, ps))
        mask = (kpos <= qp) & (qseg[:, None] == pseg)
        if window:
            mask &= (kpos > qp - window) | (kpos < sink * ps)
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        # hard-zero masked probabilities: a row whose keys are ALL masked on
        # this page (cross-segment page, pad row) must contribute nothing —
        # exp(s - m_new) alone would yield 1.0 while m_new is still _NEG
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_new = l_ref[:, 0:1] * corr + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == num_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        if return_state:
            # per-shard partial softmax state for the shard_map lse merge
            mo_ref[0, 0] = m_ref[...]
            lo_ref[0, 0] = l_ref[...]


def flash_chunk_prefill(q, positions, k_pages, v_pages, k_scale, v_scale,
                        phys_table, *, opt_kv: bool, opt_gqa: bool = True,
                        window: int = 0, sink_pages: int = 0,
                        block_q: int = 0, return_state: bool = False,
                        interpret: bool = True, seg_q=None, page_seg=None,
                        page_base=None):
    """q: (B, S, Hq, D) chunk queries; positions: (B, S) absolute per-row
    positions; k/v_pages: (P_total, ps, Hkv, D) GLOBAL pool [fp8 if opt_kv];
    k/v_scale: (P_total, ps, Hkv) f32 or None; phys_table: (B, NP) int32
    physical pages in logical order (-1 = skip, never DMA'd). The chunk's
    own K/V must already be written to the pool. Returns (B, S, Hq, D); with
    ``return_state`` also the final online-softmax (m, l) as (B, S, Hq) f32
    for the cross-shard log-sum-exp merge (``kernels.sharded``).

    Concat-prefill packing (all three or none): ``seg_q`` (B, S) int32 is
    each query row's segment id (-1 = pad row, matches nothing);
    ``page_seg`` (B, NP) the segment each table slot belongs to; and
    ``page_base`` (B, NP) the slot's logical page index WITHIN its segment
    (key positions are ``page_base * ps + iota``). Defaults reproduce the
    unpacked layout exactly: one segment 0 per row, base == slot index."""
    B, S, Hq, D = q.shape
    P, ps, Hkv, _ = k_pages.shape
    NP = phys_table.shape[1]
    if seg_q is None:
        seg_q = jnp.zeros((B, S), jnp.int32)
    if page_seg is None:
        page_seg = jnp.zeros((B, NP), jnp.int32)
    if page_base is None:
        page_base = jnp.broadcast_to(jnp.arange(NP, dtype=jnp.int32),
                                     (B, NP))
    if opt_gqa:
        G = Hq // Hkv
        heads, kv_of_head = Hkv, lambda h: h
    else:
        # Original MHA semantics: every query head re-streams its KV head.
        G = 1
        heads, kv_of_head = Hq, lambda h: h // max(Hq // Hkv, 1)
    R = S * G

    # resident-group sizing: rows stay VMEM-resident across the whole inner
    # page loop, so NQ is the page re-stream factor (1 for typical chunks).
    # block_q = 0 means "as large as the VMEM budget allows" (RESIDENT_ROWS).
    rq = resident_rows(R, G, block_q)
    NQ = R // rq

    # (B,S,Hq,D) -> (B,heads,R,D): row r = s*G + g; positions repeat per
    # group (grouped mode) or per head block (MHA mode: R == S).
    qf = q.reshape(B, S, heads, G, D).transpose(0, 2, 1, 3, 4) \
          .reshape(B, heads, R, D)
    pos_rep = jnp.repeat(positions.astype(jnp.int32), G, axis=1)  # (B, R)
    seg_rep = jnp.repeat(seg_q.astype(jnp.int32), G, axis=1)      # (B, R)
    pos_rep = jnp.stack([pos_rep, seg_rep], axis=1)               # (B, 2, R)
    # scalar-prefetch planes: [physical page, in-segment base, segment id]
    table3 = jnp.stack([phys_table.astype(jnp.int32),
                        page_base.astype(jnp.int32),
                        page_seg.astype(jnp.int32)])              # (3, B, NP)

    if k_scale is None:
        k_scale = jnp.zeros((P, ps, Hkv), jnp.float32)
        v_scale = k_scale

    def kv_idx(b, h, i, j, phys):
        return (jnp.maximum(phys[0, b, j], 0), 0, kv_of_head(h), 0)

    def sc_idx(b, h, i, j, phys):
        return (jnp.maximum(phys[0, b, j], 0), 0, kv_of_head(h))

    out_blk = pl.BlockSpec((1, 1, rq, D),
                           lambda b, h, i, j, phys: (b, h, i, 0))
    st_blk = pl.BlockSpec((1, 1, rq, 128),
                          lambda b, h, i, j, phys: (b, h, i, 0))
    out_specs = [out_blk]
    out_shape = [jax.ShapeDtypeStruct((B, heads, R, D), q.dtype)]
    if return_state:
        out_specs += [st_blk, st_blk]
        out_shape += [jax.ShapeDtypeStruct((B, heads, R, 128),
                                           jnp.float32)] * 2

    kern = functools.partial(_chunk_kernel, ps=ps, opt_kv=opt_kv,
                             window=window, sink=sink_pages, num_pages=NP,
                             return_state=return_state)
    res = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, heads, NQ, NP),
            in_specs=[
                pl.BlockSpec((1, 1, rq, D),
                             lambda b, h, i, j, phys: (b, h, i, 0)),
                pl.BlockSpec((1, 2, rq),
                             lambda b, h, i, j, phys: (b, 0, i)),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1, D), kv_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
                pl.BlockSpec((1, ps, 1), sc_idx),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((rq, 128), jnp.float32),
                pltpu.VMEM((rq, 128), jnp.float32),
                pltpu.VMEM((rq, D), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(table3, qf, pos_rep, k_pages, v_pages, k_scale, v_scale)
    out = res[0].reshape(B, heads, S, G, D).transpose(0, 2, 1, 3, 4) \
                .reshape(B, S, Hq, D)
    if not return_state:
        return out

    def _rows(x):           # (B, heads, R, 128) -> (B, S, Hq)
        return x[..., 0].reshape(B, heads, S, G).transpose(0, 2, 1, 3) \
                        .reshape(B, S, Hq)

    return out, _rows(res[1]), _rows(res[2])
