"""jax version compatibility for Pallas TPU kernels."""
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases CompilerParams.
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
