"""Opt-KV write-path Pallas kernel (paper §3.1 Alg. 1 Phase 1 + Eq. 5),
scattering into the GLOBAL paged-KV pool.

Scatters new tokens' K/V into the shared pool with (a) SkipSet filtering —
tokens whose slot is negative are routed to a sentinel cache line and never
touch live pages ("skip caching of K_i, V_i"; padding, prefix-cache hits),
and (b) fused FP8 e4m3 quantization: amax-per-(token, head) scale computed in
VREGs, quantized tile written in the same pass, so the unquantized K/V never
round-trip to HBM.

Mechanics: the GLOBAL flat slot index (B, S) is scalar-prefetched and
dereferenced inside the output BlockSpec index_map — the line written by grid
step (b, s) IS the cache line of lane b's token s (or the sentinel line for
SkipSet tokens). Because the refcounted BlockManager hands lanes disjoint
writable pages (shared prefix pages are read-only by construction), lanes
never race on a line. The cache is passed aliased (donated), so unwritten
lines keep their contents — this is the TPU analogue of an in-place scatter
with ``mode='drop'``.

Sentinel convention: the pool's very last cache line (flat slot NSlot-1) is
reserved — the engine's BlockManager never allocates the final page, so the
line only ever absorbs skipped tokens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.cache.quant import FP8_MAX


def _write_kernel(slot_ref, k_ref, v_ref,
                  kc_in, vc_in, ks_in, vs_in,          # aliased cache (unused)
                  kc_ref, vc_ref, ks_ref, vs_ref,      # outputs
                  *, opt_kv: bool):
    # k_ref/v_ref: (1, 1, Hkv, D) — one token, all kv heads.
    k = k_ref[0, 0].astype(jnp.float32)                 # (Hkv, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if opt_kv:
        k_amax = jnp.max(jnp.abs(k), axis=-1, keepdims=True)
        v_amax = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
        k_s = jnp.maximum(k_amax, 1e-12) / FP8_MAX
        v_s = jnp.maximum(v_amax, 1e-12) / FP8_MAX
        kc_ref[0] = (k / k_s).astype(kc_ref.dtype)
        vc_ref[0] = (v / v_s).astype(vc_ref.dtype)
        ks_ref[0] = k_s[:, 0]
        vs_ref[0] = v_s[:, 0]
    else:
        kc_ref[0] = k.astype(kc_ref.dtype)
        vc_ref[0] = v.astype(vc_ref.dtype)
        ks_ref[0] = jnp.zeros(ks_ref.shape[1:], jnp.float32)
        vs_ref[0] = jnp.zeros(vs_ref.shape[1:], jnp.float32)


def kv_cache_write(k_new, v_new, slot_idx, k_cache, v_cache, k_scale, v_scale,
                   *, opt_kv: bool, interpret: bool = True):
    """k/v_new: (B, S, Hkv, D); slot_idx: (B, S) int32 GLOBAL flat slots
    (-1 / SkipSet => drop); k/v_cache: (NSlot, Hkv, D) flat GLOBAL pool whose
    last line is the reserved sentinel; k/v_scale: (NSlot, Hkv) f32 (zeros ok
    if !opt_kv). Returns updated (k_cache, v_cache, k_scale, v_scale)."""
    B, S, Hkv, D = k_new.shape
    NS = k_cache.shape[0]          # includes the sentinel line
    sentinel = NS - 1
    slots = jnp.where(slot_idx < 0, sentinel, slot_idx).astype(jnp.int32)

    # no jnp.maximum clamp needed: -1 slots were pre-mapped to the pool's
    # reserved sentinel line (`slots = jnp.where(slot_idx < 0, sentinel,
    # ...)` above), so -1 can never reach these index_maps
    def cache_idx(b, s, slot):
        return (slot[b, s], 0, 0)  # coopt: allow[COOPT005]

    def scale_idx(b, s, slot):
        return (slot[b, s], 0)  # coopt: allow[COOPT005]

    kern = functools.partial(_write_kernel, opt_kv=opt_kv)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, S),
            in_specs=[
                pl.BlockSpec((1, 1, Hkv, D), lambda b, s, slot: (b, s, 0, 0)),
                pl.BlockSpec((1, 1, Hkv, D), lambda b, s, slot: (b, s, 0, 0)),
                pl.BlockSpec((1, Hkv, D), cache_idx),
                pl.BlockSpec((1, Hkv, D), cache_idx),
                pl.BlockSpec((1, Hkv), scale_idx),
                pl.BlockSpec((1, Hkv), scale_idx),
            ],
            out_specs=[
                pl.BlockSpec((1, Hkv, D), cache_idx),
                pl.BlockSpec((1, Hkv, D), cache_idx),
                pl.BlockSpec((1, Hkv), scale_idx),
                pl.BlockSpec((1, Hkv), scale_idx),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(k_cache.shape, k_cache.dtype),
            jax.ShapeDtypeStruct(v_cache.shape, v_cache.dtype),
            jax.ShapeDtypeStruct(k_scale.shape, jnp.float32),
            jax.ShapeDtypeStruct(v_scale.shape, jnp.float32),
        ],
        # aliased: unwritten cache lines keep their contents (scatter 'drop')
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        interpret=interpret,
    )(slots, k_new, v_new, k_cache, v_cache, k_scale, v_scale)
    return out
