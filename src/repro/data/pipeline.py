"""Data pipelines.

Serving: synthetic request streams with the length statistics of
ShareGPT_V3_unfiltered_cleaned_split (the paper's throughput dataset §4.2).
No dataset ships with the container, so lengths are drawn from lognormal fits
of the published ShareGPT distribution (prompt median ~ 160 tok, long tail to
2k+; output median ~ 240 tok) — what matters for the paper's claims is the
*length mix* (page occupancy, padding fraction, batch churn), not the text.

Training: deterministic synthetic LM batches (token stream + shifted labels)
for the train_4k shape and the end-to-end training example.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, List, Optional

import numpy as np

from repro.serving.request import Request


@dataclass(frozen=True)
class ShareGPTStats:
    """Lognormal length model of the ShareGPT conversation mix."""
    prompt_log_mean: float = 5.1      # exp(5.1) ~ 164 tokens median
    prompt_log_std: float = 0.9
    output_log_mean: float = 5.5      # exp(5.5) ~ 245 tokens median
    output_log_std: float = 0.8
    min_prompt: int = 4
    max_prompt: int = 2048
    min_output: int = 4
    max_output: int = 1024


class RequestStream:
    """Deterministic synthetic ShareGPT-like request source."""

    def __init__(self, vocab_size: int, stats: ShareGPTStats = ShareGPTStats(),
                 seed: int = 0, scale: float = 1.0):
        """``scale`` shrinks lengths (reduced-model benchmarks on CPU)."""
        self.vocab = vocab_size
        self.stats = stats
        self.rng = np.random.default_rng(seed)
        self.scale = scale
        self._next_id = 0

    def _len(self, mu, sigma, lo, hi) -> int:
        n = int(np.exp(self.rng.normal(mu, sigma)) * self.scale)
        return int(np.clip(n, max(int(lo * self.scale), 2),
                           max(int(hi * self.scale), 4)))

    def next_request(self, max_new_tokens: Optional[int] = None) -> Request:
        st = self.stats
        plen = self._len(st.prompt_log_mean, st.prompt_log_std,
                         st.min_prompt, st.max_prompt)
        olen = max_new_tokens or self._len(st.output_log_mean,
                                           st.output_log_std,
                                           st.min_output, st.max_output)
        prompt = self.rng.integers(0, self.vocab, plen, dtype=np.int32)
        self._next_id += 1
        return Request(req_id=self._next_id, prompt=prompt,
                       max_new_tokens=olen)

    def take(self, n: int, max_new_tokens: Optional[int] = None
             ) -> List[Request]:
        return [self.next_request(max_new_tokens) for _ in range(n)]


def sharegpt_stream(vocab_size: int, n: int, seed: int = 0,
                    scale: float = 1.0) -> List[Request]:
    return RequestStream(vocab_size, seed=seed, scale=scale).take(n)


# ---------------------------------------------------------------- training --
class TrainPipeline:
    """Synthetic LM batches: structured (Zipf-ish) token stream so the loss
    actually decreases during the end-to-end training example."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        # fixed bigram table => learnable structure
        self._succ = self.rng.integers(0, vocab_size,
                                       (vocab_size, 4), dtype=np.int32)

    def next_batch(self) -> dict:
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = self.rng.integers(0, self.vocab, B)
        noise = self.rng.random((B, S))
        choice = self.rng.integers(0, 4, (B, S))
        rand_tok = self.rng.integers(0, self.vocab, (B, S), dtype=np.int32)
        for t in range(S):
            follow = self._succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.85, follow,
                                      rand_tok[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def train_batches(vocab_size: int, batch: int, seq_len: int, steps: int,
                  seed: int = 0) -> Iterator[dict]:
    pipe = TrainPipeline(vocab_size, batch, seq_len, seed)
    for _ in range(steps):
        yield pipe.next_batch()
