from repro.data.pipeline import (RequestStream, ShareGPTStats, TrainPipeline,
                                 sharegpt_stream, train_batches)

__all__ = ["RequestStream", "ShareGPTStats", "TrainPipeline",
           "sharegpt_stream", "train_batches"]
