"""COOPT005 — Pallas kernel contracts: index_map discipline, the ``-1``
page sentinel, and a static VMEM budget.

Lineage: the paged kernels (PRs 3-5) share three load-bearing conventions:

  * BlockSpec ``index_map`` functions run on the TPU scalar core BEFORE the
    block DMA — they may only dereference SCALAR-PREFETCHED refs (the
    trailing params injected by ``PrefetchScalarGridSpec``). Touching a
    grid index as an array, or a closed-over tensor, is not a type error —
    it miscompiles or silently reads garbage.
  * Page tables use ``-1`` for never-allocated slots. An index_map that
    dereferences a table without clamping (``jnp.maximum(phys[b, s], 0)``)
    turns ``-1`` into a wrap-around DMA of the pool's LAST page — exactly
    the PR 5 slot-wrap incident class, where an unhandled sentinel let a
    write land on a live pool line. (The write kernel instead pre-maps
    ``-1`` to a reserved sentinel line before the call; its index_maps
    carry inline allows citing that.)
  * Every block named by the specs is resident in VMEM (~16 MiB/core),
    double-buffered, alongside the scratch accumulators. The estimator
    below computes worst-case residency from the BlockSpec shapes and
    fails the build when a kernel's working set crosses the budget
    (default half of VMEM, leaving headroom for the compiler's own
    allocations) — so a block-size bump that would OOM on hardware fails
    in CI on the CPU container instead.

Shape symbols are resolved against documented repo defaults (page size 64
from ``core.coopt``, head dim 128, block_q/block_k 256, ...); unresolvable
dims fall back to 128 and are listed in the report so a human can audit
the estimate.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.core import (FileCtx, Finding, dotted_name,
                                 enclosing_index, scope_of)

CODE = "COOPT005"

DEFAULT_BUDGET = 8 * 1024 * 1024    # bytes: half of ~16 MiB VMEM/core

# documented repo defaults for symbolic block dims (see module docstring)
ASSUMPTIONS: Dict[str, int] = {
    "ps": 64,       # CoOptConfig.page_size
    "D": 128,       # attention head dim
    "bq": 256, "bk": 256, "block_q": 256, "block_k": 256,
    "G": 8,         # GQA group size upper bound
    "Hkv": 8, "H": 128, "Hq": 64,
    "R": 512,       # MLA latent rank
    "W": 576,       # packed latent width R + d_rope
    "dr": 64,       # rope sub-dim
    # cross-lane visit grids (kernels.visits): flattened row counts at the
    # MAX_VISIT_LANES=32 dispatch ceiling — BG = B*G, BH = B*H_q(mla=8)
    "BG": 128, "BH": 256,
    # tile-resident chunk streaming: resident row-block caps
    # (flash_chunk_prefill.RESIDENT_ROWS / latent_chunk_prefill's)
    "rq": 1024, "rl": 512,
}
_UNKNOWN_DEFAULT = 128

_CLAMP_FUNCS = {"jnp.maximum", "jnp.clip", "jax.lax.max", "lax.max",
                "jax.numpy.maximum", "jax.numpy.clip"}
_DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "bfloat16": 2,
                "float16": 2, "int16": 2, "int8": 1, "uint8": 1,
                "float8_e4m3fn": 1, "float8_e5m2": 1, "bool_": 1}


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return "<expr>"


# --------------------------------------------------------- dim evaluation --
def _eval_dim(node: ast.AST, used: Dict[str, int],
              unknown: List[str]) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in ASSUMPTIONS:
            used[node.id] = ASSUMPTIONS[node.id]
            return ASSUMPTIONS[node.id]
        unknown.append(node.id)
        return _UNKNOWN_DEFAULT
    if isinstance(node, ast.BinOp):
        lhs = _eval_dim(node.left, used, unknown)
        rhs = _eval_dim(node.right, used, unknown)
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return max(lhs - rhs, 1)
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, ast.FloorDiv):
            return max(lhs // max(rhs, 1), 1)
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        vals = [_eval_dim(a, used, unknown) for a in node.args]
        if fname == "min" and vals:
            return min(vals)
        if fname == "max" and vals:
            return max(vals)
    unknown.append(_unparse(node))
    return _UNKNOWN_DEFAULT


def _dtype_bytes(node: ast.AST) -> int:
    name = dotted_name(node)
    if name:
        return _DTYPE_BYTES.get(name.split(".")[-1], 4)
    return 4


# ------------------------------------------------------------- resolution --
def _local_assigns(fn: ast.AST) -> Dict[str, List[ast.AST]]:
    """name -> every value ever assigned/augmented onto it in ``fn``."""
    out: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.setdefault(t.id, []).append(node.value)
        elif isinstance(node, ast.AugAssign) and \
                isinstance(node.target, ast.Name):
            out.setdefault(node.target.id, []).append(node.value)
    return out


def _is_blockspec(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and \
        (dotted_name(node.func) or "").split(".")[-1] == "BlockSpec"


def _resolve_specs(node: Optional[ast.AST],
                   assigns: Dict[str, List[ast.AST]]) -> List[ast.Call]:
    """Flatten a spec expression (list literal / single BlockSpec / local
    name built via ``x = [a]; x += [b, c]``) into BlockSpec calls. The
    union over every assignment is taken — a conservative upper bound for
    conditionally-appended specs (the ``return_state`` idiom)."""
    if node is None:
        return []
    if _is_blockspec(node):
        return [node]
    if isinstance(node, (ast.List, ast.Tuple)):
        out = []
        for el in node.elts:
            out.extend(_resolve_specs(el, assigns))
        return out
    if isinstance(node, ast.Name) and node.id in assigns:
        out = []
        for val in assigns[node.id]:
            out.extend(_resolve_specs(val, assigns))
        return out
    return []


def _resolve_index_map(node: Optional[ast.AST], fn: ast.AST):
    """The index_map callable behind a BlockSpec's second arg: an inline
    Lambda, a local ``def``, or a name bound to a lambda."""
    if node is None:
        return None
    if isinstance(node, ast.Lambda):
        return node
    if isinstance(node, ast.Name):
        for n in ast.walk(fn):
            if isinstance(n, ast.FunctionDef) and n.name == node.id:
                return n
            if isinstance(n, ast.Assign) and \
                    isinstance(n.value, ast.Lambda):
                for t in n.targets:
                    if isinstance(t, ast.Name) and t.id == node.id:
                        return n.value
    return None


def _params_of(im) -> List[str]:
    args = im.args
    return [a.arg for a in list(args.posonlyargs) + list(args.args)]


def _parent_map(root: ast.AST) -> Dict[int, ast.AST]:
    out = {}
    for node in ast.walk(root):
        for child in ast.iter_child_nodes(node):
            out[id(child)] = node
    return out


def _clamped(sub: ast.Subscript, parents: Dict[int, ast.AST]) -> bool:
    node: ast.AST = sub
    while id(node) in parents:
        node = parents[id(node)]
        if isinstance(node, ast.Call) and \
                dotted_name(node.func) in _CLAMP_FUNCS:
            return True
    return False


# ------------------------------------------------------------ the checks --
def _check_index_map(f: FileCtx, qual: str, im, grid_len: int,
                     num_prefetch: int, out: List[Finding]) -> None:
    params = _params_of(im)
    prefetch = set(params[grid_len:]) if num_prefetch else set()
    parents = _parent_map(im)
    for node in ast.walk(im):
        if not isinstance(node, ast.Subscript):
            continue
        base = node.value
        while isinstance(base, ast.Subscript):
            base = base.value
        if not isinstance(base, ast.Name):
            continue
        if base.id in prefetch:
            if not _clamped(node, parents):
                out.append(Finding(
                    code=CODE, path=f.path, line=node.lineno, symbol=qual,
                    message=(f"index_map dereferences page table "
                             f"'{base.id}' without clamping the -1 "
                             "sentinel: wrap in jnp.maximum(..., 0) (or "
                             "pre-map -1 to a reserved line before the "
                             "call) so unallocated pages cannot DMA a "
                             "wrapped pool line")))
        elif base.id in params:
            out.append(Finding(
                code=CODE, path=f.path, line=node.lineno, symbol=qual,
                message=(f"index_map subscripts grid index '{base.id}': "
                         "only scalar-prefetch refs (the trailing "
                         f"{num_prefetch} params) may be dereferenced "
                         "inside an index_map")))
        else:
            out.append(Finding(
                code=CODE, path=f.path, line=node.lineno, symbol=qual,
                message=(f"index_map subscripts closed-over value "
                         f"'{base.id}': index_maps run on the scalar core "
                         "before the DMA and may only touch their params "
                         "(scalar-prefetch refs); pass the table through "
                         "PrefetchScalarGridSpec instead")))


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _analyze_site(f: FileCtx, qual: str, fn: ast.AST, call: ast.Call,
                  budget: int, out: List[Finding],
                  report: List[Dict[str, object]]) -> None:
    assigns = _local_assigns(fn)
    grid_spec = _kw(call, "grid_spec")
    num_prefetch = 0
    if isinstance(grid_spec, ast.Call):
        src = grid_spec
        npf = _kw(grid_spec, "num_scalar_prefetch")
        if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
            num_prefetch = npf.value
    else:
        src = call
    grid = _kw(src, "grid")
    grid_len = len(grid.elts) if isinstance(grid, (ast.Tuple, ast.List)) \
        else 0
    in_specs = _resolve_specs(_kw(src, "in_specs"), assigns)
    out_specs = _resolve_specs(_kw(src, "out_specs"), assigns)
    scratch = _kw(call, "scratch_shapes") or _kw(src, "scratch_shapes")

    used: Dict[str, int] = {}
    unknown: List[str] = []
    block_bytes = 0
    for spec in in_specs + out_specs:
        shape = spec.args[0] if spec.args else None
        dims = 1
        if isinstance(shape, (ast.Tuple, ast.List)):
            for d in shape.elts:
                dims *= _eval_dim(d, used, unknown)
        block_bytes += dims * 4           # f32 upper bound per element
        im = _resolve_index_map(spec.args[1] if len(spec.args) > 1 else None,
                                fn)
        if im is not None:
            _check_index_map(f, qual, im, grid_len, num_prefetch, out)
    scratch_bytes = 0
    if isinstance(scratch, (ast.List, ast.Tuple)):
        for s in scratch.elts:
            if isinstance(s, ast.Call) and s.args:
                dims = 1
                if isinstance(s.args[0], (ast.Tuple, ast.List)):
                    for d in s.args[0].elts:
                        dims *= _eval_dim(d, used, unknown)
                nbytes = _dtype_bytes(s.args[1]) if len(s.args) > 1 else 4
                scratch_bytes += dims * nbytes
    total = block_bytes * 2 + scratch_bytes   # x2: double-buffered DMA
    entry = {
        "kernel": qual or "<module>", "path": f.path, "line": call.lineno,
        "grid": _unparse(grid) if grid is not None else None,
        "num_scalar_prefetch": num_prefetch,
        "num_block_specs": len(in_specs) + len(out_specs),
        "block_bytes": block_bytes, "scratch_bytes": scratch_bytes,
        "est_vmem_bytes": total, "budget_bytes": budget,
        "under_budget": total <= budget,
        "assumed_dims": dict(sorted(used.items())),
        "unresolved_dims": sorted(set(unknown)),
    }
    report.append(entry)
    if total > budget:
        out.append(Finding(
            code=CODE, path=f.path, line=call.lineno, symbol=qual,
            message=(f"estimated VMEM working set {total} bytes exceeds "
                     f"the {budget}-byte budget (blocks {block_bytes} x2 "
                     f"double-buffered + scratch {scratch_bytes}): shrink "
                     "the BlockSpec block shapes or raise --vmem-budget "
                     "with a hardware justification")))


def run(files: Sequence[FileCtx], *, vmem_budget: Optional[int] = None
        ) -> Tuple[List[Finding], List[Dict[str, object]]]:
    budget = vmem_budget if vmem_budget else DEFAULT_BUDGET
    out: List[Finding] = []
    report: List[Dict[str, object]] = []
    for f in files:
        if "kernels/" not in f.path:
            continue
        index = enclosing_index(f.tree)
        scope_nodes = {}
        from repro.analysis.core import iter_scopes
        for q, fn, _c in iter_scopes(f.tree):
            scope_nodes[q] = fn
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call) and \
                    (dotted_name(node.func) or "").split(".")[-1] == \
                    "pallas_call":
                qual = scope_of(index, node.lineno)
                fn = scope_nodes.get(qual, f.tree)
                _analyze_site(f, qual, fn, node, budget, out, report)
    report.sort(key=lambda e: (e["path"], e["line"]))
    return out, report
