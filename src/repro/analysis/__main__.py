"""CLI for cooptlint: ``python -m repro.analysis [paths...]``.

Exit status is 0 iff every finding is suppressed inline or carried by the
committed baseline — so CI can run this as a blocking gate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis.core import (Finding, load_baseline, run_suite,
                                 write_baseline)

_DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baseline.json")


def _fmt_bytes(n: int) -> str:
    return f"{n / (1024 * 1024):.2f} MiB"


def _print_text(live: List[Finding], suppressed: List[Finding],
                baselined: List[Finding], vmem_report, show_vmem: bool
                ) -> None:
    for f in live:
        sym = f" [{f.symbol}]" if f.symbol else ""
        print(f"{f.path}:{f.line}: {f.code}{sym}: {f.message}")
    if show_vmem and vmem_report:
        print()
        print("VMEM report (est. per-kernel working set, "
              "blocks x2 double-buffered + scratch):")
        for e in vmem_report:
            mark = "OK " if e["under_budget"] else "OVER"
            extra = ""
            if e["unresolved_dims"]:
                extra = (" (unresolved dims default to 128: "
                         + ", ".join(e["unresolved_dims"]) + ")")
            print(f"  {mark} {e['kernel']:<45s} "
                  f"{_fmt_bytes(e['est_vmem_bytes']):>10s} / "
                  f"{_fmt_bytes(e['budget_bytes'])}"
                  f"  ({e['path']}:{e['line']}){extra}")
    print()
    print(f"cooptlint: {len(live)} finding(s), {len(suppressed)} "
          f"suppressed inline, {len(baselined)} baselined")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="cooptlint: static analysis for the serving stack's "
                    "trace-safety, donation, host-sync, mesh-ctx, and "
                    "Pallas kernel contracts")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=_DEFAULT_BASELINE,
                    help="baseline JSON of grandfathered findings "
                         "(default: the committed src/repro/analysis/"
                         "baseline.json); pass '' to disable")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current live findings to --baseline "
                         "(each entry then needs a justification) and "
                         "exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated finding codes to run, e.g. "
                         "COOPT001,COOPT005")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="per-kernel VMEM budget in bytes "
                         "(default: 8388608 = half of ~16 MiB/core)")
    ap.add_argument("--vmem-report", default=None, metavar="FILE",
                    help="also write the per-kernel VMEM report as JSON")
    args = ap.parse_args(argv)

    paths = args.paths or ["src/repro"]
    select = ([c.strip() for c in args.select.split(",") if c.strip()]
              if args.select else None)
    baseline = args.baseline or None

    live, suppressed, baselined, vmem_report = run_suite(
        paths, select=select, baseline_path=baseline,
        vmem_budget=args.vmem_budget)

    if args.write_baseline:
        if not baseline:
            print("--write-baseline requires --baseline", file=sys.stderr)
            return 2
        write_baseline(baseline, live)
        print(f"wrote {len(live)} finding(s) to {baseline}; fill in the "
              "justification for each")
        return 0

    if args.vmem_report:
        with open(args.vmem_report, "w", encoding="utf-8") as fh:
            json.dump({"budget_bytes": args.vmem_budget or 8 * 1024 * 1024,
                       "kernels": vmem_report}, fh, indent=2)
            fh.write("\n")

    # stale-baseline hygiene: entries that no longer match anything are
    # reported (non-fatal) so the baseline shrinks over time
    stale = 0
    if baseline:
        matched = {f.match_key() for f in baselined}
        from repro.analysis.core import baseline_keys
        stale = len(baseline_keys(load_baseline(baseline)) - matched)

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_json() for f in live],
            "suppressed": [f.to_json() for f in suppressed],
            "baselined": [f.to_json() for f in baselined],
            "stale_baseline_entries": stale,
            "vmem_report": vmem_report,
        }, indent=2))
    else:
        _print_text(live, suppressed, baselined, vmem_report,
                    show_vmem=True)
        if stale:
            print(f"note: {stale} baseline entr{'y' if stale == 1 else 'ies'} "
                  "no longer match any finding — prune the baseline")
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
