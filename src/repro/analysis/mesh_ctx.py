"""COOPT003 — mesh-ctx scoping.

Lineage: PR 5's shard_map dispatch keys every kernel wrapper off a module
global (``ops._MESH_CTX``) that is read at TRACE time. The jit-cache-leak
class from that PR: install a ctx, trace a step, and forget to restore —
every LATER trace (a different engine, a test, a benchmark sharing the
process) silently inherits the stale mesh and dispatches single-host work
through shard_map (or vice versa). Because the leak lives in cached
traces, it survives long after the offending code returns. PR 5's fix was
``ops.mesh_ctx_scope`` — bind for the duration of a trace, restore in
``finally``.

Contract enforced: every ``ops.set_mesh_ctx(...)`` call must be either
(a) inside the implementation itself (``set_mesh_ctx`` / the
``mesh_ctx_scope`` context manager), or (b) part of an explicit
save/restore pair in the same function — a ``saved = ops.mesh_ctx()``
capture before the install and a ``ops.set_mesh_ctx(saved)`` restore
after it (ideally in a ``finally``). Anything else — including
module-level installs — is a finding: wrap the region in
``with ops.mesh_ctx_scope(ctx):`` instead.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.core import FileCtx, Finding, dotted_name, iter_scopes

CODE = "COOPT003"

# functions allowed to call set_mesh_ctx directly: the primitive itself and
# the canonical scope wrapper that restores in `finally`
_IMPL_FUNCS = {"set_mesh_ctx", "mesh_ctx_scope"}


def _is_set_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return name is not None and name.split(".")[-1] == "set_mesh_ctx"


def _is_ctx_read(node: ast.AST) -> bool:
    """``ops.mesh_ctx()`` call or a direct ``_MESH_CTX`` read."""
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        return name is not None and name.split(".")[-1] == "mesh_ctx"
    name = dotted_name(node)
    return name is not None and name.split(".")[-1] == "_MESH_CTX"


def _saved_names(fn: ast.AST) -> dict:
    """name -> lineno for ``saved = ops.mesh_ctx()`` style captures."""
    out = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _is_ctx_read(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.lineno
    return out


def _check_function(f: FileCtx, qual: str, fn: ast.AST,
                    out: List[Finding]) -> None:
    if qual.split(".")[-1] in _IMPL_FUNCS:
        return
    calls = [n for n in ast.walk(fn)
             if isinstance(n, ast.Call) and _is_set_call(n)]
    if not calls:
        return
    saved = _saved_names(fn)
    # restores: set_mesh_ctx(saved_name) with the capture before the restore
    restore_lines: Set[int] = set()
    for c in calls:
        if len(c.args) == 1 and isinstance(c.args[0], ast.Name) and \
                c.args[0].id in saved and saved[c.args[0].id] < c.lineno:
            restore_lines.add(c.lineno)
    for c in calls:
        if c.lineno in restore_lines:
            continue  # the restore half of a pair is always fine
        has_save_before = any(ln < c.lineno for ln in saved.values())
        has_restore_after = any(ln > c.lineno for ln in restore_lines)
        if has_save_before and has_restore_after:
            continue  # explicit save/restore pair
        out.append(Finding(
            code=CODE, path=f.path, line=c.lineno, symbol=qual,
            message=("un-scoped set_mesh_ctx call: installs a trace-time "
                     "dispatch ctx with no save/restore pair — later "
                     "jit traces inherit the stale mesh; use "
                     "`with ops.mesh_ctx_scope(ctx):` instead")))


def run(files: List[FileCtx]) -> List[Finding]:
    out: List[Finding] = []
    for f in files:
        func_spans = []
        for qual, fn, _cls in iter_scopes(f.tree):
            _check_function(f, qual, fn, out)
            func_spans.append((fn.lineno,
                               getattr(fn, "end_lineno", fn.lineno)))
        # module-level installs (outside every function) are never scoped
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call) and _is_set_call(node) and \
                    not any(lo <= node.lineno <= hi for lo, hi in func_spans):
                out.append(Finding(
                    code=CODE, path=f.path, line=node.lineno, symbol="",
                    message=("module-level set_mesh_ctx install: the ctx "
                             "leaks into every subsequent trace in the "
                             "process; bind it inside "
                             "`ops.mesh_ctx_scope` at trace time")))
    return out
