"""COOPT001 — host-sync discipline on the serving step path.

Lineage: PR 6's whole design exists to prevent this class. The async
pipeline (``serving.frontend``) overlaps host plan-building with device
execution ONLY because exactly one code path blocks on device values — the
emit worker's ``np.asarray(tokens)``. Any other ``np.asarray`` /
``.block_until_ready()`` / ``.item()`` / ``float()`` applied to a device
value on the step path re-serializes the pipeline: the host stalls, the
device starves, and the dispatch-depth-2 win silently evaporates (the
pipeline-stall class CHANGES.md PR 6 calls out — "a device-resident
lane_tok feed so decode plans never wait for token values").

Contract enforced: inside the serving modules (``serving/engine.py``,
``serving/frontend.py``) every host-sync pattern must live in one of the
ALLOWED scopes below — the sync loop's designated host boundary
(``Engine._execute`` / ``Engine._sample``), the async pipeline's single
sync point (``AsyncEngine._emit_worker``), or host-side setup/client-API
scopes that never run per-step. Anything else is a finding: move the sync
to the emit worker, keep the value on device, or — if the sync is a
deliberate design decision — add an inline ``# coopt: allow[COOPT001]``
with a rationale (canonical example: ``EngineStats._pct``, which applies
``float``/``np.asarray`` to host-side Python lists, not device values).
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import (FileCtx, Finding, dotted_name,
                                 enclosing_index, scope_of)

CODE = "COOPT001"

# modules under the host-sync contract (matched by path suffix)
CHECKED_SUFFIXES = ("serving/engine.py", "serving/frontend.py")

# scopes where host syncs are part of the design, not a pipeline stall
ALLOWED_SCOPES = frozenset({
    # setup / teardown — never on the per-step path
    "Engine.__init__", "Engine._place_cache", "Engine.warmup",
    "Engine._dummy_batch", "Engine._warmup_lattice",
    "AsyncEngine.__init__", "AsyncEngine.close",
    # the synchronous loop's designated host boundary: _execute blocks on
    # the step it just dispatched, _sample converts its logits' samples
    "Engine._execute", "Engine._sample",
    # client API — coerces caller-provided host prompts, stamps times
    "Engine.generate", "Engine.add_request", "AsyncEngine.submit",
    # THE async host sync: the emit worker owns the only blocking convert
    "AsyncEngine._emit_worker",
})

# call patterns that force a device->host sync when fed a device value
_SYNC_FUNCS = {"np.asarray", "numpy.asarray", "np.array", "numpy.array",
               "jax.device_get"}
_SYNC_METHODS = {"block_until_ready", "item"}


def _is_checked(path: str) -> bool:
    return any(path.endswith(s) for s in CHECKED_SUFFIXES)


def _sync_kind(node: ast.Call):
    """Return a description if this call matches a sync pattern."""
    fn = node.func
    name = dotted_name(fn)
    if name in _SYNC_FUNCS:
        return f"{name}(...)"
    if isinstance(fn, ast.Attribute) and fn.attr in _SYNC_METHODS \
            and not isinstance(fn.value, ast.Constant):
        return f".{fn.attr}()"
    if isinstance(fn, ast.Name) and fn.id == "float" and node.args \
            and not isinstance(node.args[0], ast.Constant):
        return "float(...)"
    return None


def run(files: List[FileCtx]) -> List[Finding]:
    out: List[Finding] = []
    for f in files:
        if not _is_checked(f.path):
            continue
        index = enclosing_index(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            kind = _sync_kind(node)
            if kind is None:
                continue
            scope = scope_of(index, node.lineno)
            if scope in ALLOWED_SCOPES:
                continue
            out.append(Finding(
                code=CODE, path=f.path, line=node.lineno, symbol=scope,
                message=(f"host sync {kind} on the serving step path "
                         f"(scope {scope or '<module>'}): only "
                         "AsyncEngine._emit_worker (async) and "
                         "Engine._execute/_sample (sync loop) may block "
                         "on device values")))
    return out
