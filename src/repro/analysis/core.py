"""cooptlint infrastructure: findings, file contexts, inline suppression,
the committed baseline, and the pass runner.

Design notes:

  * Passes receive the WHOLE file set (``List[FileCtx]``), not one file at
    a time — donation analysis (COOPT002) and trace-safety (COOPT004) need
    cross-file registries (e.g. ``StepBundle.jitted`` is defined in
    ``launch/steps.py`` and called from ``launch/dryrun.py``).
  * Baseline entries match on ``(code, path, symbol, message)`` — line
    numbers drift under refactors, so they are recorded for humans but
    ignored for matching. Every entry carries a ``justification``.
  * Inline suppression is comment-based (``# coopt: allow[CODE]``) on the
    finding's line or the line directly above, so the rationale lives next
    to the code it excuses.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_ALLOW_RE = re.compile(r"#\s*coopt:\s*allow\[([A-Z0-9_,\s]+)\]")


@dataclass(frozen=True)
class Finding:
    code: str                  # stable pass code, e.g. "COOPT001"
    path: str                  # repo-relative posix path
    line: int                  # 1-based line of the offending node
    symbol: str                # enclosing qualname, e.g. "Engine._sample"
    message: str               # one-line description of the violation

    def match_key(self) -> Tuple[str, str, str, str]:
        """Baseline identity — line numbers excluded (they drift)."""
        return (self.code, self.path, self.symbol, self.message)

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclass
class FileCtx:
    """One parsed source file handed to every pass."""
    path: str                  # repo-relative posix path
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)
    # line -> frozenset of allowed codes (from `# coopt: allow[...]`)
    allows: Dict[int, frozenset] = field(default_factory=dict)

    @classmethod
    def parse(cls, abspath: str, relpath: str) -> "FileCtx":
        with open(abspath, "r", encoding="utf-8") as f:
            src = f.read()
        tree = ast.parse(src, filename=relpath)
        lines = src.splitlines()
        allows: Dict[int, frozenset] = {}
        for i, ln in enumerate(lines, start=1):
            m = _ALLOW_RE.search(ln)
            if m:
                codes = frozenset(c.strip() for c in m.group(1).split(",")
                                  if c.strip())
                allows[i] = codes
        return cls(path=relpath, source=src, tree=tree, lines=lines,
                   allows=allows)

    def suppressed(self, code: str, line: int) -> bool:
        """A finding at ``line`` is suppressed by an allow marker on the
        same line or the line directly above it."""
        for ln in (line, line - 1):
            if code in self.allows.get(ln, frozenset()):
                return True
        return False


# ------------------------------------------------------------- AST helpers --
def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_scopes(tree: ast.Module):
    """Yield ``(qualname, func_node, class_node_or_None)`` for every
    function/method in the module, including nested ones."""

    def walk(node, prefix: str, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child, cls
                yield from walk(child, q + ".", cls)
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.", child)

    yield from walk(tree, "", None)


def enclosing_index(tree: ast.Module) -> List[Tuple[str, int, int]]:
    """(qualname, first_line, last_line) per scope, innermost resolvable
    via :func:`scope_of`."""
    out = []
    for q, fn, _ in iter_scopes(tree):
        out.append((q, fn.lineno, max(fn.lineno,
                                      getattr(fn, "end_lineno", fn.lineno))))
    return out


def scope_of(index: List[Tuple[str, int, int]], line: int) -> str:
    """Innermost scope qualname containing ``line`` ('' = module level)."""
    best, best_span = "", None
    for q, lo, hi in index:
        if lo <= line <= hi:
            span = hi - lo
            if best_span is None or span <= best_span:
                best, best_span = q, span
    return best


# ---------------------------------------------------------------- baseline --
def load_baseline(path: str) -> List[Dict[str, object]]:
    if not path or not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    return list(data.get("findings", []))


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = [{"code": f.code, "path": f.path, "symbol": f.symbol,
                "message": f.message, "line": f.line,
                "justification": "TODO: justify or fix"}
               for f in findings]
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"comment": "cooptlint grandfathered findings; every "
                              "entry needs a one-line justification",
                   "findings": entries}, f, indent=2)
        f.write("\n")


def baseline_keys(entries: Iterable[Dict[str, object]]):
    return {(str(e.get("code")), str(e.get("path")), str(e.get("symbol")),
             str(e.get("message"))) for e in entries}


# ------------------------------------------------------------------ runner --
def collect_files(paths: Sequence[str],
                  root: Optional[str] = None) -> List[FileCtx]:
    root = root or os.getcwd()
    out: List[FileCtx] = []
    seen = set()
    for p in paths:
        ap = os.path.abspath(p)
        if os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__")
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        fp = os.path.join(dirpath, fn)
                        if fp not in seen:
                            seen.add(fp)
                            out.append(_parse_one(fp, root))
        elif ap.endswith(".py"):
            if ap not in seen:
                seen.add(ap)
                out.append(_parse_one(ap, root))
    return out


def _parse_one(abspath: str, root: str) -> FileCtx:
    rel = os.path.relpath(abspath, root)
    return FileCtx.parse(abspath, rel.replace(os.sep, "/"))


def all_passes():
    """The registered passes, in code order. Imported lazily so a syntax
    error in one pass module names itself instead of breaking import of
    the package."""
    from repro.analysis import (donation, exceptions, host_sync, mesh_ctx,
                                pallas_vmem, trace_safety)
    return [host_sync, donation, mesh_ctx, trace_safety, pallas_vmem,
            exceptions]


def run_suite(paths: Sequence[str], *, root: Optional[str] = None,
              select: Optional[Sequence[str]] = None,
              baseline_path: Optional[str] = None,
              vmem_budget: Optional[int] = None):
    """Run every (selected) pass over ``paths``.

    Returns ``(findings, suppressed, baselined, vmem_report)`` where
    ``findings`` are the live violations (not suppressed, not baselined).
    """
    files = collect_files(paths, root=root)
    by_path = {f.path: f for f in files}
    raw: List[Finding] = []
    vmem_report: List[Dict[str, object]] = []
    for mod in all_passes():
        if select and mod.CODE not in select:
            continue
        kwargs = {}
        if mod.CODE == "COOPT005" and vmem_budget is not None:
            kwargs["vmem_budget"] = vmem_budget
        result = mod.run(files, **kwargs)
        if mod.CODE == "COOPT005":
            found, vmem_report = result
        else:
            found = result
        raw.extend(found)
    # dedupe (a pass may report the same node through two spec lists)
    raw = sorted(set(raw), key=lambda f: (f.path, f.line, f.code, f.message))
    suppressed, live = [], []
    for f in raw:
        ctx = by_path.get(f.path)
        if ctx is not None and ctx.suppressed(f.code, f.line):
            suppressed.append(f)
        else:
            live.append(f)
    baselined: List[Finding] = []
    if baseline_path:
        keys = baseline_keys(load_baseline(baseline_path))
        still_live = []
        for f in live:
            if f.match_key() in keys:
                baselined.append(f)
            else:
                still_live.append(f)
        live = still_live
    return live, suppressed, baselined, vmem_report
