"""cooptlint — repo-specific static analysis for the serving stack's
unwritten contracts.

PRs 1-6 grew a serving substrate whose correctness rests on conventions no
generic linter knows about: the async pipeline is only sound if exactly one
code path host-syncs, buffer donation is only sound if no caller reads a
donated binding after dispatch, AOT warmup's zero-retrace guarantee is only
sound if jitted impls never capture mutable state, and every Pallas kernel
must honor the ``-1`` page sentinel and scalar-prefetch-only ``index_map``
contracts. Each pass here descends from a real incident recorded in
CHANGES.md; see the individual pass modules for the lineage.

Passes (stable finding codes):

  COOPT001  host-sync        stray device->host syncs on the serving step
                             path (``repro.analysis.host_sync``)
  COOPT002  use-after-donation  reads of a donated jit argument after the
                             donating call (``repro.analysis.donation``)
  COOPT003  mesh-ctx scoping  un-scoped ``ops.set_mesh_ctx`` calls
                             (``repro.analysis.mesh_ctx``)
  COOPT004  trace-safety     jitted fns capturing mutable state; full-pool
                             gathers on the kernel hot path
                             (``repro.analysis.trace_safety``)
  COOPT005  Pallas contracts  index_map / sentinel / VMEM-budget checks
                             (``repro.analysis.pallas_vmem``)
  COOPT006  fault swallowing  blanket ``except`` handlers that drop
                             exceptions inside serving loops/workers
                             (``repro.analysis.exceptions``)

Usage::

    python -m repro.analysis [paths...] [--format text|json]
        [--baseline FILE] [--write-baseline] [--vmem-report FILE]
        [--vmem-budget BYTES] [--select CODES]

Inline suppression: append ``# coopt: allow[COOPT001]`` (comma-separate
multiple codes) to the offending line or the line directly above it, with a
short rationale in the surrounding comment. Grandfathered findings live in
the committed baseline (``src/repro/analysis/baseline.json``), each with a
one-line justification; the CLI exits non-zero on any finding that is
neither suppressed nor baselined.
"""
from repro.analysis.core import (Finding, load_baseline, run_suite,
                                 write_baseline)

__all__ = ["Finding", "run_suite", "load_baseline", "write_baseline"]
