"""COOPT002 — use-after-donation.

Lineage: PR 6 made the engine donate its largest buffers — the whole paged
KV pool and the async pipeline's ``lane_tok`` feed — into every step
(``jax.jit(..., donate_argnums=...)``, ``serving/engine.py``), so XLA can
update pages in place instead of copying the pool each step. Donation's
contract is unforgiving: after the donating call, the caller's binding
refers to a buffer the runtime may already have reused. Reading it again
is at best a ``DeviceArray has been deleted`` crash and at worst (through
aliasing layers like the shard_map write path) silently corrupt pool
lines — the same class as PR 5's slot-wrap incident, where a stale mapping
let a write land on a live pool line.

Contract enforced: for every ``jax.jit(..., donate_argnums=...)`` site,
walk each caller and flag any read of the donated argument's binding after
the call, unless the call statement itself rebinds it (the engine's
idiom: ``logits, self.cache = fn(..., self.cache, ...)``).

Scope and honesty: the analysis resolves donating callables bound to
locals / ``self.`` attributes, dict-of-donating-fns lookups (the
``_execute`` idiom), and methods that RETURN a donating jit (the
``StepBundle.jitted`` idiom). Calls it cannot resolve (``fn(*args)``)
are skipped, not guessed at.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (FileCtx, Finding, dotted_name,
                                 enclosing_index, scope_of)

CODE = "COOPT002"


def _donate_argnums(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """The donate_argnums of a ``jax.jit(...)`` call, else None."""
    if dotted_name(call.func) not in ("jax.jit", "jit"):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Tuple):
                nums = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value,
                                                                  int):
                        nums.append(e.value)
                return tuple(nums)
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            return ()  # dynamic donate spec: registered, argnums unknown
    return None


def _binding_repr(node: ast.AST) -> Optional[str]:
    """Canonical text for a simple binding (Name or self.attr chain)."""
    name = dotted_name(node)
    if name is None:
        return None
    return name


class _Registry:
    """Donating callables: binding-text -> donated argnums; plus method
    names whose RETURN VALUE is a donating jit (``jitted`` idiom)."""

    def __init__(self):
        self.bindings: Dict[str, Tuple[int, ...]] = {}
        self.returning_methods: Dict[str, Tuple[int, ...]] = {}

    def register_from(self, files: List[FileCtx]) -> None:
        for f in files:
            for node in ast.walk(f.tree):
                if isinstance(node, ast.Assign) and \
                        isinstance(node.value, ast.Call):
                    nums = _donate_argnums(node.value)
                    if nums:
                        for t in node.targets:
                            b = _binding_repr(t)
                            if b:
                                self.bindings[b] = nums
                elif isinstance(node, ast.Return) and \
                        isinstance(node.value, ast.Call):
                    nums = _donate_argnums(node.value)
                    if nums:
                        # find the enclosing def name via the scope index
                        idx = enclosing_index(f.tree)
                        q = scope_of(idx, node.lineno)
                        if q:
                            self.returning_methods[q.split(".")[-1]] = nums

    def resolve_local(self, fn_node: ast.AST,
                      local: Dict[str, Tuple[int, ...]]) -> \
            Optional[Tuple[int, ...]]:
        b = _binding_repr(fn_node)
        if b is not None:
            if b in local:
                return local[b]
            if b in self.bindings:
                return self.bindings[b]
        return None


def _loads_of(node: ast.AST, binding: str) -> List[ast.AST]:
    """READ occurrences of ``binding`` inside ``node`` (stores excluded)."""
    hits = []
    for n in ast.walk(node):
        if _binding_repr(n) == binding and \
                isinstance(getattr(n, "ctx", None), ast.Load):
            # skip the inner parts of a longer attribute chain
            hits.append(n)
    return hits


def _stores_binding(stmt: ast.stmt, binding: str) -> bool:
    """Does ``stmt`` (re)bind ``binding`` (plain assignment targets)?"""
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        for el in (t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]):
            if _binding_repr(el) == binding:
                return True
    return False


def _scan_function(f: FileCtx, qual: str, fn: ast.AST, reg: _Registry,
                   out: List[Finding]) -> None:
    # local donating bindings inside this function:
    #   fn = {"a": self._x_fn, ...}[key]   (all values donating, same nums)
    #   fn = self._x_fn
    #   fn = bundle.jitted()
    local: Dict[str, Tuple[int, ...]] = {}
    stmts = list(ast.walk(fn))
    for node in stmts:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = _binding_repr(node.targets[0])
        if tgt is None:
            continue
        v = node.value
        if isinstance(v, ast.Subscript) and isinstance(v.value, ast.Dict):
            sets: Set[Tuple[int, ...]] = set()
            for dv in v.value.values:
                nums = reg.resolve_local(dv, {})
                if nums is None:
                    sets.clear()
                    break
                sets.add(nums)
            if len(sets) == 1:
                local[tgt] = sets.pop()
        elif isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute) \
                and v.func.attr in reg.returning_methods:
            local[tgt] = reg.returning_methods[v.func.attr]
        else:
            nums = reg.resolve_local(v, {})
            if nums is not None:
                local[tgt] = nums

    # walk statements; for each donating call, check reads-after
    body_stmts = [n for n in ast.walk(fn) if isinstance(n, ast.stmt)]
    for stmt in body_stmts:
        for call in [n for n in ast.walk(stmt)
                     if isinstance(n, ast.Call)]:
            nums = reg.resolve_local(call.func, local)
            if nums is None:
                # direct jax.jit(...)(...) invocation
                if isinstance(call.func, ast.Call):
                    nums = _donate_argnums(call.func)
                if not nums:
                    continue
            if any(isinstance(a, ast.Starred) for a in call.args):
                continue  # unresolvable splat — skipped, not guessed
            for argnum in nums:
                if argnum >= len(call.args):
                    continue
                donated = _binding_repr(call.args[argnum])
                if donated is None:
                    continue
                if _stores_binding(stmt, donated):
                    continue  # rebound by the call's own statement
                _flag_reads_after(f, qual, fn, stmt, call, donated, out)


def _flag_reads_after(f: FileCtx, qual: str, fn: ast.AST, call_stmt: ast.stmt,
                      call: ast.Call, donated: str,
                      out: List[Finding]) -> None:
    call_end = getattr(call_stmt, "end_lineno", call_stmt.lineno)
    stmts = sorted((n for n in ast.walk(fn) if isinstance(n, ast.stmt)),
                   key=lambda n: n.lineno)
    for stmt in stmts:
        if stmt.lineno <= call_end:
            continue
        if _stores_binding(stmt, donated):
            return  # rebound before any further read we'd flag
        reads = _loads_of(stmt, donated)
        if _stores_binding(stmt, donated) is False and reads:
            out.append(Finding(
                code=CODE, path=f.path, line=reads[0].lineno, symbol=qual,
                message=(f"read of donated binding '{donated}' after the "
                         f"donating call at line {call.lineno} "
                         "(donate_argnums): the buffer may already be "
                         "reused — rebind the result or drop the read")))
            return


def run(files: List[FileCtx]) -> List[Finding]:
    reg = _Registry()
    reg.register_from(files)
    out: List[Finding] = []
    for f in files:
        for qual, fn, _cls in [(q, n, c) for q, n, c in
                               _iter_funcs(f.tree)]:
            _scan_function(f, qual, fn, reg, out)
    return out


def _iter_funcs(tree: ast.Module):
    from repro.analysis.core import iter_scopes
    return iter_scopes(tree)
