"""COOPT004 — trace-safety of jitted step functions.

Lineage: two recorded incidents. (1) PR 6's AOT warmup promises ZERO
retraces at serve time (``warmup()`` pre-compiles every bucketed shape);
that guarantee only holds if jitted impls never read state that mutates
between traces — a closed-over mutable ``self`` attribute or a module
global silently bakes its TRACE-TIME value into the cached executable
(the ``ops.INTERPRET`` flag is the canonical hazard: it is flipped by
``configure_for_backend()`` AFTER import, so a jitted body that reads it
directly freezes whichever value import-time happened to see). (2) PR 4
replaced the ``jnp.take`` full-pool gather in the MLA decode path with
paged Pallas kernels precisely because a full-pool gather materialises
the ENTIRE KV pool per step — re-introducing one inside ``kernels/``
would quietly undo that PR.

Contracts enforced:

  * A jitted function (``@jax.jit`` / ``@partial(jax.jit, ...)`` decorated,
    or the impl behind ``self.X = jax.jit(self.X_impl, ...)``) must not
    read a module global that is reassigned through ``global X`` anywhere
    in its module, and must not read a ``self`` attribute that is stored
    outside ``__init__`` (mutable engine state like ``self.cache`` must
    flow through the function's arguments instead).
  * No ``jnp.take`` full-pool gathers inside ``kernels/`` modules —
    except ``kernels/ref.py``, the interpret-mode parity oracle whose
    whole point is the naive gather formulation.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import FileCtx, Finding, dotted_name, iter_scopes

CODE = "COOPT004"

_GATHER_FUNCS = {"jnp.take", "jax.numpy.take", "numpy.take"}
_INIT_SCOPES = {"__init__", "__post_init__", "setup"}


def _jit_decorated(fn) -> bool:
    for dec in fn.decorator_list:
        name = dotted_name(dec)
        if name in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call):
            cname = dotted_name(dec.func)
            if cname in ("jax.jit", "jit"):
                return True
            if cname in ("partial", "functools.partial") and dec.args and \
                    dotted_name(dec.args[0]) in ("jax.jit", "jit"):
                return True
    return False


def _jitted_impl_names(tree: ast.Module) -> Set[str]:
    """Method/function names passed positionally into ``jax.jit(...)``
    (the ``self._prefill_fn = jax.jit(self._prefill_impl, ...)`` idiom)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                dotted_name(node.func) in ("jax.jit", "jit") and node.args:
            target = dotted_name(node.args[0])
            if target:
                out.add(target.split(".")[-1])
    return out


def _mutable_globals(tree: ast.Module) -> Set[str]:
    """Module globals reassigned via ``global X`` inside some function."""
    out: Set[str] = set()
    for _q, fn, _c in iter_scopes(tree):
        declared: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Global):
                declared.update(node.names)
        if not declared:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id in declared:
                        out.add(t.id)
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.target, ast.Name) and \
                    node.target.id in declared:
                out.add(node.target.id)
    return out


def _self_attr_stores(fn) -> Set[str]:
    """Attribute names stored on ``self`` inside ``fn`` — plain stores,
    AugAssign, and item-stores (``self.x[...] = ...`` mutates the object
    ``self.x`` refers to, which is just as trace-hostile)."""
    out: Set[str] = set()

    def base_attr(target) -> Optional[str]:
        while isinstance(target, ast.Subscript):
            target = target.value
        if isinstance(target, ast.Attribute) and \
                isinstance(target.value, ast.Name) and \
                target.value.id == "self":
            return target.attr
        return None

    for node in ast.walk(fn):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                a = base_attr(el)
                if a:
                    out.add(a)
    return out


def _mutable_attrs_by_class(tree: ast.Module) -> Dict[str, Set[str]]:
    """class name -> attrs stored on ``self`` outside __init__-like
    scopes (these are per-step mutable state, not frozen config)."""
    out: Dict[str, Set[str]] = {}
    for q, fn, cls in iter_scopes(tree):
        if cls is None or q.split(".")[-1] in _INIT_SCOPES:
            continue
        out.setdefault(cls.name, set()).update(_self_attr_stores(fn))
    return out


def _param_names(fn) -> Set[str]:
    a = fn.args
    names = [p.arg for p in
             list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def _check_jitted_body(f: FileCtx, qual: str, fn, cls,
                       mutable_globals: Set[str],
                       mutable_attrs: Dict[str, Set[str]],
                       out: List[Finding]) -> None:
    params = _param_names(fn)
    locals_stored: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                           else [t]):
                    if isinstance(el, ast.Name):
                        locals_stored.add(el.id)
    cls_attrs = mutable_attrs.get(cls.name, set()) if cls else set()
    seen: Set[Tuple[str, str]] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            nm = node.id
            if nm in mutable_globals and nm not in params and \
                    nm not in locals_stored and ("g", nm) not in seen:
                seen.add(("g", nm))
                out.append(Finding(
                    code=CODE, path=f.path, line=node.lineno, symbol=qual,
                    message=(f"jitted function reads mutable module global "
                             f"'{nm}' (reassigned via `global {nm}`): its "
                             "trace-time value is baked into the cached "
                             "executable — pass it as a static argument "
                             "instead")))
        elif isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Load) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self":
            if node.attr in cls_attrs and ("a", node.attr) not in seen:
                seen.add(("a", node.attr))
                out.append(Finding(
                    code=CODE, path=f.path, line=node.lineno, symbol=qual,
                    message=(f"jitted method reads 'self.{node.attr}', "
                             "which is stored outside __init__ (per-step "
                             "mutable state): the closure bakes its "
                             "trace-time value into the cached trace — "
                             "thread it through the arguments")))


def run(files: List[FileCtx]) -> List[Finding]:
    out: List[Finding] = []
    for f in files:
        mg = _mutable_globals(f.tree)
        ma = _mutable_attrs_by_class(f.tree)
        impl_names = _jitted_impl_names(f.tree)
        for qual, fn, cls in iter_scopes(f.tree):
            if _jit_decorated(fn) or fn.name in impl_names:
                _check_jitted_body(f, qual, fn, cls, mg, ma, out)

        # full-pool gathers in kernel-hot-path modules
        if "kernels/" in f.path and not f.path.endswith("/ref.py"):
            from repro.analysis.core import enclosing_index, scope_of
            index = enclosing_index(f.tree)
            for node in ast.walk(f.tree):
                if isinstance(node, ast.Call) and \
                        dotted_name(node.func) in _GATHER_FUNCS:
                    out.append(Finding(
                        code=CODE, path=f.path, line=node.lineno,
                        symbol=scope_of(index, node.lineno),
                        message=("jnp.take full-pool gather inside a "
                                 "kernel hot-path module: materialises "
                                 "the whole pool per step (the pattern "
                                 "PR 4's paged kernels removed); use the "
                                 "scalar-prefetch index_map path")))
    return out
