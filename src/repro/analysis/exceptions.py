"""COOPT006 — no swallowed exceptions on serving fault paths.

Lineage: the resilience layer's whole contract is that faults PROPAGATE —
a step exception drains the pipeline as ERROR, an emit-worker fault is
posted to the loop, a stall raises ``PipelineStallError``. One
``except: pass`` in a serving loop or worker turns any of those into a
silent hang: the stream never closes, the client blocks forever, and the
chaos suite's "every stream terminates with the correct FinishReason"
guarantee dies. (The canonical near-miss: a blanket handler around the
emit worker's host sync that drops the exception instead of posting it —
the watchdog then reports a stall instead of the real fault.)

Contract enforced: inside ``serving/`` modules, a BLANKET handler — bare
``except:``, ``except Exception``, or ``except BaseException`` — must
either re-raise or USE the exception it bound (pass it somewhere, attach
it, post it); binding nothing, or binding ``as exc`` and never reading
it, is a finding. Narrow handlers (``queue.Empty``, ``OutOfBlocks``, ...)
are policy, not swallowing, and pass untouched. A deliberate blanket
swallow needs an inline ``# coopt: allow[COOPT006]`` rationale.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import (FileCtx, Finding, dotted_name,
                                 enclosing_index, scope_of)

CODE = "COOPT006"

# modules under the fault-propagation contract (matched by path segment)
CHECKED_SEGMENT = "serving/"

_BLANKET = {"Exception", "BaseException"}


def _is_checked(path: str) -> bool:
    return CHECKED_SEGMENT in path


def _blanket_kind(handler: ast.ExceptHandler):
    """'' for bare except, the type name for Exception/BaseException (also
    inside a tuple), None for narrow handlers."""
    t = handler.type
    if t is None:
        return ""
    types = t.elts if isinstance(t, ast.Tuple) else [t]
    for node in types:
        name = dotted_name(node)
        if name in _BLANKET:
            return name
    return None


def _handler_propagates(handler: ast.ExceptHandler) -> bool:
    """True if the handler re-raises or reads the exception it bound."""
    for stmt in handler.body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Raise):
                return True
            if (handler.name is not None and isinstance(node, ast.Name)
                    and node.id == handler.name
                    and isinstance(node.ctx, ast.Load)):
                return True
    return False


def run(files: List[FileCtx]) -> List[Finding]:
    out: List[Finding] = []
    for f in files:
        if not _is_checked(f.path):
            continue
        index = enclosing_index(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            kind = _blanket_kind(node)
            if kind is None or _handler_propagates(node):
                continue
            what = "bare except:" if kind == "" else f"except {kind}"
            scope = scope_of(index, node.lineno)
            out.append(Finding(
                code=CODE, path=f.path, line=node.lineno, symbol=scope,
                message=(f"{what} swallows exceptions on a serving fault "
                         f"path (scope {scope or '<module>'}): re-raise "
                         "or use the bound exception — faults must "
                         "propagate or be recorded, never vanish")))
    return out
