"""End-to-end training driver: a ~100M-param qwen3-family model for a few
hundred steps on the synthetic corpus (CPU). Demonstrates the training
substrate (AdamW, MoE aux losses, checkpointing) the dry-run lowers at
production scale.

  PYTHONPATH=src python examples/train_small.py [--steps 200] [--moe]
"""
import argparse

from repro.configs import get_config
from repro.data import TrainPipeline
from repro.training import Trainer
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--moe", action="store_true",
                    help="train the MoE (mixtral-family) variant instead")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    arch = "mixtral-8x22b-reduced" if args.moe else "qwen3-4b-reduced"
    # ~100M-param variant: widen the reduced config
    cfg = get_config(arch).replace(d_model=512, d_ff=1408, num_layers=4,
                                   num_heads=8, num_kv_heads=4,
                                   vocab_size=8192)
    tr = Trainer(cfg, lr=1e-3)
    n = tr.model.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

    pipe = TrainPipeline(cfg.vocab_size, batch=8, seq_len=128, seed=0)
    hist = tr.fit(pipe, steps=args.steps, log_every=10)
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"

    if args.ckpt:
        save_checkpoint(args.ckpt, tr.params, step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
