"""Long-context decode with the Opt-KV SkipSet as block sparsity
(DESIGN.md §5 long_500k policy): only {sink pages + sliding-window pages}
are gathered per step — the paper's Eq. 5/Eq. 9 machinery used as a
sparsity mechanism (streaming-LLM style).

Also runs the attention-free RWKV-6 path (O(1) state) for contrast.

  PYTHONPATH=src python examples/long_context_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.coopt import COOPT, MODES
from repro.models import get_model


def dense_block_sparse():
    cfg = get_config("qwen3-4b-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, CTX = 1, 2048                       # stand-in for 500k on CPU
    coopt = COOPT
    cache = m.init_cache(B, CTX + 64, coopt)

    # fill a long context via chunked prefill (Sarathi-style continuation:
    # absolute positions + cross-chunk attention over the paged cache)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, CTX), 0,
                              cfg.vocab_size)
    step = jax.jit(lambda p, b, c: m.prefill(p, b, c, coopt))
    for i in range(0, CTX, 512):
        pos = jnp.broadcast_to(jnp.arange(i, i + 512),
                               (B, 512)).astype(jnp.int32)
        logits, cache = step(p, {"tokens": toks[:, i:i + 512],
                                 "positions": pos, "slot_idx": pos}, cache)
    print(f"prefilled {int(cache['length'][0])} tokens")

    dec_full = jax.jit(lambda p, b, c: m.decode_step(p, b, c, coopt))
    dec_win = jax.jit(lambda p, b, c: m.decode_step(p, b, c, coopt,
                                                    long_window=256))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for name, fn in [("full-attention decode", dec_full),
                     ("block-sparse decode (window 256 + sink)", dec_win)]:
        c = jax.tree.map(lambda x: x, cache)
        lg, c = fn(p, {"token": tok}, c)    # compile
        t0 = time.perf_counter()
        for _ in range(8):
            lg, c = fn(p, {"token": jnp.argmax(lg, -1)[:, None]
                           .astype(jnp.int32)}, c)
        lg.block_until_ready()
        dt = (time.perf_counter() - t0) / 8 * 1e3
        print(f"{name:42s} {dt:7.1f} ms/token")


def rwkv_constant_state():
    cfg = get_config("rwkv6-7b-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B = 1
    cache = m.init_cache(B, 0, COOPT)      # O(1) state: no pages at all
    dec = jax.jit(lambda p, b, c: m.decode_step(p, b, c, COOPT))
    tok = jnp.zeros((B, 1), jnp.int32)
    lg, cache = dec(p, {"token": tok}, cache)
    t0 = time.perf_counter()
    for _ in range(16):
        lg, cache = dec(p, {"token": jnp.argmax(lg, -1)[:, None]
                            .astype(jnp.int32)}, cache)
    lg.block_until_ready()
    dt = (time.perf_counter() - t0) / 16 * 1e3
    bytes_state = sum(np.prod(v.shape) * v.dtype.itemsize
                      for v in jax.tree.leaves(cache))
    print(f"rwkv6 O(1)-state decode                    {dt:7.1f} ms/token "
          f"(state = {bytes_state/1024:.0f} KiB regardless of context)")


if __name__ == "__main__":
    dense_block_sparse()
    rwkv_constant_state()
