"""Quickstart: build an LLM-CoOpt engine, serve a few requests, and compare
the paper's five technique modes on the same prompts.

  PYTHONPATH=src python examples/quickstart.py
"""
import copy

import numpy as np

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.data import sharegpt_stream
from repro.serving import Engine, EngineConfig

ARCH = "qwen3-4b-reduced"          # any of the 10 assigned archs (+-reduced)


def main():
    cfg = get_config(ARCH)
    print(f"model: {cfg.name}  ({cfg.num_layers}L, d={cfg.d_model}, "
          f"H={cfg.num_heads}/kv{cfg.num_kv_heads})")

    ecfg = EngineConfig(num_lanes=2, max_len=192,
                        prefill_buckets=(16, 32, 64))
    requests = sharegpt_stream(cfg.vocab_size, 3, seed=0, scale=0.05)
    for r in requests:
        r.max_new_tokens = 8

    outputs = {}
    for mode, coopt in MODES.items():
        engine = Engine(cfg, coopt, ecfg)
        rs = [copy.deepcopy(r) for r in requests]
        for r in rs:
            engine.add_request(r)
        engine.run()
        outputs[mode] = [r.output for r in rs]
        print(f"{mode:9s}  throughput={engine.stats.throughput():7.1f} tok/s"
              f"  first outputs: {rs[0].output}")

    same = outputs["original"] == outputs["opt-gqa"] == outputs["opt-pa"]
    print(f"\nopt-gqa / opt-pa greedy-identical to original: {same}")
    print("opt-kv / coopt differ only by fp8 cache rounding "
          "(paper Tables 1-2: accuracy preserved)")


if __name__ == "__main__":
    main()
