"""End-to-end serving driver (deliverable b): a ShareGPT-mix workload through
the continuous-batching engine with the full LLM-CoOpt stack, reporting the
paper's Eq. 11/12 metrics and the block-manager fragmentation the paper's
Fig. 3 discusses.

  PYTHONPATH=src python examples/serve_continuous_batching.py \
      [--arch internvl2-2b] [--mode coopt] [--requests 12]
"""
import argparse
import time

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.data import RequestStream
from repro.serving import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--mode", default="coopt", choices=list(MODES))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-reduced")
    ecfg = EngineConfig(num_lanes=args.lanes, max_len=256,
                        prefill_buckets=(16, 32, 64, 128))
    engine = Engine(cfg, MODES[args.mode], ecfg)
    stream = RequestStream(cfg.vocab_size, seed=0, scale=0.1)

    pending = stream.take(args.requests, max_new_tokens=16)
    t0 = time.perf_counter()
    step = 0
    while pending or engine.scheduler.has_work:
        # Poisson-ish arrivals: feed 1 request every 2 engine steps
        if pending and step % 2 == 0:
            engine.add_request(pending.pop(0))
        engine.step()
        step += 1
        if step % 20 == 0:
            frag = engine.scheduler.manager.fragmentation()
            print(f"  step {step:4d}  running={len(engine.scheduler.running)}"
                  f"  waiting={len(engine.scheduler.waiting)}"
                  f"  pool fragmentation={frag:.2f}")
    wall = time.perf_counter() - t0

    s = engine.stats
    print(f"\narch={cfg.name} mode={args.mode}")
    print(f"requests served : {args.requests}")
    print(f"tokens generated: {s.generated_tokens}")
    print(f"latency  (Eq.11): {wall:.2f}s "
          f"(prefill {s.prefill_time:.2f}s, decode {s.decode_time:.2f}s)")
    print(f"throughput(Eq.12): {s.generated_tokens / wall:.1f} tok/s")
    lat = s.latency_summary()
    print(f"TTFT p50/p95    : {lat['ttft_p50_s']:.3f}s / "
          f"{lat['ttft_p95_s']:.3f}s")
    print(f"TPOT p50/p95    : {lat['tpot_p50_s']:.3f}s / "
          f"{lat['tpot_p95_s']:.3f}s")


if __name__ == "__main__":
    main()
