"""Cross-lane shared-prefix visit batching (kernels.visits) and
tile-resident chunk streaming.

Covers the visit planner's dedup/ownership/ordering contract, parity of the
visit-grid decode kernels vs the jnp oracles over {fp8, bf16} x {dense,
windowed} with 2 and 8 sharing lanes, BIT-identity of the visit grid vs the
per-lane grid (with and without sharing present — the visit grid processes
each lane's pages in the same ascending-slot order, so even the
floating-point reduction order is unchanged), multi-resident-block chunk
parity (block_q forcing NQ > 1 must not change results), and engine-level
greedy identity with ``share_visits`` on vs off plus the sharing
observability counters."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.quant import quantize_fp8, quantize_latent
from repro.configs import get_config
from repro.core.coopt import MODES
from repro.core.opt_kv import identity_page_table
from repro.kernels import ops, ref
from repro.kernels.visits import (MAX_VISIT_LANES, plan_visits,
                                  sharing_stats)
from repro.serving import Engine, EngineConfig


def _shared_tables(B, P, shared):
    """Physical pages 0..shared-1 common to all lanes, tails private."""
    phys = np.zeros((B, P), np.int32)
    for b in range(B):
        for i in range(P):
            phys[b, i] = i if i < shared else \
                shared + b * (P - shared) + (i - shared)
    log = np.ascontiguousarray(
        np.broadcast_to(np.arange(P, dtype=np.int32)[None], (B, P)))
    total = shared + B * (P - shared)
    return jnp.asarray(phys), jnp.asarray(log), total


def _gqa_inputs(B, P, shared, ps, Hkv, G, D, opt_kv, seed=0):
    phys, log, PT = _shared_tables(B, P, shared)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hkv * G, D)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (PT, ps, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (PT, ps, Hkv, D), jnp.float32)
    if opt_kv:
        kq, ksc = quantize_fp8(k)
        vq, vsc = quantize_fp8(v)
        return q, jnp.stack([kq, vq]), jnp.stack([ksc, vsc]), phys, log
    return q, jnp.stack([k, v]).astype(jnp.bfloat16), None, phys, log


# ------------------------------------------------------------ plan_visits --
def test_plan_visits_dedups_shared_pages():
    phys = jnp.asarray([[0, 3], [0, 4], [0, 5]], jnp.int32)
    log = jnp.asarray([[0, 1]] * 3, jnp.int32)
    vp, vm, vl = (np.asarray(x) for x in plan_visits(phys, log))
    B = 3
    # s-major flatten: visit v = s*B + b. Slot 0: page 0 owned by lane 0
    # with all three lanes' bits; lanes 1/2 emit dead visits.
    assert vp[0] == 0 and vm[0] == 0b111 and vl[0] == 0
    assert vp[1] == -1 and vm[1] == 0 and vp[2] == -1
    # slot 1: three private pages, each its own visit with its own bit
    assert list(vp[3:]) == [3, 4, 5]
    assert list(vm[3:]) == [0b001, 0b010, 0b100]
    assert list(vl[3:]) == [1, 1, 1]


def test_plan_visits_skips_holes_and_keys_on_logical_id():
    # a -1 (never-DMA'd) entry is dead; equal physical page under DIFFERENT
    # logical ids (window remap) must NOT be merged
    phys = jnp.asarray([[7, -1], [7, 9]], jnp.int32)
    log = jnp.asarray([[2, 3], [5, 3]], jnp.int32)
    vp, vm, vl = (np.asarray(x) for x in plan_visits(phys, log))
    # slot 0: same phys page 7 but logical 2 vs 5 -> two separate visits
    assert list(vp[:2]) == [7, 7]
    assert list(vm[:2]) == [0b01, 0b10]
    assert list(vl[:2]) == [2, 5]
    # slot 1: lane 0's hole emits nothing; lane 1's page stands alone
    assert vp[2] == -1 and vm[2] == 0
    assert vp[3] == 9 and vm[3] == 0b10 and vl[3] == 3


def test_plan_visits_per_lane_slot_order_preserved():
    """Each lane's member visits appear in ascending slot order in the
    flattened list — the property that makes the visit grid's reduction
    order (hence floating point) identical to the per-lane grid."""
    B, P, shared = 4, 6, 3
    phys, log, _ = _shared_tables(B, P, shared)
    vp, vm, _ = (np.asarray(x) for x in plan_visits(phys, log))
    for lane in range(B):
        member = (vp >= 0) & ((vm >> lane) & 1 == 1)
        slots = np.nonzero(member)[0] // B     # visit v = s*B + b
        assert list(slots) == sorted(slots)
        assert len(slots) == P                 # every slot visited once


def test_sharing_stats_counts_dup_streams():
    phys, _, _ = _shared_tables(4, 6, 3)
    st = sharing_stats(np.asarray(phys))
    assert st["shared_page_visits"] == 3           # 3 shared slots
    assert st["dup_page_streams_saved"] == 3 * 3   # (4-1) lanes x 3 pages
    assert st["lanes_per_shared_page"] == {4: 3}


# ------------------------------------------------- GQA decode visit grid --
@pytest.mark.parametrize("opt_kv", [False, True])
@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("lanes", [2, 8])
def test_gqa_visit_parity_vs_oracle(opt_kv, window, lanes):
    B, P, shared, ps, Hkv, G, D = lanes, 6, 4, 16, 2, 4, 64
    q, kv, sc, phys, log = _gqa_inputs(B, P, shared, ps, Hkv, G, D, opt_kv)
    # varied lengths across the sharing lanes: the positional mask is
    # per-member inside one shared visit
    cl = jnp.asarray(P * ps - 5 * np.arange(B), jnp.int32)
    out = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=opt_kv,
                                opt_gqa=True, window=window,
                                share_visits=True)
    ks, vs = (sc[0], sc[1]) if sc is not None else (None, None)
    exp = ref.paged_pool_decode_ref(q, kv[0], kv[1], ks, vs, cl, phys, log,
                                    opt_kv=opt_kv, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


@pytest.mark.parametrize("shared", [0, 4])
def test_gqa_visit_grid_bit_identical_to_per_lane(shared):
    """share_visits on vs off: bitwise-equal outputs, both with NO sharing
    (pure degenerate case) and WITH sharing (ascending-slot visit order
    reproduces the per-lane reduction order exactly)."""
    B, P, ps, Hkv, G, D = 4, 6, 16, 2, 4, 64
    q, kv, sc, phys, log = _gqa_inputs(B, P, shared, ps, Hkv, G, D,
                                       opt_kv=True)
    cl = jnp.asarray(P * ps - 7 * np.arange(B), jnp.int32)
    off = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                opt_gqa=True, share_visits=False)
    on = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                               opt_gqa=True, share_visits=True)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))


def test_visit_dispatch_gate():
    """B = 1 and B > MAX_VISIT_LANES stay on the per-lane grid (the int32
    lane bitmask bounds the visit grid) — outputs must still match."""
    for B in (1, MAX_VISIT_LANES + 1):
        P, ps, Hkv, G, D = 2, 8, 1, 2, 64
        q, kv, sc, phys, log = _gqa_inputs(B, P, 0, ps, Hkv, G, D,
                                           opt_kv=True, seed=2)
        cl = jnp.full((B,), P * ps, jnp.int32)
        off = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                    opt_gqa=True, share_visits=False)
        on = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                   opt_gqa=True, share_visits=True)
        np.testing.assert_array_equal(np.asarray(off), np.asarray(on))


# -------------------------------------------------- latent (MLA) visits --
@pytest.mark.parametrize("opt_kv", [False, True])
@pytest.mark.parametrize("window", [0, 48])
def test_latent_visit_parity_vs_oracle(opt_kv, window):
    B, P, shared, ps, H, R, dr = 8, 6, 4, 16, 8, 64, 32
    phys, log, PT = _shared_tables(B, P, shared)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    ql = jax.random.normal(ks[0], (B, H, R)).astype(jnp.bfloat16)
    qr = jax.random.normal(ks[1], (B, H, dr)).astype(jnp.bfloat16)
    latf = jax.random.normal(ks[2], (PT, ps, R + dr), jnp.float32)
    if opt_kv:
        lat, sc = quantize_latent(latf, R)
    else:
        lat, sc = latf.astype(jnp.bfloat16), None
    cl = jnp.asarray(P * ps - 5 * np.arange(B), jnp.int32)
    sm = (R + dr) ** -0.5
    out = ops.paged_latent_decode(ql, qr, lat, sc, cl, phys, log,
                                  sm_scale=sm, opt_kv=opt_kv, window=window,
                                  share_visits=True)
    exp = ref.paged_latent_decode_ref(ql, qr, lat, sc, cl, phys, log,
                                      sm_scale=sm, opt_kv=opt_kv,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


@pytest.mark.parametrize("shared", [0, 4])
def test_latent_visit_grid_bit_identical_to_per_lane(shared):
    B, P, ps, H, R, dr = 4, 6, 16, 8, 64, 32
    phys, log, PT = _shared_tables(B, P, shared)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    ql = jax.random.normal(ks[0], (B, H, R)).astype(jnp.bfloat16)
    qr = jax.random.normal(ks[1], (B, H, dr)).astype(jnp.bfloat16)
    lat, sc = quantize_latent(
        jax.random.normal(ks[2], (PT, ps, R + dr), jnp.float32), R)
    cl = jnp.asarray(P * ps - 7 * np.arange(B), jnp.int32)
    sm = (R + dr) ** -0.5
    off = ops.paged_latent_decode(ql, qr, lat, sc, cl, phys, log,
                                  sm_scale=sm, opt_kv=True,
                                  share_visits=False)
    on = ops.paged_latent_decode(ql, qr, lat, sc, cl, phys, log,
                                 sm_scale=sm, opt_kv=True,
                                 share_visits=True)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))


# ------------------------------------- tile-resident chunk streaming -----
def test_chunk_prefill_multi_resident_block_parity():
    """Forcing several resident row-blocks per chunk (NQ > 1) must match
    both the single-resident-block run and the jnp oracle — the restructure
    changed the streaming schedule, not the math."""
    from repro.core.coopt import CoOptConfig
    from repro.core.opt_pa import paged_chunk_attention
    from repro.kernels.flash_chunk_prefill import (flash_chunk_prefill,
                                                   resident_rows)

    B, P, ps, Hkv, G, D, S = 2, 4, 16, 2, 4, 64, 8
    q = jax.random.normal(jax.random.PRNGKey(7),
                          (B, S, Hkv * G, D)).astype(jnp.bfloat16)
    phys = identity_page_table(B, B * P)
    k = jax.random.normal(jax.random.PRNGKey(8), (B * P, ps, Hkv, D),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(9), (B * P, ps, Hkv, D),
                          jnp.float32)
    kq, ksc = quantize_fp8(k)
    vq, vsc = quantize_fp8(v)
    positions = jnp.stack([jnp.arange(24, 32),
                           jnp.arange(56, 64)]).astype(jnp.int32)
    R = S * G
    assert resident_rows(R, G, G) == G and R // G > 1   # forces NQ > 1
    tiled = flash_chunk_prefill(q, positions, kq, vq, ksc, vsc, phys,
                                opt_kv=True, block_q=G)
    whole = flash_chunk_prefill(q, positions, kq, vq, ksc, vsc, phys,
                                opt_kv=True)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(whole))
    exp = paged_chunk_attention(
        q, jnp.stack([kq, vq]), jnp.stack([ksc, vsc]), positions, phys,
        CoOptConfig(opt_kv=True, opt_gqa=True, opt_pa=True))
    np.testing.assert_allclose(np.asarray(whole, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


def test_latent_chunk_multi_resident_block_parity():
    from repro.kernels.latent_chunk_prefill import (latent_chunk_prefill,
                                                    resident_rows)

    B, P, ps, H, R, dr, S = 2, 4, 16, 8, 64, 32, 4
    phys = identity_page_table(B, B * P)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    ql = jax.random.normal(ks[0], (B, S, H, R)).astype(jnp.bfloat16)
    qr = jax.random.normal(ks[1], (B, S, H, dr)).astype(jnp.bfloat16)
    lat, sc = quantize_latent(
        jax.random.normal(ks[2], (B * P, ps, R + dr), jnp.float32), R)
    positions = jnp.stack([jnp.arange(24, 28),
                           jnp.arange(60, 64)]).astype(jnp.int32)
    sm = (R + dr) ** -0.5
    RW = S * H
    assert resident_rows(RW, H, H) == H and RW // H > 1   # forces NQ > 1
    tiled = latent_chunk_prefill(ql, qr, positions, lat, sc, phys,
                                 sm_scale=sm, opt_kv=True, block_q=H)
    whole = latent_chunk_prefill(ql, qr, positions, lat, sc, phys,
                                 sm_scale=sm, opt_kv=True)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(whole))
    exp = ref.latent_chunk_prefill_ref(ql, qr, positions, lat, sc, phys,
                                       sm_scale=sm, opt_kv=True)
    np.testing.assert_allclose(np.asarray(whole, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


# --------------------------------------------- engine greedy identity ----
def test_engine_greedy_identical_and_sharing_observed():
    """Shared-prompt serving through the kernel path: greedy outputs are
    bit-identical with ``share_visits`` on vs off, and with it on the
    engine's sharing counters see the refcount-shared prefix pages."""
    cfg = get_config("qwen3-4b-reduced")
    ecfg = EngineConfig(num_lanes=2, max_len=192,
                        prefill_buckets=(16, 32, 64, 128))
    prompt = (np.arange(80, dtype=np.int32) * 7 + 11) % cfg.vocab_size

    def serve(share):
        from repro.serving import Request
        co = MODES["coopt"].replace(use_kernel=True, share_visits=share)
        eng = Engine(cfg, co, ecfg)
        warm = Request(req_id=0, prompt=prompt.copy(), max_new_tokens=2)
        eng.add_request(warm)
        eng.run()                 # commits the prompt's pages to the
        eng.stats.__init__()      # prefix cache, then reset counters
        rs = [Request(req_id=i + 1, prompt=prompt.copy(), max_new_tokens=5)
              for i in range(2)]
        for r in rs:
            eng.add_request(r)
        eng.run()
        return [r.output for r in rs], eng.stats

    out_on, stats_on = serve(True)
    out_off, _ = serve(False)
    assert out_on == out_off
    assert all(len(o) == 5 for o in out_on)
    # both lanes decoded off the same cached prompt pages -> the decode
    # steps' page tables carried genuinely shared pages
    assert stats_on.shared_page_visits > 0
    assert stats_on.dup_page_streams_saved > 0
    assert 2 in stats_on.lanes_per_shared_page
    assert ("shared_page_visits"
            in stats_on.latency_summary())


def test_block_manager_shared_page_accessors():
    from repro.cache.block_manager import BlockManager
    m = BlockManager(num_pages=8, page_size=4)
    toks = list(range(12))                       # three full pages
    pages1, _ = m.allocate(1, len(toks), token_ids=toks)
    m.commit_prefill(1, len(toks), token_ids=toks)
    pages2, cached = m.allocate(2, len(toks), token_ids=toks)
    # leading full pages hit; the final page stays writable (unshared)
    assert cached > 0 and cached % m.page_size == 0
    shared = m.shared_page_counts()
    n_shared = cached // m.page_size
    assert set(shared) == set(pages1[:n_shared]) == set(pages2[:n_shared])
    assert all(r == 2 for r in shared.values())
    assert m.sharing_histogram() == {2: n_shared}
    m.free(2)
    assert m.shared_page_counts() == {} and m.sharing_histogram() == {}
