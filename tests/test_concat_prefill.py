"""Concat-prefill packing: packed-vs-unpacked parity (jnp AND Pallas
kernel paths), the segment-id mask regression (two prompts sharing one
packed row), and ``pack_rows`` invariants (constraints respected, a
request never splits across rows or shards).

All generation runs greedy (temperature 0): a segment-mask leak would
perturb a neighbour prompt's logits and show up as a token difference.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.kernels import ops
from repro.serving import Engine, EngineConfig, Request
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import (PackedRow, PrefillChunk, chunk_pages,
                                     pack_rows)

CFG = get_config("qwen3-4b-reduced")
ops.configure_for_backend()


def _engine(pack, use_kernel=False, num_lanes=4, seed=0):
    ecfg = EngineConfig(num_lanes=num_lanes, max_len=128,
                        prefill_buckets=(32, 64, 128),
                        sampling=SamplingParams(temperature=0.0),
                        seed=seed, pack_prefill=pack)
    return Engine(CFG, MODES["coopt"].replace(use_kernel=use_kernel), ecfg)


def _prompts(n, rng, lo=4, hi=24):
    return [rng.integers(0, CFG.vocab_size, int(rng.integers(lo, hi)),
                         dtype=np.int32) for _ in range(n)]


def _first_token_logits(pack, prompts, use_kernel=False):
    """Admit ``prompts``, build ONE step, run its impl directly and return
    {req_id: last-token logits} plus the StepBatch (to inspect layout)."""
    eng = _engine(pack, use_kernel=use_kernel)
    for i, p in enumerate(prompts):
        eng.add_request(Request(req_id=i, prompt=np.asarray(p, np.int32),
                                max_new_tokens=1))
    plan = eng.scheduler.schedule_step()
    sb = eng._build_step(plan)
    fn = eng._packed_fn if sb.kind == "packed" else eng._prefill_fn
    logits, _ = fn(eng.params, sb.batch, eng.cache,
                   eng._dev_const(sb.lane_mask))
    logits = np.asarray(logits)
    return {req.req_id: logits[idx] for req, _, idx in sb.samples}, sb


# ----------------------------------------------------- logit parity ------
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "kernel"])
def test_two_prompts_one_row_logit_parity(use_kernel):
    """THE segment-mask regression: two short prompts packed into ONE row
    produce (near-)identical first-token logits to each prompt prefilled
    in its own lane — any attention leak across the shared row would
    perturb them. Kernel and jnp paths each compared within themselves."""
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, CFG.vocab_size, 9, dtype=np.int32),
               rng.integers(0, CFG.vocab_size, 6, dtype=np.int32)]
    packed, sb = _first_token_logits(True, prompts, use_kernel=use_kernel)
    unpacked, _ = _first_token_logits(False, prompts, use_kernel=use_kernel)

    assert sb.kind == "packed"
    # both prompts really share row 0 (segment ids 0 and 1 both present)
    segs = set(np.asarray(sb.batch["seg_q"])[0]) - {-1}
    assert segs == {0, 1}
    for rid in (0, 1):
        assert np.argmax(packed[rid]) == np.argmax(unpacked[rid])
        np.testing.assert_allclose(packed[rid], unpacked[rid],
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "kernel"])
def test_packed_vs_unpacked_greedy_identity(use_kernel):
    """End-to-end: packing ON vs OFF serves identical greedy tokens, and
    the packed run really packed (rows saved > 0)."""
    rng = np.random.default_rng(23)
    prompts = _prompts(6, rng)
    toks = 3 if use_kernel else 6           # interpret-mode kernels are slow

    ref = _engine(False, use_kernel=use_kernel).generate(
        prompts, max_new_tokens=toks)
    eng = _engine(True, use_kernel=use_kernel)
    got = eng.generate(prompts, max_new_tokens=toks)

    assert [list(o) for o in got] == [list(o) for o in ref]
    assert eng.stats.packed_steps > 0
    assert eng.stats.packed_rows_saved > 0


# ------------------------------------------------- pack_rows invariants --
def _mk_chunks(sizes, shards, page_size=16):
    chunks = []
    for i, (n, sh) in enumerate(zip(sizes, shards)):
        r = Request(req_id=i, prompt=np.zeros(n, np.int32),
                    max_new_tokens=1)
        r.shard = sh
        chunks.append(PrefillChunk(req=r, start=0,
                                   tokens=np.zeros(n, np.int32),
                                   final=True, first=True))
    return chunks


def test_pack_rows_respects_all_constraints():
    width, slots, ppl, ps = 32, 2, 4, 16
    chunks = _mk_chunks([20, 16, 8, 8, 4, 4], [0, 0, 0, 0, 0, 0], ps)
    rows = pack_rows(chunks, width, slots, ppl, ps)
    packed = [c for row in rows for c in row.chunks]
    # every chunk lands whole, exactly once (never split)
    assert sorted(c.req.req_id for c in packed) == list(range(len(chunks)))
    for row in rows:
        assert sum(c.n for c in row.chunks) == row.tokens <= width
        assert sum(chunk_pages(c, ps) for c in row.chunks) == row.pages <= ppl
        assert sum(int(c.final) for c in row.chunks) == row.finals <= slots
    # it actually packs: fewer rows than chunks
    assert len(rows) < len(chunks)


def test_pack_rows_never_mixes_shards():
    """A packed row gathers pages from ONE KV shard: chunks pinned to
    different shards must never share a row, however well they'd fit."""
    ps = 16
    chunks = _mk_chunks([4, 4, 4, 4], [0, 1, 0, 1], ps)
    rows = pack_rows(chunks, width=32, pack_slots=4, pages_per_lane=8,
                     page_size=ps)
    assert len(rows) == 2
    for row in rows:
        shards = {c.req.shard for c in row.chunks}
        assert shards == {row.shard}


def test_pack_rows_chunk_pages_cover_history():
    """A continuation chunk's page need covers the WHOLE cached history
    (it attends to everything), not just its own tokens."""
    r = Request(req_id=0, prompt=np.zeros(40, np.int32), max_new_tokens=1)
    r.shard = 0
    c = PrefillChunk(req=r, start=32, tokens=np.zeros(8, np.int32),
                     final=True)
    assert chunk_pages(c, 16) == -(-(32 + 8) // 16) == 3
    rows = pack_rows([c], width=32, pack_slots=4, pages_per_lane=2,
                     page_size=16)
    # needs 3 page slots but rows only have 2: it still lands (alone, in
    # its own fresh row) rather than being dropped or split
    assert len(rows) == 1 and rows[0].chunks == [c]


def test_scheduler_packing_never_splits_requests_across_shards():
    """Engine-level, two KV shards: every packed step's rows stay
    shard-pure while outputs still match the unpacked two-shard run."""
    rng = np.random.default_rng(31)
    prompts = _prompts(6, rng, lo=4, hi=16)

    ref = Engine(CFG, MODES["coopt"],
                 EngineConfig(num_lanes=4, max_len=128,
                              prefill_buckets=(32, 64, 128),
                              sampling=SamplingParams(temperature=0.0),
                              seed=0, num_shards=2)).generate(
        prompts, max_new_tokens=4)
    eng = Engine(CFG, MODES["coopt"],
                 EngineConfig(num_lanes=4, max_len=128,
                              prefill_buckets=(32, 64, 128),
                              sampling=SamplingParams(temperature=0.0),
                              seed=0, num_shards=2, pack_prefill=True))
    got = eng.generate(prompts, max_new_tokens=4)
    assert [list(o) for o in got] == [list(o) for o in ref]
    assert eng.stats.packed_steps > 0
