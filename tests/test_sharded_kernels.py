"""shard_map'd pooled Pallas kernels — ONE kernel hot path for single-host
AND distributed serving (PR 5 acceptance).

Covers: kernel-level parity of every sharded wrapper vs the jnp reference,
engine-level greedy identity (dense AND mla) with ``use_kernel`` under a
simulated multi-device mesh, the no-pool-all-gather HLO guarantee of the
sharded step, the EngineConfig.num_shards <-> mesh consistency bugfix, and
the regression that an UNSHARDED mesh takes the identical code path as no
mesh at all.

Mesh sizing is driven by the CI mesh matrix: ``REPRO_KV_SHARDS`` (default:
4 when >= 8 simulated devices are available, else 1) picks the pages-axis
extent; tests that need a sharded mesh skip when the environment cannot
form one (device_count 1/2 cells of the matrix).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import opt_kv, opt_pa
from repro.core.coopt import COOPT, MODES
from repro.kernels import ops
from repro.launch.mesh import kv_shard_count, make_host_mesh, make_sim_mesh
from repro.serving import Engine, EngineConfig

NDEV = len(jax.devices())
KV_SHARDS = int(os.environ.get("REPRO_KV_SHARDS", "0")) or \
    (4 if NDEV >= 8 else 1)
MODEL_PAR = 2 if NDEV >= 2 * KV_SHARDS else 1

needs_sharded_mesh = pytest.mark.skipif(
    KV_SHARDS < 2 or NDEV < KV_SHARDS * MODEL_PAR,
    reason=f"needs a sharded pages axis: REPRO_KV_SHARDS={KV_SHARDS} with "
           f"{NDEV} devices (CI mesh matrix provides both)")


@pytest.fixture
def mesh():
    return make_sim_mesh(data=KV_SHARDS, model=MODEL_PAR)


@pytest.fixture(autouse=True)
def _clear_ctx():
    yield
    ops.set_mesh_ctx(None)


def _sharded_pool(mesh, arr, pages_dim):
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = [None] * arr.ndim
    spec[pages_dim] = "data"
    return jax.device_put(arr, NamedSharding(mesh, P(*spec)))


# ----------------------------------------------------- unsharded == no mesh --
def test_unsharded_mesh_is_identical_code_path():
    """A mesh whose pages axes have extent 1 yields NO shard ctx — ops
    dispatch, engine ctx and outputs are identical to running meshless."""
    assert ops.make_mesh_ctx(None) is None
    assert ops.make_mesh_ctx(make_host_mesh()) is None
    assert ops.make_mesh_ctx(make_sim_mesh(data=1, model=1)) is None
    if NDEV >= 2:
        assert ops.make_mesh_ctx(make_sim_mesh(data=1, model=2)) is None

    cfg = get_config("qwen3-4b-reduced")
    prompts = [np.random.default_rng(0).integers(0, cfg.vocab_size, 40,
                                                 dtype=np.int32)]
    ecfg = EngineConfig(num_lanes=2, max_len=128,
                        prefill_buckets=(16, 32, 64))
    coopt = MODES["coopt"].replace(use_kernel=True)
    out_nomesh = Engine(cfg, coopt, ecfg).generate(prompts, max_new_tokens=4)
    eng = Engine(cfg, coopt, ecfg, mesh=make_host_mesh())
    assert eng._kernel_ctx is None
    assert eng.ecfg.num_shards == 1
    assert eng.generate(prompts, max_new_tokens=4) == out_nomesh


def test_configure_for_backend_composes_with_mesh_dispatch(monkeypatch):
    """``configure_for_backend()`` (the launchers' interpret-mode switch)
    and the mesh ctx dispatch compose: whatever INTERPRET resolves to is
    forwarded into the shard_map layer, and with no ctx the single-device
    wrapper runs instead — same flag, one dispatch point."""
    import jax as _jax
    from repro.kernels import sharded as _sh

    seen = {}
    monkeypatch.setattr(ops, "INTERPRET", ops.INTERPRET)  # restore on exit
    monkeypatch.setattr(
        ops._sh, "paged_pool_decode",
        lambda ctx, *a, **kw: seen.update(ctx=ctx, **kw) or "sharded")
    monkeypatch.setattr(
        ops, "_paged_pool_decode_single", lambda *a, **kw: "single")

    monkeypatch.setattr(_jax, "default_backend", lambda: "tpu")
    ops.configure_for_backend()
    assert ops.INTERPRET is False
    ctx = _sh.ShardCtx(mesh=None, axes=("data",), num_shards=2)  # dummy
    ops.set_mesh_ctx(ctx)
    args = (jnp.zeros((1, 2, 4)), jnp.zeros((2, 4, 2, 2, 4)), None,
            jnp.zeros(1, jnp.int32), jnp.zeros((1, 2), jnp.int32),
            jnp.zeros((1, 2), jnp.int32))
    assert ops.paged_pool_decode(*args, opt_kv=False, opt_gqa=True) \
        == "sharded"
    assert seen["ctx"] is ctx and seen["interpret"] is False

    monkeypatch.setattr(_jax, "default_backend", lambda: "cpu")
    ops.configure_for_backend()
    ops.set_mesh_ctx(None)
    assert ops.paged_pool_decode(*args, opt_kv=False, opt_gqa=True) \
        == "single"


# ------------------------------------------------- num_shards <-> mesh fix --
def test_engine_derives_num_shards_from_mesh_and_rejects_conflict():
    """Bugfix: a config built before the mesh can disagree with
    kv_shard_count — the engine derives the default and hard-rejects an
    inconsistent explicit value."""
    cfg = get_config("qwen3-4b-reduced")
    mesh1 = make_sim_mesh(data=1, model=1)
    assert kv_shard_count(mesh1) == 1
    eng = Engine(cfg, MODES["coopt"],
                 EngineConfig(num_lanes=2, max_len=128,
                              prefill_buckets=(16, 32, 64)), mesh=mesh1)
    assert eng.ecfg.num_shards == 1
    with pytest.raises(ValueError, match="disagrees"):
        Engine(cfg, MODES["coopt"],
               EngineConfig(num_lanes=2, max_len=128,
                            prefill_buckets=(16, 32, 64), num_shards=3),
               mesh=mesh1)


@needs_sharded_mesh
def test_engine_derives_num_shards_from_sharded_mesh(mesh):
    cfg = get_config("qwen3-4b-reduced")
    ecfg = EngineConfig(num_lanes=2, max_len=128,
                        prefill_buckets=(16, 32, 64))
    eng = Engine(cfg, MODES["coopt"], ecfg, mesh=mesh)
    assert eng.ecfg.num_shards == kv_shard_count(mesh) == KV_SHARDS
    # explicit matching value is accepted unchanged
    eng2 = Engine(cfg, MODES["coopt"],
                  EngineConfig(**{**ecfg.__dict__,
                                  "num_shards": KV_SHARDS}), mesh=mesh)
    assert eng2.ecfg.num_shards == KV_SHARDS


# ------------------------------------------------------ kernel-level parity --
@needs_sharded_mesh
@pytest.mark.parametrize("opt_kv_on", [False, True])
def test_sharded_decode_kernel_matches_jnp_reference(mesh, opt_kv_on):
    """The shard_map'd decode kernel (global table -> local holes, partial
    (m, l) lse-merged across the pages axis) matches the jnp gather
    reference on a pool whose pages are scattered across shards."""
    B, Hq, Hkv, D, ps, P_total = 2, 8, 4, 128, 8, 16
    coopt = COOPT.replace(opt_kv=opt_kv_on, use_kernel=False)
    kv = (jax.random.normal(jax.random.PRNGKey(1),
                            (2, P_total, ps, Hkv, D), jnp.float32) * 0.3)
    scale = None
    if opt_kv_on:
        from repro.cache.quant import quantize_fp8
        kv, scale = quantize_fp8(kv, axis=-1)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Hq, D), jnp.float32)
    cache_len = jnp.array([37, 90], jnp.int32)
    pt = opt_kv.identity_page_table(B, P_total)
    ref = opt_pa.paged_decode_attention(q, kv, scale, cache_len,
                                        coopt=coopt, page_table=pt)

    phys, log = opt_kv.decode_page_select(cache_len, pt, ps, opt_pa=True)
    kv_sh = _sharded_pool(mesh, kv, 1)
    sc_sh = _sharded_pool(mesh, scale, 1) if scale is not None else None
    ops.set_mesh_ctx(ops.make_mesh_ctx(mesh))
    out = ops.paged_pool_decode(q, kv_sh, sc_sh, cache_len, phys, log,
                                opt_kv=opt_kv_on, opt_gqa=True)
    tol = 0.05 if opt_kv_on else 5e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@needs_sharded_mesh
def test_sharded_visit_grid_shard_local_and_matches_reference(mesh):
    """``share_visits`` under shard_map: every shard plans its visit list
    AFTER the global->local page remap, so visits reference only
    shard-local page ids and shared prefix pages dedup inside the one
    shard that owns them (pages in other shards become -1 holes there).
    The table here shares prefix pages living in DIFFERENT shards and
    must match both the jnp reference and the per-lane sharded grid
    bit-for-bit."""
    B, Hq, Hkv, D, ps, P_total, NP = 4, 8, 4, 128, 8, 16, 4
    from repro.cache.quant import quantize_fp8
    coopt = COOPT.replace(opt_kv=True, use_kernel=False)
    kv = (jax.random.normal(jax.random.PRNGKey(1),
                            (2, P_total, ps, Hkv, D), jnp.float32) * 0.3)
    kv, scale = quantize_fp8(kv, axis=-1)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Hq, D), jnp.float32)
    # prefix pages 0 and 9 shared by ALL lanes (they land in different
    # shards under the page-range partition); two private tail pages each
    pt = jnp.asarray([[0, 9, 2 + b, 12 + b] for b in range(B)], jnp.int32)
    cache_len = jnp.asarray([NP * ps - 3 * b for b in range(B)], jnp.int32)
    ref = opt_pa.paged_decode_attention(q, kv, scale, cache_len,
                                        coopt=coopt, page_table=pt)

    phys, log = opt_kv.decode_page_select(cache_len, pt, ps, opt_pa=True)
    kv_sh = _sharded_pool(mesh, kv, 1)
    sc_sh = _sharded_pool(mesh, scale, 1)
    ops.set_mesh_ctx(ops.make_mesh_ctx(mesh))
    on = ops.paged_pool_decode(q, kv_sh, sc_sh, cache_len, phys, log,
                               opt_kv=True, opt_gqa=True, share_visits=True)
    off = ops.paged_pool_decode(q, kv_sh, sc_sh, cache_len, phys, log,
                                opt_kv=True, opt_gqa=True,
                                share_visits=False)
    # near-exact vs the per-lane grid: the visit grid batches all lanes'
    # rows into one (B*G, ps) score dot where the per-lane grid runs
    # (G, ps) dots, and the backend's matmul blocking may round a ULP
    # apart at different M — tolerance covers exactly that, nothing more
    np.testing.assert_allclose(np.asarray(on, np.float32),
                               np.asarray(off, np.float32), atol=1e-6)
    np.testing.assert_allclose(np.asarray(on, np.float32),
                               np.asarray(ref, np.float32), atol=0.05)


@needs_sharded_mesh
def test_sharded_chunk_kernel_matches_jnp_reference(mesh):
    B, S, Hq, Hkv, D, ps, P_total = 2, 4, 8, 4, 128, 8, 16
    coopt = COOPT.replace(opt_kv=False, use_kernel=False)
    kv = (jax.random.normal(jax.random.PRNGKey(1),
                            (2, P_total, ps, Hkv, D), jnp.float32) * 0.3)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, S, Hq, D), jnp.float32)
    positions = jnp.stack([jnp.arange(33, 37),
                           jnp.arange(86, 90)]).astype(jnp.int32)
    pt = opt_kv.identity_page_table(B, P_total)
    ref = opt_pa.paged_chunk_attention(q, kv, None, positions, pt, coopt)

    ops.set_mesh_ctx(ops.make_mesh_ctx(mesh))
    out = ops.paged_chunk_prefill(q, positions, _sharded_pool(mesh, kv, 1),
                                  None, pt, opt_kv=False, opt_gqa=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-3)


@needs_sharded_mesh
def test_sharded_write_stays_shard_local_and_drops_foreign_slots(mesh):
    """The shard-local write scatters exactly the intended lines: no
    sentinel-line aliasing on mid-pool shards (a foreign/-1 slot is OOB-
    dropped, never wrapped), matching the global jnp write bit-for-bit."""
    B, Hkv, D, ps, P_total = 2, 4, 16, 8, 16
    kv = (jax.random.normal(jax.random.PRNGKey(1),
                            (2, P_total, ps, Hkv, D), jnp.float32))
    k_new = jnp.full((B, 1, Hkv, D), 7.0)
    v_new = jnp.full((B, 1, Hkv, D), 9.0)
    # one mid-pool slot + one SkipSet (-1) token
    slots = jnp.array([[37], [-1]], jnp.int32)
    ref, _ = opt_kv.write_kv(kv, None, k_new, v_new, slots,
                             COOPT.replace(opt_kv=False, use_kernel=False))
    ops.set_mesh_ctx(ops.make_mesh_ctx(mesh))
    out, _ = ops.kv_cache_write(_sharded_pool(mesh, kv, 1), None,
                                k_new, v_new, slots, opt_kv=False)
    # every LIVE line matches the global jnp write bit-for-bit; the global
    # jnp write parks the -1 token in the reserved sentinel (last) line,
    # the shard-local write simply DROPS it — assert the sentinel is the
    # only divergence and that no mid-shard line absorbed the skip
    o = np.asarray(out).reshape(2, P_total * ps, Hkv, D)
    r = np.asarray(ref).reshape(2, P_total * ps, Hkv, D)
    np.testing.assert_array_equal(o[:, :-1], r[:, :-1])
    np.testing.assert_array_equal(
        o[:, -1], np.asarray(kv).reshape(2, P_total * ps, Hkv, D)[:, -1])


# ---------------------------------------------------- engine greedy parity --
@needs_sharded_mesh
@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite-16b"])
def test_engine_kernel_greedy_identical_on_mesh(mesh, arch):
    """Acceptance: with ``use_kernel`` on under the sharded mesh, engine
    greedy decoding (multi-chunk prefill + decode, shard-affine placement,
    pages-sharded device pool) is identical to the meshless jnp reference
    for the dense AND mla families."""
    cfg = get_config(arch + "-reduced")
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, n, dtype=np.int32)
               for n in (100, 45)]
    ecfg = EngineConfig(num_lanes=2, max_len=256,
                        prefill_buckets=(16, 32, 64, 128))

    ref = Engine(cfg, MODES["coopt"], ecfg)
    out_ref = ref.generate(prompts, max_new_tokens=6)

    eng = Engine(cfg, MODES["coopt"].replace(use_kernel=True), ecfg,
                 mesh=mesh)
    assert eng._kernel_ctx is not None
    assert eng.ecfg.num_shards == KV_SHARDS
    out_mesh = eng.generate(prompts, max_new_tokens=6)
    assert out_ref == out_mesh
    assert all(len(o) == 6 for o in out_mesh)


# --------------------------------------------------------- HLO: no gather --
@needs_sharded_mesh
@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-lite-16b"])
def test_sharded_step_hlo_has_no_pool_all_gather(mesh, arch):
    """Acceptance: the compiled HLO of the engine's sharded kernel decode
    step contains no all-gather of the KV/latent pool — every all-gather
    moves strictly less than one shard's pool bytes (the lse merge moves
    only (B, H)-sized partials). Asserted via the HLO text walk of
    ``launch.hlo_cost``."""
    from repro.launch.hlo_cost import HloCostModel

    cfg = get_config(arch + "-reduced")
    eng = Engine(cfg, MODES["coopt"].replace(use_kernel=True),
                 EngineConfig(num_lanes=2, max_len=256,
                              prefill_buckets=(16, 32, 64, 128)),
                 mesh=mesh)
    B = eng.ecfg.num_lanes
    NP = eng.scheduler.pages_per_lane
    batch = {"token": jnp.zeros((B, 1), jnp.int32),
             "positions": jnp.full((B, 1), 5, jnp.int32),
             "slot_idx": jnp.full((B, 1), 5, jnp.int32),
             "page_table": jnp.zeros((B, NP), jnp.int32),
             "cache_len": jnp.full((B,), 6, jnp.int32)}
    compiled = eng._decode_fn.lower(eng.params, batch, eng.cache,
                                    jnp.ones((B,), bool)).compile()
    model = HloCostModel(compiled.as_text())

    pool_bytes = sum(eng.cache[k].nbytes for k in ("kv", "scale")
                     if k in eng.cache)
    shard_bytes = pool_bytes // KV_SHARDS
    offenders = [d for b, d in model.collective_ops
                 if "all-gather" in d and b >= shard_bytes]
    assert not offenders, \
        f"pool-sized all-gather in sharded step HLO: {offenders[:3]}"
