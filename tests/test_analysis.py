"""cooptlint self-tests: one good + one bad fixture per finding code,
baseline round-trip, inline suppression, and the repo-gate invariant that
`python -m repro.analysis src/repro` exits 0 on the committed tree."""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis import run_suite, write_baseline

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint(tmp_path, relpath, source, **kw):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    live, suppressed, baselined, report = run_suite(
        [str(tmp_path)], root=str(tmp_path), **kw)
    return live, suppressed, baselined, report


def _codes(findings):
    return [f.code for f in findings]


# ------------------------------------------------------------- COOPT001 --
BAD_SYNC = """
    import numpy as np

    class Engine:
        def _build_step(self, toks):
            return np.asarray(toks)     # stray sync on the plan path
"""

GOOD_SYNC = """
    import numpy as np

    class Engine:
        def _execute(self, sb):
            return np.asarray(sb.toks)  # the designated host boundary
"""


def test_host_sync_bad(tmp_path):
    live, *_ = _lint(tmp_path, "serving/engine.py", BAD_SYNC)
    assert _codes(live) == ["COOPT001"]
    assert live[0].symbol == "Engine._build_step"


def test_host_sync_good(tmp_path):
    live, *_ = _lint(tmp_path, "serving/engine.py", GOOD_SYNC)
    assert live == []


def test_host_sync_only_serving_modules(tmp_path):
    # the same sync outside serving/ is not this pass's business
    live, *_ = _lint(tmp_path, "models/util.py", BAD_SYNC)
    assert live == []


# ------------------------------------------------------------- COOPT002 --
BAD_DONATE = """
    import jax

    class Engine:
        def __init__(self):
            self._step_fn = jax.jit(self._impl, donate_argnums=(1,))

        def step(self, params, cache):
            logits, new_cache = self._step_fn(params, cache)
            return logits, cache.shape   # read after donation
"""

GOOD_DONATE = """
    import jax

    class Engine:
        def __init__(self):
            self._step_fn = jax.jit(self._impl, donate_argnums=(1,))

        def step(self, params, cache):
            logits, cache = self._step_fn(params, cache)  # rebound
            return logits, cache.shape
"""


def test_donation_bad(tmp_path):
    live, *_ = _lint(tmp_path, "serving/x.py", BAD_DONATE)
    assert _codes(live) == ["COOPT002"]
    assert "cache" in live[0].message


def test_donation_good(tmp_path):
    live, *_ = _lint(tmp_path, "serving/x.py", GOOD_DONATE)
    assert live == []


def test_donation_dict_dispatch(tmp_path):
    # the Engine._execute idiom: fn looked up from a dict of donating jits
    src = """
    import jax

    class Engine:
        def __init__(self):
            self._a_fn = jax.jit(self._a, donate_argnums=(0,))
            self._b_fn = jax.jit(self._b, donate_argnums=(0,))

        def run(self, kind, cache):
            fn = {"a": self._a_fn, "b": self._b_fn}[kind]
            out = fn(cache)
            return out, cache.shape      # read after donation
    """
    live, *_ = _lint(tmp_path, "serving/x.py", src)
    assert _codes(live) == ["COOPT002"]


# ------------------------------------------------------------- COOPT003 --
BAD_MESH = """
    from repro.kernels import ops

    def trace_step(ctx, fn, x):
        ops.set_mesh_ctx(ctx)            # installed, never restored
        return fn(x)
"""

GOOD_MESH = """
    from repro.kernels import ops

    def trace_step(ctx, fn, x):
        saved = ops.mesh_ctx()
        ops.set_mesh_ctx(ctx)
        try:
            return fn(x)
        finally:
            ops.set_mesh_ctx(saved)
"""


def test_mesh_ctx_bad(tmp_path):
    live, *_ = _lint(tmp_path, "launch/x.py", BAD_MESH)
    assert _codes(live) == ["COOPT003"]


def test_mesh_ctx_good(tmp_path):
    live, *_ = _lint(tmp_path, "launch/x.py", GOOD_MESH)
    assert live == []


# ------------------------------------------------------------- COOPT004 --
BAD_TRACE = """
    import jax

    INTERPRET = True

    def configure():
        global INTERPRET
        INTERPRET = False

    @jax.jit
    def step(x):
        return run(x, interpret=INTERPRET)   # baked at trace time
"""

GOOD_TRACE = """
    import jax
    from functools import partial

    INTERPRET = True

    def configure():
        global INTERPRET
        INTERPRET = False

    @partial(jax.jit, static_argnames=("interpret",))
    def _step(x, *, interpret):
        return run(x, interpret=interpret)

    def step(x):
        return _step(x, interpret=INTERPRET)   # read OUTSIDE the jit
"""


def test_trace_safety_global_bad(tmp_path):
    live, *_ = _lint(tmp_path, "kernels_misc/x.py", BAD_TRACE)
    assert _codes(live) == ["COOPT004"]
    assert "INTERPRET" in live[0].message


def test_trace_safety_global_good(tmp_path):
    live, *_ = _lint(tmp_path, "kernels_misc/x.py", GOOD_TRACE)
    assert live == []


def test_trace_safety_mutable_self_attr(tmp_path):
    src = """
    import jax

    class Engine:
        def __init__(self):
            self.cache = None
            self._fn = jax.jit(self._impl)

        def place(self, c):
            self.cache = c               # mutated outside __init__

        def _impl(self, x):
            return x + self.cache        # closure over per-step state
    """
    live, *_ = _lint(tmp_path, "serving/x.py", src)
    assert _codes(live) == ["COOPT004"]
    assert "self.cache" in live[0].message


def test_trace_safety_full_pool_gather(tmp_path):
    src = """
    import jax.numpy as jnp

    def decode(pool, pt):
        return jnp.take(pool, pt, axis=0)
    """
    live, *_ = _lint(tmp_path / "hot", "kernels/hot.py", src)
    assert _codes(live) == ["COOPT004"]
    # ref.py is the designated naive-formulation oracle
    live, *_ = _lint(tmp_path / "ref", "kernels/ref.py", src)
    assert live == []


# ------------------------------------------------------------- COOPT005 --
_KERNEL_TMPL = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def kernel(q, pages, phys, *, interpret):
        def page_idx(b, s, phys):
            return ({DEREF}, 0, 0)
        return pl.pallas_call(
            _kern,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(4, 8),
                in_specs=[
                    pl.BlockSpec((1, 1, {BQ}, 128),
                                 lambda b, s, phys: (b, s, 0, 0)),
                    pl.BlockSpec((1, {BQ}, 128), page_idx),
                ],
                out_specs=[pl.BlockSpec((1, 1, {BQ}, 128),
                                        lambda b, s, phys: (b, s, 0, 0))],
                scratch_shapes=[pltpu.VMEM(({BQ}, 128), jnp.float32)],
            ),
            interpret=interpret,
        )(phys, q, pages)
"""


def _kernel_src(deref="jnp.maximum(phys[b, s], 0)", bq=64):
    return _KERNEL_TMPL.replace("{DEREF}", deref).replace("{BQ}", str(bq))


def test_pallas_sentinel_clamped_ok(tmp_path):
    live, _s, _b, report = _lint(tmp_path, "kernels/k.py", _kernel_src())
    assert live == []
    assert len(report) == 1 and report[0]["under_budget"]


def test_pallas_sentinel_unclamped_flagged(tmp_path):
    live, *_ = _lint(tmp_path, "kernels/k.py",
                     _kernel_src(deref="phys[b, s]"))
    assert _codes(live) == ["COOPT005"]
    assert "sentinel" in live[0].message


def test_pallas_grid_index_deref_flagged(tmp_path):
    # subscripting a grid index (not a prefetch ref) inside the index_map
    live, *_ = _lint(tmp_path, "kernels/k.py",
                     _kernel_src(deref="jnp.maximum(b[s], 0)"))
    assert _codes(live) == ["COOPT005"]
    assert "grid index" in live[0].message


def test_pallas_vmem_budget(tmp_path):
    # same kernel, huge query block: must blow a 1 MiB budget
    live, _s, _b, report = _lint(tmp_path, "kernels/k.py",
                                 _kernel_src(bq=4096),
                                 vmem_budget=1 << 20)
    assert _codes(live) == ["COOPT005"]
    assert "budget" in live[0].message
    assert not report[0]["under_budget"]
    assert report[0]["est_vmem_bytes"] > (1 << 20)


def test_vmem_report_covers_repo_kernels():
    """The four pooled serving kernels must appear in the repo's VMEM
    report and sit under the default budget."""
    live, _s, _b, report = run_suite(
        [os.path.join(REPO_ROOT, "src", "repro", "kernels")],
        root=REPO_ROOT, select=["COOPT005"])
    names = {e["kernel"] for e in report}
    for k in ("paged_pool_decode", "flash_chunk_prefill",
              "paged_latent_decode", "latent_chunk_prefill"):
        assert k in names, f"{k} missing from VMEM report"
    assert all(e["under_budget"] for e in report)


# ------------------------------------------------------------- COOPT006 --
BAD_EXCEPT = """
    class Worker:
        def run(self):
            while True:
                try:
                    self.step()
                except Exception:
                    pass                    # fault swallowed
"""

GOOD_EXCEPT = """
    class Worker:
        def run(self):
            try:
                self.step()
            except Exception as exc:
                self.post(exc)              # recorded, not swallowed
            try:
                self.step()
            except ValueError:
                pass                        # narrow handlers are policy
            try:
                self.step()
            except Exception:
                self.note()
                raise                       # re-raised
"""


def test_exceptions_bad(tmp_path):
    live, *_ = _lint(tmp_path, "serving/worker.py", BAD_EXCEPT)
    assert _codes(live) == ["COOPT006"]
    assert live[0].symbol == "Worker.run"


def test_exceptions_good(tmp_path):
    live, *_ = _lint(tmp_path, "serving/worker.py", GOOD_EXCEPT)
    assert live == []


def test_exceptions_bound_but_unused(tmp_path):
    src = BAD_EXCEPT.replace("except Exception:",
                             "except Exception as exc:")
    live, *_ = _lint(tmp_path, "serving/worker.py", src)
    assert _codes(live) == ["COOPT006"]


def test_exceptions_bare_except(tmp_path):
    src = BAD_EXCEPT.replace("except Exception:", "except:")
    live, *_ = _lint(tmp_path, "serving/worker.py", src)
    assert _codes(live) == ["COOPT006"]
    assert "bare except" in live[0].message


def test_exceptions_only_serving_modules(tmp_path):
    # the same swallow outside serving/ is not this pass's business
    live, *_ = _lint(tmp_path, "benchmarks/run.py", BAD_EXCEPT)
    assert live == []


# --------------------------------------------- suppression and baseline --
def test_inline_suppression(tmp_path):
    src = BAD_SYNC.replace(
        "return np.asarray(toks)     # stray sync on the plan path",
        "return np.asarray(toks)  # coopt: allow[COOPT001]")
    live, suppressed, *_ = _lint(tmp_path, "serving/engine.py", src)
    assert live == [] and _codes(suppressed) == ["COOPT001"]


def test_inline_suppression_line_above(tmp_path):
    src = BAD_SYNC.replace(
        "return np.asarray(toks)     # stray sync on the plan path",
        "# coopt: allow[COOPT001]\n            return np.asarray(toks)")
    live, suppressed, *_ = _lint(tmp_path, "serving/engine.py", src)
    assert live == [] and _codes(suppressed) == ["COOPT001"]


def test_inline_suppression_wrong_code_does_not_apply(tmp_path):
    src = BAD_SYNC.replace(
        "return np.asarray(toks)     # stray sync on the plan path",
        "return np.asarray(toks)  # coopt: allow[COOPT005]")
    live, suppressed, *_ = _lint(tmp_path, "serving/engine.py", src)
    assert _codes(live) == ["COOPT001"] and suppressed == []


def test_baseline_round_trip(tmp_path):
    p = tmp_path / "serving" / "engine.py"
    p.parent.mkdir(parents=True)
    p.write_text(textwrap.dedent(BAD_SYNC))
    live, _s, baselined, _r = run_suite([str(tmp_path)], root=str(tmp_path))
    assert _codes(live) == ["COOPT001"]

    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), live)
    data = json.loads(bl.read_text())
    assert len(data["findings"]) == 1
    assert "justification" in data["findings"][0]

    live2, _s, baselined2, _r = run_suite(
        [str(tmp_path)], root=str(tmp_path), baseline_path=str(bl))
    assert live2 == [] and _codes(baselined2) == ["COOPT001"]

    # baseline matching ignores line drift: shift the file down two lines
    p.write_text("# pad\n# pad\n" + textwrap.dedent(BAD_SYNC))
    live3, _s, baselined3, _r = run_suite(
        [str(tmp_path)], root=str(tmp_path), baseline_path=str(bl))
    assert live3 == [] and _codes(baselined3) == ["COOPT001"]


# ----------------------------------------------------------- repo gate --
def test_repo_is_clean():
    """The committed tree must pass its own linter — the CI gate."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src/repro",
         "--format", "json"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    payload = json.loads(res.stdout)
    assert res.returncode == 0, payload["findings"]
    assert payload["findings"] == []
    assert len(payload["vmem_report"]) >= 4
