"""Global refcounted BlockManager: free-list, prefix-cache and LRU
properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache.block_manager import (BlockManager, OutOfBlocks,
                                       PageHome, PageResidency)


def test_allocate_free_roundtrip():
    m = BlockManager(num_pages=8, page_size=16)
    pages, cached = m.allocate(seq_id=1, num_tokens=40)     # 3 pages
    assert len(pages) == 3 and cached == 0 and m.free_pages == 5
    m.free(1)
    assert m.free_pages == 8


def test_append_token_grows_pages():
    m = BlockManager(8, 4)
    m.allocate(1, 4)                                 # exactly one page
    slot = m.append_token(1)                         # needs a new page
    assert m.num_tokens(1) == 5
    assert m.free_pages == 6
    assert slot == m.page_table(1)[1] * 4            # first slot of page 2


def test_out_of_blocks_raises():
    m = BlockManager(2, 16)
    m.allocate(1, 32)
    with pytest.raises(OutOfBlocks):
        m.allocate(2, 1)


def test_slot_indices_skipset():
    m = BlockManager(4, 8)
    m.allocate(1, 16)
    pos = np.arange(16)
    skip = (pos % 3 == 0)
    slots = m.slot_indices(1, pos, skip=skip)
    assert np.all(slots[skip] == -1)
    assert np.all(slots[~skip] >= 0)


def test_fragmentation_metric():
    m = BlockManager(8, 16)
    m.allocate(1, 17)                                # 2 pages, 17/32 used
    assert abs(m.fragmentation() - (1 - 17 / 32)) < 1e-9


# ------------------------------------------------------- prefix caching ----
def test_prefix_cache_hit_shares_pages():
    """Two sequences with a shared 2-page prefix allocate the pages ONCE."""
    m = BlockManager(8, page_size=4)
    toks = list(range(11))                           # 2 full pages + tail
    p1, cached1 = m.allocate(1, 11, token_ids=toks)
    assert cached1 == 0
    m.commit_prefill(1, 11, token_ids=toks)          # registers pages 0..1
    p2, cached2 = m.allocate(2, 11, token_ids=toks)
    assert cached2 == 8                              # 2 full pages reused
    assert p2[:2] == p1[:2] and p2[2] != p1[2]       # tail page is fresh
    assert m.prefix_hits == 2
    # pool accounting: 4 unique pages live, not 6
    assert m.pages_in_use == 4


def test_prefix_cache_never_matches_whole_prompt():
    """At least one token is always recomputed (prefill must emit logits)."""
    m = BlockManager(8, page_size=4)
    toks = list(range(8))                            # exactly 2 pages
    m.allocate(1, 8, token_ids=toks)
    m.commit_prefill(1, 8, token_ids=toks)
    _, cached = m.allocate(2, 8, token_ids=toks)
    assert cached == 4                               # page 2 NOT matched


def test_freed_registered_pages_park_in_lru_then_evict():
    m = BlockManager(4, page_size=4)
    toks = list(range(8))
    m.allocate(1, 8, token_ids=toks)
    m.commit_prefill(1, 8, token_ids=toks)
    m.free(1)
    assert m.free_pages == 2 and m.evictable_pages == 2
    # a cold hit resurrects them
    _, cached = m.allocate(2, 9, token_ids=toks + [99])
    assert cached == 8                               # both full pages hit
    m.free(2)
    # allocation pressure evicts the LRU entries
    m.allocate(3, 16)                                # needs all 4 pages
    assert m.evictable_pages == 0 and m.evictions >= 1
    # the cache no longer serves the evicted prefix
    m.free(3)
    _, cached = m.allocate(4, 8, token_ids=toks)
    assert cached == 0


def test_refcounted_free_keeps_shared_pages_alive():
    m = BlockManager(8, page_size=4)
    toks = list(range(9))
    m.allocate(1, 9, token_ids=toks)
    m.commit_prefill(1, 9, token_ids=toks)
    m.allocate(2, 9, token_ids=toks)                 # shares 2 pages
    m.free(1)                                        # seq 2 still holds them
    table = m.page_table(2)
    # gathering seq 2's pages must still be legal (pages not on free list)
    free = {ps.page for ps in m.page_states().values() if ps.home is PageHome.FREE}
    assert all(p not in free for p in table.tolist())
    m.free(2)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 60), st.booleans()),
                min_size=1, max_size=30))
def test_no_double_allocation_property(ops):
    """Pages referenced by live sequences are disjoint from the free list
    and the LRU; every page is accounted for exactly once."""
    m = BlockManager(num_pages=64, page_size=8)
    live = {}
    for i, (ntok, do_free) in enumerate(ops):
        toks = list(range(i, i + ntok))              # mostly distinct
        if m.can_allocate(ntok):
            pages, cached = m.allocate(i, ntok, token_ids=toks)
            m.commit_prefill(i, ntok, token_ids=toks)
            live[i] = pages
        if do_free and live:
            sid = next(iter(live))
            m.free(sid)
            del live[sid]
        # invariants: live pages never on the free list or evictable list;
        # free + evictable + referenced == total
        flat = {p for ps in live.values() for p in ps}
        states = m.page_states().values()
        free = {s.page for s in states if s.home is PageHome.FREE}
        cached = {s.page for s in states if s.home is PageHome.CACHED}
        assert not (flat & free)
        assert not (flat & cached)
        assert len(flat) == m.pages_in_use
        assert m.pages_in_use + m.free_pages + m.evictable_pages == 64
