"""BlockManager free-list properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache.block_manager import BlockManager, OutOfBlocks


def test_allocate_free_roundtrip():
    m = BlockManager(num_pages=8, page_size=16)
    pages = m.allocate(seq_id=1, num_tokens=40)     # 3 pages
    assert len(pages) == 3 and m.free_pages == 5
    m.free(1)
    assert m.free_pages == 8


def test_append_token_grows_pages():
    m = BlockManager(8, 4)
    m.allocate(1, 4)                                 # exactly one page
    slot = m.append_token(1)                         # needs a new page
    assert m.num_tokens(1) == 5
    assert slot // 4 != m.page_table(1)[0] or True   # new page allocated
    assert m.free_pages == 6


def test_out_of_blocks_raises():
    m = BlockManager(2, 16)
    m.allocate(1, 32)
    with pytest.raises(OutOfBlocks):
        m.allocate(2, 1)


def test_slot_indices_skipset():
    m = BlockManager(4, 8)
    m.allocate(1, 16)
    pos = np.arange(16)
    skip = (pos % 3 == 0)
    slots = m.slot_indices(1, pos, skip=skip)
    assert np.all(slots[skip] == -1)
    assert np.all(slots[~skip] >= 0)


def test_fragmentation_metric():
    m = BlockManager(8, 16)
    m.allocate(1, 17)                                # 2 pages, 17/32 used
    assert abs(m.fragmentation() - (1 - 17 / 32)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 60), st.booleans()),
                min_size=1, max_size=30))
def test_no_double_allocation_property(ops):
    """Pages handed out concurrently are always disjoint; free returns
    exactly what was allocated."""
    m = BlockManager(num_pages=64, page_size=8)
    live = {}
    for i, (ntok, do_free) in enumerate(ops):
        need = (ntok + 7) // 8
        if need <= m.free_pages:
            pages = m.allocate(i, ntok)
            live[i] = pages
        if do_free and live:
            sid = next(iter(live))
            m.free(sid)
            del live[sid]
        # invariant: all live pages disjoint
        flat = [p for ps in live.values() for p in ps]
        assert len(flat) == len(set(flat))
        assert len(flat) + m.free_pages == 64
