"""Opt-KV FP8 quantization properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache.quant import (FP8_DTYPE, FP8_MAX, dequantize_fp8,
                               quantize_fp8, quant_roundtrip_error)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       scale=st.floats(1e-3, 1e3),
       d=st.sampled_from([32, 64, 128]))
def test_roundtrip_relative_error(seed, scale, d):
    """fp8 e4m3 roundtrip error <= 2^-3 of the per-vector amax (one ULP)."""
    x = np.random.default_rng(seed).normal(size=(4, d)).astype(np.float32)
    x = x * scale
    err = float(quant_roundtrip_error(jnp.asarray(x)))
    assert err <= 2.0 ** -3 + 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_quantized_values_in_range(seed):
    x = np.random.default_rng(seed).normal(size=(8, 64)) * 100
    q, s = quantize_fp8(jnp.asarray(x, jnp.float32))
    assert q.dtype == FP8_DTYPE
    assert np.all(np.isfinite(np.asarray(q, np.float32)))
    assert np.abs(np.asarray(q, np.float32)).max() <= FP8_MAX
    assert np.all(np.asarray(s) > 0)


def test_scale_is_per_token_per_head():
    x = jnp.ones((2, 3, 4, 8)) * jnp.arange(1, 5)[None, None, :, None]
    q, s = quantize_fp8(x, axis=-1)
    assert s.shape == (2, 3, 4)
    np.testing.assert_allclose(np.asarray(s[0, 0]),
                               np.arange(1, 5) / FP8_MAX, rtol=1e-6)


def test_dequant_inverts_scaling():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 128), jnp.float32)
    q, s = quantize_fp8(x)
    back = dequantize_fp8(q, s, dtype=jnp.float32)
    amax = np.abs(np.asarray(x)).max(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(amax.max()) * 2 ** -3)


def test_zero_vector_is_stable():
    q, s = quantize_fp8(jnp.zeros((4, 64)))
    back = dequantize_fp8(q, s, dtype=jnp.float32)
    assert np.all(np.asarray(back) == 0)
    assert np.all(np.isfinite(np.asarray(s)))
