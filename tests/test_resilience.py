"""Serving resilience chaos suite: seeded ``FaultPlan`` episodes against
the async pipeline, each asserting the three invariants of the layer —

  1. ``BlockManager.audit()`` is clean after the episode (zero leaked
     pages, zero refcount drift, coherent free/LRU/prefix state);
  2. EVERY stream terminates with the CORRECT ``FinishReason`` (no hangs,
     no idle-sweep laggards — terminal events close streams in-line);
  3. surviving requests' greedy outputs are BIT-IDENTICAL to a fault-free
     run of the same prompts.

Episodes: OutOfBlocks storms (injected pool pressure driving preemption),
emit-worker kill (stall watchdog), dispatched-step exceptions (ERROR
drain), emit-path exceptions (posted in-band), seeded cancel storms,
cancel-during-preemption, deadline expiry under load, submit-time load
shedding, and the bounded-preemption reject. All generation is greedy so
any corruption shows up as a token difference.
"""
import queue

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import MODES
from repro.kernels import ops
from repro.serving import (AsyncEngine, Engine, EngineConfig, FaultInjector,
                           FaultPlan, FinishReason, PipelineStallError,
                           Request, TokenStream)
from repro.serving.faults import FaultInjected
from repro.serving.request import RequestState
from repro.serving.sampler import SamplingParams

CFG = get_config("qwen3-4b-reduced")
ops.configure_for_backend()


def _engine(num_lanes=4, max_len=128, seed=0, **kw):
    ecfg = EngineConfig(num_lanes=num_lanes, max_len=max_len,
                        prefill_buckets=(32, 64, 128),
                        sampling=SamplingParams(temperature=0.0),
                        seed=seed, **kw)
    return Engine(CFG, MODES["coopt"], ecfg)


def _prompts(n, rng, lo=4, hi=40):
    return [rng.integers(0, CFG.vocab_size, int(rng.integers(lo, hi)),
                         dtype=np.int32) for _ in range(n)]


def _baseline(prompts, max_new_tokens):
    return _engine().generate(prompts, max_new_tokens=max_new_tokens)


def _assert_clean(eng):
    """Episode oracle: allocator invariants hold and the pool is empty."""
    assert eng.scheduler.manager.audit() == []
    eng._update_pool_stats()
    assert eng.stats.pages_in_use == 0
    assert not eng.scheduler.running


def _assert_all_terminated(streams):
    for s in streams:
        assert s.closed, f"stream {s.req.req_id} never closed"
        assert s.finish_reason is not None
        assert s.req.finish_reason is not None
        # the stream's status mirrors the request's
        assert s.finish_reason is s.req.finish_reason
        # drain any delivered tokens; the terminal sentinel is right
        # behind them — and once closed, get() keeps returning None
        for _ in range(10_000):
            if s.get(timeout=0.1) is None:
                break
        assert s.get(timeout=0.1) is None


# ------------------------------------------------------ OutOfBlocks storm --
def test_oob_storm_preempts_and_survivors_match_baseline():
    """Injected pool-pressure storm: preemptions fire, every request still
    finishes, outputs are bit-identical to a fault-free run, and the
    allocator audits clean."""
    rng = np.random.default_rng(17)
    prompts = _prompts(5, rng, lo=8, hi=30)
    want = _baseline(prompts, 12)

    eng = _engine()
    inj = FaultInjector(FaultPlan(seed=17, oob_at_append=10,
                                  oob_count=4)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=12) for p in prompts]
    fe.run_until_idle()

    assert inj.injected_oob > 0
    assert eng.scheduler.preemptions > 0
    _assert_all_terminated(streams)
    assert [s.finish_reason for s in streams] == \
        [FinishReason.FINISHED] * len(streams)
    assert [list(s.req.output) for s in streams] == [list(o) for o in want]
    _assert_clean(eng)


def test_preemption_limit_rejects_instead_of_livelock():
    """With ``max_preemptions=0`` any preemption becomes a bounded reject
    (PREEMPTION_LIMIT), closing the victim's stream at decision time."""
    rng = np.random.default_rng(23)
    prompts = _prompts(4, rng, lo=8, hi=24)
    eng = _engine(max_preemptions=0)
    FaultInjector(FaultPlan(oob_at_append=6, oob_count=2)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=12) for p in prompts]
    fe.run_until_idle()

    _assert_all_terminated(streams)
    reasons = [s.finish_reason for s in streams]
    assert FinishReason.PREEMPTION_LIMIT in reasons
    assert eng.scheduler.preemption_limit_rejects > 0
    assert eng.stats.preemption_limit_rejects > 0
    for s in streams:          # rejected victims surface as REJECTED state
        if s.finish_reason is FinishReason.PREEMPTION_LIMIT:
            assert s.req.state is RequestState.REJECTED
    _assert_clean(eng)


# ------------------------------------------------------- emit-worker kill --
def test_emit_worker_kill_trips_watchdog_not_a_hang():
    """A silently-dead emit worker must NOT hang ``run_until_idle``: the
    stall watchdog raises ``PipelineStallError`` after the fault drain, so
    every stream is already closed with ERROR and the pool is empty."""
    rng = np.random.default_rng(31)
    eng = _engine()
    FaultInjector(FaultPlan(kill_emit_at=1)).install(eng)
    fe = AsyncEngine(eng, warmup=False, watchdog_s=1.0)
    streams = [fe.submit(p, max_new_tokens=16)
               for p in _prompts(3, rng, lo=6, hi=20)]
    with pytest.raises(PipelineStallError):
        fe.run_until_idle()

    _assert_all_terminated(streams)
    for s in streams:
        assert s.finish_reason is FinishReason.ERROR
        assert isinstance(s.error, PipelineStallError)
    _assert_clean(eng)
    assert eng.stats.errors == len(streams)


# ---------------------------------------------------- step-fault episodes --
def test_dispatched_step_fault_drains_pipeline_as_error():
    """A fault raised inside step dispatch routes ERROR (with the
    exception) to every affected stream; the loop drains instead of
    stranding the pipeline, and later submits fast-fail."""
    rng = np.random.default_rng(37)
    eng = _engine()
    FaultInjector(FaultPlan(raise_at_step=3)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=16)
               for p in _prompts(4, rng, lo=6, hi=20)]
    fe.run_until_idle()        # returns: the fault rides on the streams

    _assert_all_terminated(streams)
    for s in streams:
        assert s.finish_reason is FinishReason.ERROR
        assert isinstance(s.error, FaultInjected)
    _assert_clean(eng)
    # the pipeline is dead: a later submit comes back closed immediately
    late = fe.submit(_prompts(1, rng)[0], max_new_tokens=4)
    assert late.closed and late.finish_reason is FinishReason.ERROR
    assert isinstance(late.error, FaultInjected)


def test_emit_path_exception_is_posted_not_swallowed():
    """An exception inside the emit worker's host sync is posted in-band
    to the loop, which fails the pipeline — the worker never dies silently
    for a non-kill fault."""
    rng = np.random.default_rng(41)
    eng = _engine()

    class EmitBomb:
        def __init__(self):
            self.emissions = 0

        def before_execute(self, sb):
            pass

        def on_turn(self, fe):
            pass

        def on_emit(self):
            self.emissions += 1
            if self.emissions == 2:
                raise RuntimeError("emit-path fault")

    eng.faults = EmitBomb()
    fe = AsyncEngine(eng, warmup=False, watchdog_s=5.0)
    streams = [fe.submit(p, max_new_tokens=16)
               for p in _prompts(3, rng, lo=6, hi=20)]
    fe.run_until_idle()

    _assert_all_terminated(streams)
    for s in streams:
        assert s.finish_reason is FinishReason.ERROR
        assert isinstance(s.error, RuntimeError)
        assert "emit-path fault" in str(s.error)
    _assert_clean(eng)


def test_sync_engine_step_fault_aborts_all_and_reraises():
    """The synchronous loop's contract: a step fault re-raises to the
    caller AFTER draining every live request as ERROR (no leaked pages)."""
    rng = np.random.default_rng(43)
    eng = _engine()
    FaultInjector(FaultPlan(raise_at_step=2)).install(eng)
    reqs = [Request(req_id=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(_prompts(3, rng, lo=6, hi=20))]
    for r in reqs:
        eng.add_request(r)
    with pytest.raises(FaultInjected):
        eng.run()
    for r in reqs:
        assert r.finish_reason is FinishReason.ERROR
        assert isinstance(r.error, FaultInjected)
    _assert_clean(eng)


# -------------------------------------------------------- cancel chaos ----
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cancel_storm_audits_clean_and_survivors_identical(seed):
    """Seeded cancel storms mid-flight: pool returns to zero pages, no
    stream is left unclosed, and the UNcancelled requests' outputs are
    bit-identical to a fault-free run."""
    rng = np.random.default_rng(100 + seed)
    prompts = _prompts(6, rng, lo=6, hi=28)
    want = _baseline(prompts, 10)

    eng = _engine()
    inj = FaultInjector(FaultPlan(seed=seed, cancel_at_turns=(4, 8),
                                  cancel_frac=0.5)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=10) for p in prompts]
    fe.run_until_idle()

    assert inj.injected_cancels > 0
    _assert_all_terminated(streams)
    for s, w in zip(streams, want):
        assert s.finish_reason in (FinishReason.FINISHED,
                                   FinishReason.CANCELLED)
        if s.finish_reason is FinishReason.FINISHED:
            assert list(s.req.output) == list(w)
    _assert_clean(eng)


def test_cancel_during_preemption_interleaving():
    """Cancel a request WHILE it sits preempted in the waiting queue (with
    in-flight device tokens): pages return to zero, its stream closes
    CANCELLED, and the other requests are unaffected."""
    rng = np.random.default_rng(53)
    prompts = _prompts(3, rng, lo=8, hi=24)
    want = _baseline(prompts, 12)

    eng = _engine()
    inj = FaultInjector(FaultPlan(oob_at_append=8,
                                  oob_count=2)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=12) for p in prompts]
    victim = None
    for _ in range(400):
        fe._loop_once()
        preempted = [s for s in streams
                     if s.req.state is RequestState.PREEMPTED]
        if preempted and victim is None:
            victim = preempted[0]
            fe.cancel(victim)          # cancel WHILE preempted
        if victim is not None:
            break
    assert victim is not None, "injection never caused a preemption"
    assert inj.injected_oob > 0
    fe.run_until_idle()

    _assert_all_terminated(streams)
    assert victim.finish_reason is FinishReason.CANCELLED
    for s, w in zip(streams, want):
        if s is not victim:
            assert s.finish_reason is FinishReason.FINISHED
            assert list(s.req.output) == list(w)
    _assert_clean(eng)


# --------------------------------------------- deadlines & load shedding --
def test_deadline_expiry_sheds_queued_work_at_decision_time():
    """Queued requests whose deadline passes are shed TIMED_OUT by the
    scheduler — their streams close WHILE the busy wave still runs, not at
    idle time."""
    rng = np.random.default_rng(59)
    eng = _engine(num_lanes=2)
    fe = AsyncEngine(eng, warmup=False)
    busy = [fe.submit(p, max_new_tokens=40)
            for p in _prompts(2, rng, lo=6, hi=16)]
    doomed = [fe.submit(p, max_new_tokens=8, deadline_s=1e-4)
              for p in _prompts(3, rng, lo=6, hi=16)]
    for _ in range(600):
        fe._loop_once()
        if all(s.closed for s in doomed):
            break
    # the terminal event closed them in-line: the busy wave is still going
    assert all(s.closed for s in doomed)
    assert any(not s.closed for s in busy)
    for s in doomed:
        assert s.finish_reason is FinishReason.TIMED_OUT
        assert s.get(timeout=0.1) is None
    fe.run_until_idle()
    _assert_all_terminated(busy + doomed)
    assert eng.stats.deadline_shed == len(doomed)
    assert eng.stats.latency_summary()["deadline_shed"] == len(doomed)
    _assert_clean(eng)


def test_submit_load_shedding_past_queue_depth_watermark():
    """Past ``max_queue_depth`` pending requests, ``submit`` fast-rejects:
    the stream comes back ALREADY closed with SHED, without ever touching
    the scheduler."""
    rng = np.random.default_rng(61)
    eng = _engine(num_lanes=2)
    fe = AsyncEngine(eng, warmup=False, max_queue_depth=2)
    streams = [fe.submit(p, max_new_tokens=6)
               for p in _prompts(5, rng, lo=6, hi=16)]
    kept, shed = streams[:2], streams[2:]
    for s in shed:
        assert s.closed and s.finish_reason is FinishReason.SHED
        assert s.get(timeout=0.1) is None          # closed NOW, no loop run
    assert eng.stats.shed == len(shed)
    fe.run_until_idle()
    for s in kept:
        assert s.finish_reason is FinishReason.FINISHED
    assert eng.stats.latency_summary()["shed"] == len(shed)
    _assert_clean(eng)


def test_submit_load_shedding_past_queued_tokens_watermark():
    rng = np.random.default_rng(67)
    eng = _engine(num_lanes=2)
    fe = AsyncEngine(eng, warmup=False, max_queued_tokens=40)
    a = fe.submit(rng.integers(0, CFG.vocab_size, 30, dtype=np.int32),
                  max_new_tokens=4)
    b = fe.submit(rng.integers(0, CFG.vocab_size, 30, dtype=np.int32),
                  max_new_tokens=4)              # 30 + 30 > 40 -> shed
    assert not a.closed
    assert b.closed and b.finish_reason is FinishReason.SHED
    fe.run_until_idle()
    assert a.finish_reason is FinishReason.FINISHED
    _assert_clean(eng)


# ---------------------------------------------- terminal-status contract --
def test_rejected_stream_closes_at_rejection_time():
    """Regression (PR 9 satellite): a REJECTED request's stream must close
    the scheduling turn that rejected it — not after the whole pipeline
    idles — so a client blocked on ``get()`` is released immediately."""
    rng = np.random.default_rng(71)
    eng = _engine(num_lanes=2, max_len=128)
    fe = AsyncEngine(eng, warmup=False)
    busy = fe.submit(_prompts(1, rng, lo=8, hi=16)[0], max_new_tokens=48)
    # 100 prompt tokens + 64 generation > max_len=128: never servable
    doomed = fe.submit(rng.integers(0, CFG.vocab_size, 100, dtype=np.int32),
                       max_new_tokens=64)
    for _ in range(600):
        fe._loop_once()
        if doomed.closed:
            break
    assert doomed.closed and doomed.finish_reason is FinishReason.REJECTED
    assert doomed.get(timeout=0.1) is None
    assert not busy.closed          # the pipeline is very much still busy
    fe.run_until_idle()
    assert busy.finish_reason is FinishReason.FINISHED
    _assert_clean(eng)


def test_token_stream_timeout_raises_timeout_error():
    """``get(timeout=...)`` raises TimeoutError (never ``queue.Empty``);
    None strictly means closed, and a closed stream stays closed."""
    s = TokenStream(Request(req_id=0, prompt=np.zeros(4, np.int32)))
    with pytest.raises(TimeoutError):
        s.get(timeout=0.01)
    try:
        s.get(timeout=0.01)
    except queue.Empty:
        pytest.fail("queue.Empty leaked through TokenStream.get")
    except TimeoutError:
        pass
    s.put(7)
    s.req.finish(FinishReason.CANCELLED)
    s.close()
    assert s.get(timeout=0.1) == 7
    assert s.get(timeout=0.1) is None
    assert s.get(timeout=0.1) is None       # stays closed
    assert s.finish_reason is FinishReason.CANCELLED


# ----------------------------------------------------- host-tier chaos ----
def _host_tier_kw(host_pages=32):
    from repro.serving import CacheConfig
    # 4 usable device pages (page_size=64, 2 pages per request): the
    # shared-prefix replay below cannot fit its working set, so evictions
    # spill to the host tier and repeats prefetch back
    return dict(num_lanes=2, max_len=128,
                cache=CacheConfig(num_pages=5, host_pages=host_pages,
                                  prefetch_depth=2))


def _shared_prefix_prompts(rng, k=6, rounds=2):
    """k distinct one-page (64-token) prefixes replayed round-robin: reuse
    distance always exceeds the 4-page device pool."""
    prefixes = [rng.integers(0, CFG.vocab_size, 64, dtype=np.int32)
                for _ in range(k)]
    out = []
    for _ in range(rounds):
        for p in prefixes:
            out.append(np.concatenate(
                [p, rng.integers(0, CFG.vocab_size, 16, dtype=np.int32)]))
    return out


def test_host_tier_chaos_spill_drop_and_prefetch_fail():
    """Seeded host-tier faults — dropped spill copies and a failed
    prefetch landing — must be absorbed silently: dropped pages just
    recompute, failed flights return their payload to the host store, all
    streams FINISH with outputs bit-identical to the fault-free tier run,
    and the two-tier allocator audits clean."""
    rng = np.random.default_rng(83)
    prompts = _shared_prefix_prompts(rng)

    ref = _engine(**_host_tier_kw())
    want = ref.generate(prompts, max_new_tokens=8)
    assert ref.stats.spilled_pages > 0          # the episode exercises the tier

    eng = _engine(**_host_tier_kw())
    inj = FaultInjector(FaultPlan(seed=83, spill_drop_at=2,
                                  spill_drop_count=3,
                                  prefetch_fail_at=1,
                                  prefetch_fail_count=1)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=8) for p in prompts]
    fe.run_until_idle()

    assert inj.spills > 0 and inj.injected_spill_drops > 0
    _assert_all_terminated(streams)
    assert [s.finish_reason for s in streams] == \
        [FinishReason.FINISHED] * len(streams)
    assert [list(s.req.output) for s in streams] == [list(o) for o in want]
    _assert_clean(eng)
    assert eng.scheduler.manager.staging_pages == 0


def test_host_tier_chaos_slow_prefetch_cancel_storm():
    """Slow host link (every prefetch takes 3 extra turns to land) plus a
    seeded cancel storm mid-episode: cancelled streams close CANCELLED,
    survivors FINISH, no flight leaks a staging page, and the allocator
    audits clean with zero pages in use."""
    rng = np.random.default_rng(89)
    prompts = _shared_prefix_prompts(rng)

    eng = _engine(**_host_tier_kw())
    inj = FaultInjector(FaultPlan(seed=89, prefetch_delay_turns=3,
                                  cancel_at_turns=(6, 12),
                                  cancel_frac=0.3)).install(eng)
    fe = AsyncEngine(eng, warmup=False)
    streams = [fe.submit(p, max_new_tokens=8) for p in prompts]
    fe.run_until_idle()

    _assert_all_terminated(streams)
    reasons = [s.finish_reason for s in streams]
    assert set(reasons) <= {FinishReason.FINISHED, FinishReason.CANCELLED}
    if inj.injected_cancels:
        assert reasons.count(FinishReason.CANCELLED) == inj.injected_cancels
    assert reasons.count(FinishReason.FINISHED) > 0
    _assert_clean(eng)
    assert eng.scheduler.manager.staging_pages == 0
    assert eng._prefetch_flights == []
