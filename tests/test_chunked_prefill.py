"""Chunked prefill (Sarathi-style continuation) must equal monolithic
prefill: same cache contents, same final logits, decode continues
identically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import COOPT, ORIGINAL
from repro.models import get_model


@pytest.mark.parametrize("arch", ["qwen3-4b-reduced", "yi-34b-reduced"])
@pytest.mark.parametrize("coopt", [ORIGINAL, COOPT], ids=["bf16", "coopt"])
def test_chunked_equals_monolithic_prefill(arch, coopt):
    cfg = get_config(arch)
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S, C = 2, 64, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)

    mono_cache = m.init_cache(B, S + 8, coopt)
    mono_logits, mono_cache = m.prefill(p, {"tokens": toks}, mono_cache,
                                        coopt)

    ch_cache = m.init_cache(B, S + 8, coopt)
    for i in range(0, S, C):
        pos = jnp.broadcast_to(jnp.arange(i, i + C), (B, C)).astype(jnp.int32)
        ch_logits, ch_cache = m.prefill(
            p, {"tokens": toks[:, i:i + C], "positions": pos,
                "slot_idx": pos}, ch_cache, coopt)

    np.testing.assert_array_equal(np.asarray(ch_cache["length"]),
                                  np.asarray(mono_cache["length"]))
    a = np.asarray(mono_logits, np.float32)
    b = np.asarray(ch_logits, np.float32)
    # chunked reads its keys back through the (possibly fp8) cache: allow
    # quantization skew in coopt mode, near-exact in bf16 mode
    atol = (0.15 if coopt.opt_kv else 0.05) * max(np.abs(a).max(), 1.0)
    np.testing.assert_allclose(a, b, atol=atol)

    # decode continues identically from either cache
    tok = jnp.argmax(mono_logits, -1)[:, None].astype(jnp.int32)
    d1, _ = m.decode_step(p, {"token": tok}, mono_cache, coopt)
    d2, _ = m.decode_step(p, {"token": tok}, ch_cache, coopt)
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d2, np.float32), atol=atol)


def test_chunked_prefill_mla_raises():
    cfg = get_config("deepseek-v2-lite-16b-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(1, 32, COOPT)
    pos = jnp.arange(16)[None].astype(jnp.int32)
    with pytest.raises(NotImplementedError):
        m.prefill(p, {"tokens": jnp.zeros((1, 16), jnp.int32),
                      "positions": pos, "slot_idx": pos}, cache, COOPT)
