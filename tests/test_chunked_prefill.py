"""Chunked prefill (Sarathi-style continuation) must equal monolithic
prefill: same cache contents, same final logits, decode continues
identically — now over the GLOBAL pool (chunks carry global slot indices
under the lane-identity partition)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.coopt import COOPT, ORIGINAL
from repro.core.opt_kv import identity_slots
from repro.models import get_model


@pytest.mark.parametrize("arch", ["qwen3-4b-reduced", "yi-34b-reduced",
                                  "deepseek-v2-lite-16b-reduced"])
@pytest.mark.parametrize("coopt", [ORIGINAL, COOPT], ids=["bf16", "coopt"])
def test_chunked_equals_monolithic_prefill(arch, coopt):
    cfg = get_config(arch)
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S, C = 2, 64, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)

    mono_cache = m.init_cache(B, S + 8, coopt)
    mono_logits, mono_cache = m.prefill(p, {"tokens": toks}, mono_cache,
                                        coopt)

    ch_cache = m.init_cache(B, S + 8, coopt)
    # mla latent pool: (L, P, ps, R+dr); others: (L, 2, P, ps, Hkv, D)
    P_total = ch_cache["kv"].shape[1 if cfg.family == "mla" else 2]
    for i in range(0, S, C):
        pos = jnp.broadcast_to(jnp.arange(i, i + C), (B, C)).astype(jnp.int32)
        slots = identity_slots(B, pos, P_total, coopt.page_size)
        ch_logits, ch_cache = m.prefill(
            p, {"tokens": toks[:, i:i + C], "positions": pos,
                "slot_idx": slots}, ch_cache, coopt)

    np.testing.assert_array_equal(np.asarray(ch_cache["length"]),
                                  np.asarray(mono_cache["length"]))
    a = np.asarray(mono_logits, np.float32)
    b = np.asarray(ch_logits, np.float32)
    # chunked reads its keys back through the (possibly fp8) cache: allow
    # quantization skew in coopt mode, near-exact in bf16 mode
    atol = (0.15 if coopt.opt_kv else 0.05) * max(np.abs(a).max(), 1.0)
    np.testing.assert_allclose(a, b, atol=atol)

    # decode continues identically from either cache
    tok = jnp.argmax(mono_logits, -1)[:, None].astype(jnp.int32)
    d1, _ = m.decode_step(p, {"token": tok}, mono_cache, coopt)
    d2, _ = m.decode_step(p, {"token": tok}, ch_cache, coopt)
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d2, np.float32), atol=atol)


def test_mixed_step_decode_lane_matches_pure_decode():
    """A decode token fed through the chunked path (chunk of length 1, the
    token-budget scheduler's mixed step) must produce the same logits as the
    dedicated decode path — bf16 mode, exact schedule equivalence."""
    cfg = get_config("qwen3-4b-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    coopt = ORIGINAL
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache = m.init_cache(B, S + 8, coopt)
    logits, cache = m.prefill(p, {"tokens": toks}, cache, coopt)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    P_total = cache["kv"].shape[2]
    pos = jnp.full((B, 1), S, jnp.int32)
    slots = identity_slots(B, pos, P_total, coopt.page_size)
    via_decode, _ = m.decode_step(
        p, {"token": tok, "positions": pos, "slot_idx": slots,
            "cache_len": jnp.full((B,), S + 1, jnp.int32)}, cache, coopt)
    via_chunk, _ = m.prefill(
        p, {"tokens": tok, "positions": pos, "slot_idx": slots,
            "cache_len": jnp.full((B,), S + 1, jnp.int32),
            "last_pos": jnp.zeros((B,), jnp.int32)}, cache, coopt)
    a = np.asarray(via_decode, np.float32)
    b = np.asarray(via_chunk, np.float32)
    atol = 0.05 * max(np.abs(a).max(), 1.0)
    np.testing.assert_allclose(a, b, atol=atol)


def test_mixed_step_decode_lane_matches_pure_decode_mla():
    """MLA's absorbed chunk attention (chunk of length 1) must agree with
    its absorbed paged decode — same matrix-absorption, same latent bytes."""
    cfg = get_config("deepseek-v2-lite-16b-reduced")
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    coopt = ORIGINAL
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache = m.init_cache(B, S + 8, coopt)
    logits, cache = m.prefill(p, {"tokens": toks}, cache, coopt)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    P_total = cache["kv"].shape[1]
    pos = jnp.full((B, 1), S, jnp.int32)
    slots = identity_slots(B, pos, P_total, coopt.page_size)
    via_decode, _ = m.decode_step(
        p, {"token": tok, "positions": pos, "slot_idx": slots,
            "cache_len": jnp.full((B,), S + 1, jnp.int32)}, cache, coopt)
    via_chunk, _ = m.prefill(
        p, {"tokens": tok, "positions": pos, "slot_idx": slots,
            "cache_len": jnp.full((B,), S + 1, jnp.int32),
            "last_pos": jnp.zeros((B,), jnp.int32)}, cache, coopt)
    a = np.asarray(via_decode, np.float32)
    b = np.asarray(via_chunk, np.float32)
    atol = 0.05 * max(np.abs(a).max(), 1.0)
    np.testing.assert_allclose(a, b, atol=atol)
