"""Opt-KV write/read path semantics over the GLOBAL pool (paper §3.1,
Eq. 5/6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coopt import CoOptConfig, COOPT, ORIGINAL, OPT_KV
from repro.core.opt_kv import (gather_cached_kv, identity_page_table,
                               identity_slots, logical_to_physical,
                               make_layer_cache, window_page_table, write_kv)


def _mk(P=8, ps=8, H=2, D=16, B=2, S=5, coopt=OPT_KV):
    kv, sc = make_layer_cache(P, ps, H, D, coopt)
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D), jnp.float32)
    return kv, sc, k, v


def test_skipset_negative_slots_never_written():
    """Eq. 5: slot < 0 => the token's K/V must not touch the cache."""
    kv, sc, k, v = _mk()
    # lanes write DISJOINT global slots (refcounted pool invariant)
    slots = jnp.array([[0, -1, 2, -1, 4], [-1, 33, -1, 35, -1]], jnp.int32)
    kv2, sc2 = write_kv(kv, sc, k, v, slots, OPT_KV)
    flat = np.asarray(kv2.reshape(2, -1, 2, 16).astype(jnp.float32))
    # skipped slots stay zero
    assert np.all(flat[:, 1] == 0) and np.all(flat[:, 3] == 0)
    assert np.all(flat[:, 32] == 0) and np.all(flat[:, 34] == 0)
    # written slots are non-zero
    assert np.abs(flat[0, 0]).max() > 0
    assert np.abs(flat[0, 33]).max() > 0


def test_write_then_gather_roundtrip_fp8():
    """Eq. 6: gather_cached_kv dequantizes what write_kv stored."""
    kv, sc, k, v = _mk()
    # lane 0 -> page 0 (slots 0..), lane 1 -> page 4 (slots 32..): the
    # identity partition of an 8-page pool between 2 lanes
    slots = identity_slots(2, jnp.broadcast_to(jnp.arange(5), (2, 5)), 8, 8)
    kv2, sc2 = write_kv(kv, sc, k, v, slots, OPT_KV)
    table = identity_page_table(2, 8)[:, :1]      # each lane's first page
    out = gather_cached_kv(kv2, sc2, table, OPT_KV, dtype=jnp.float32)
    amax = float(np.abs(np.asarray(k)).max())
    np.testing.assert_allclose(np.asarray(out[0, :, :5]), np.asarray(k),
                               atol=amax * 2 ** -3)


def test_bf16_mode_is_exactish():
    co = ORIGINAL
    kv, sc, k, v = _mk(coopt=co)
    slots = identity_slots(2, jnp.broadcast_to(jnp.arange(5), (2, 5)), 8, 8)
    kv2, _ = write_kv(kv, None, k, v, slots, co)
    table = identity_page_table(2, 8)[:, :1]
    out = gather_cached_kv(kv2, None, table, co, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out[0, :, :5]), np.asarray(k),
                               atol=0.01, rtol=0.01)


def test_gather_negative_pages_are_zero():
    kv, sc, k, v = _mk()
    slots = identity_slots(2, jnp.broadcast_to(jnp.arange(5), (2, 5)), 8, 8)
    kv2, sc2 = write_kv(kv, sc, k, v, slots, OPT_KV)
    table = jnp.array([[0, -1], [-1, 4]], jnp.int32)
    out = np.asarray(gather_cached_kv(kv2, sc2, table, OPT_KV,
                                      dtype=jnp.float32))
    ps = 8
    assert np.all(out[:, 0, ps:] == 0)            # lane 0, table slot 1 = -1
    assert np.all(out[:, 1, :ps] == 0)            # lane 1, table slot 0 = -1


def test_shared_page_read_by_two_lanes():
    """Prefix caching: the SAME physical page appears in two lanes' tables
    and both gathers see identical content (CoW read sharing)."""
    kv, sc, k, v = _mk()
    slots = jnp.broadcast_to(jnp.arange(5), (1, 5)).astype(jnp.int32)
    kv2, sc2 = write_kv(kv, sc, k[:1], v[:1], slots, OPT_KV)
    table = jnp.array([[0], [0]], jnp.int32)      # both lanes -> page 0
    out = np.asarray(gather_cached_kv(kv2, sc2, table, OPT_KV,
                                      dtype=jnp.float32))
    np.testing.assert_array_equal(out[:, 0], out[:, 1])


class TestWindowPageTable:
    def test_selects_sink_and_window(self):
        # 16 pages x 16 tokens; window 64 => 5 window pages + 1 sink
        t = window_page_table(jnp.array([256]), 16, 16, 64, 1)
        sel = set(int(x) for x in np.asarray(t[0]) if x >= 0)
        assert 0 in sel                            # sink page
        assert {11, 12, 13, 14, 15} <= sel         # window pages

    def test_no_duplicates_at_full_cache(self):
        """Regression: cache_len == P*ps must not duplicate the last page."""
        t = np.asarray(window_page_table(jnp.array([256]), 16, 16, 64, 1)[0])
        live = t[t >= 0]
        assert len(live) == len(set(live.tolist()))

    def test_short_context_no_sink_overlap(self):
        t = np.asarray(window_page_table(jnp.array([40]), 16, 16, 64, 1)[0])
        live = t[t >= 0]
        assert len(live) == len(set(live.tolist()))
        assert set(live.tolist()) <= {0, 1, 2}     # only pages 0..2 exist

    def test_logical_to_physical_preserves_skips(self):
        logical = jnp.array([[0, 2, -1]], jnp.int32)
        table = jnp.array([[7, 5, 3]], jnp.int32)  # lane's physical pages
        phys = np.asarray(logical_to_physical(logical, table))
        assert phys.tolist() == [[7, 3, -1]]

    def test_beyond_table_width_skips_not_aliases(self):
        """Regression: cache_len > num_pages * ps used to CLAMP the window
        pages onto page num_pages-1 (attending the wrong page's content);
        out-of-range logical ids must come back -1 (a skip)."""
        # 4-page table, 16-token pages, cache_len far past the table
        t = np.asarray(window_page_table(jnp.array([400]), 4, 16, 64, 1)[0])
        assert t.max() < 4                        # nothing aliased onto p3
        live = t[t >= 0]
        assert len(live) == len(set(live.tolist()))
        # every window page (ids 20..24) is out of range -> skipped
        assert set(live.tolist()) <= {0, 1, 2, 3}
        assert (t == -1).sum() >= 5

    def test_partially_beyond_table_keeps_in_range_pages(self):
        # cache_len 100 -> last_page 6; table width 5: pages 5,6 skipped,
        # pages 2..4 of the window survive
        t = np.asarray(window_page_table(jnp.array([100]), 5, 16, 64, 1)[0])
        live = set(t[t >= 0].tolist())
        assert live == {0, 2, 3, 4}
        assert t.max() < 5
