"""Opt-KV write/read path semantics (paper §3.1, Eq. 5/6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coopt import CoOptConfig, COOPT, ORIGINAL, OPT_KV
from repro.core.opt_kv import (gather_cached_kv, make_layer_cache,
                               window_page_table, write_kv)


def _mk(B=2, P=4, ps=8, H=2, D=16, coopt=OPT_KV):
    kv, sc = make_layer_cache(B, P, ps, H, D, coopt)
    k = jax.random.normal(jax.random.PRNGKey(0), (B, 5, H, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(1), (B, 5, H, D), jnp.float32)
    return kv, sc, k, v


def test_skipset_negative_slots_never_written():
    """Eq. 5: slot < 0 => the token's K/V must not touch the cache."""
    kv, sc, k, v = _mk()
    slots = jnp.array([[0, -1, 2, -1, 4], [-1, 1, -1, 3, -1]], jnp.int32)
    kv2, sc2 = write_kv(kv, sc, k, v, slots, OPT_KV)
    flat = np.asarray(kv2.reshape(2, 2, -1, 2, 16).astype(jnp.float32))
    # skipped slots stay zero
    assert np.all(flat[:, 0, 1] == 0) and np.all(flat[:, 0, 3] == 0)
    assert np.all(flat[:, 1, 0] == 0) and np.all(flat[:, 1, 2] == 0)
    # written slots are non-zero
    assert np.abs(flat[0, 0, 0]).max() > 0
    assert np.abs(flat[0, 1, 1]).max() > 0


def test_write_then_gather_roundtrip_fp8():
    """Eq. 6: gather_cached_kv dequantizes what write_kv stored."""
    kv, sc, k, v = _mk()
    slots = jnp.broadcast_to(jnp.arange(5), (2, 5)).astype(jnp.int32)
    kv2, sc2 = write_kv(kv, sc, k, v, slots, OPT_KV)
    table = jnp.zeros((2, 1), jnp.int32)          # page 0 holds slots 0..7
    out = gather_cached_kv(kv2, sc2, table, OPT_KV, dtype=jnp.float32)
    amax = float(np.abs(np.asarray(k)).max())
    np.testing.assert_allclose(np.asarray(out[0, :, :5]), np.asarray(k),
                               atol=amax * 2 ** -3)


def test_bf16_mode_is_exactish():
    co = ORIGINAL
    kv, sc, k, v = _mk(coopt=co)
    slots = jnp.broadcast_to(jnp.arange(5), (2, 5)).astype(jnp.int32)
    kv2, _ = write_kv(kv, None, k, v, slots, co)
    out = gather_cached_kv(kv2, None, jnp.zeros((2, 1), jnp.int32), co,
                           dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out[0, :, :5]), np.asarray(k),
                               atol=0.01, rtol=0.01)


def test_gather_negative_pages_are_zero():
    kv, sc, k, v = _mk()
    slots = jnp.broadcast_to(jnp.arange(5), (2, 5)).astype(jnp.int32)
    kv2, sc2 = write_kv(kv, sc, k, v, slots, OPT_KV)
    table = jnp.array([[0, -1], [-1, 0]], jnp.int32)
    out = np.asarray(gather_cached_kv(kv2, sc2, table, OPT_KV,
                                      dtype=jnp.float32))
    ps = 8
    assert np.all(out[:, 0, ps:] == 0)            # batch 0, page slot 1 = -1
    assert np.all(out[:, 1, :ps] == 0)            # batch 1, page slot 0 = -1


class TestWindowPageTable:
    def test_selects_sink_and_window(self):
        # 16 pages x 16 tokens; window 64 => 5 window pages + 1 sink
        t = window_page_table(jnp.array([256]), 16, 16, 64, 1)
        sel = set(int(x) for x in np.asarray(t[0]) if x >= 0)
        assert 0 in sel                            # sink page
        assert {11, 12, 13, 14, 15} <= sel         # window pages

    def test_no_duplicates_at_full_cache(self):
        """Regression: cache_len == P*ps must not duplicate the last page."""
        t = np.asarray(window_page_table(jnp.array([256]), 16, 16, 64, 1)[0])
        live = t[t >= 0]
        assert len(live) == len(set(live.tolist()))

    def test_short_context_no_sink_overlap(self):
        t = np.asarray(window_page_table(jnp.array([40]), 16, 16, 64, 1)[0])
        live = t[t >= 0]
        assert len(live) == len(set(live.tolist()))
        assert set(live.tolist()) <= {0, 1, 2}     # only pages 0..2 exist
