"""HLO cost-model validation: the roofline's FLOP/byte source must resolve
scan trip counts exactly (cost_analysis() does not — see EXPERIMENTS.md
§Dry-run methodology)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile()


def test_plain_dot_flops_exact():
    c = _compile(lambda a, b: a @ b, (256, 512), (512, 1024))
    assert analyze_hlo(c.as_text())["flops"] == 2 * 256 * 512 * 1024


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    c = _compile(f, (128, 128), (128, 128))
    assert analyze_hlo(c.as_text())["flops"] == 10 * 2 * 128 ** 3


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    c = _compile(f, (128, 128), (128, 128))
    assert analyze_hlo(c.as_text())["flops"] == 12 * 2 * 128 ** 3


def test_cost_analysis_undercounts_scans():
    """The reason hlo_cost exists: XLA's own analysis counts a scan body
    once. If this ever starts passing with == 10x, the workaround can be
    retired."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    c = _compile(f, (128, 128), (128, 128))
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):          # jax<=0.4.x: one per device
        cost = cost[0]
    xla_flops = cost["flops"]
    assert xla_flops < 10 * 2 * 128 ** 3 / 2     # undercounts by ~10x


def test_bytes_include_operands_and_output():
    c = _compile(lambda a, b: a @ b, (64, 64), (64, 64))
    s = analyze_hlo(c.as_text())
    assert s["bytes"] >= 3 * 64 * 64 * 4         # 2 reads + 1 write minimum


def test_in_place_update_counts_update_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))
    # donation makes the update truly in-place (no defensive copy op)
    c = jax.jit(f, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    s = analyze_hlo(c.as_text())
    # must NOT count the 64 MiB buffer as traffic
    assert s["bytes"] < 4096 * 4096 * 4 / 2


def test_no_collectives_single_device():
    c = _compile(lambda a, b: a @ b, (64, 64), (64, 64))
    assert analyze_hlo(c.as_text())["collective_bytes"] == 0
