"""Per-kernel allclose sweeps vs the pure-jnp oracles (ref.py), across
shapes, dtypes and mode flags — interpret=True on CPU. Layouts follow the
GLOBAL paged pool (no batch dim on kv pages; lanes address the pool through
scalar-prefetched page tables)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.quant import quantize_fp8
from repro.core.opt_kv import (identity_page_table, logical_to_physical,
                               window_page_table)
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _pool_inputs(B, P, ps, Hkv, G, D, opt_kv, seed=0):
    """Pool of B*P pages, lane-identity partitioned."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Hq = Hkv * G
    PT = B * P
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (PT, ps, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (PT, ps, Hkv, D), jnp.float32)
    phys = identity_page_table(B, PT)
    log = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None], (B, P))
    if opt_kv:
        kq, ksc = quantize_fp8(k)
        vq, vsc = quantize_fp8(v)
        return q, jnp.stack([kq, vq]), jnp.stack([ksc, vsc]), phys, log
    return q, jnp.stack([k, v]).astype(jnp.bfloat16), None, phys, log


def _scales(sc):
    return (sc[0], sc[1]) if sc is not None else (None, None)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt_kv,opt_gqa",
                         list(itertools.product([False, True], repeat=2)))
def test_pool_decode_modes(opt_kv, opt_gqa):
    q, kv, sc, phys, log = _pool_inputs(2, 8, 16, 2, 4, 128, opt_kv)
    cl = jnp.array([8 * 16, 55], jnp.int32)
    out = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=opt_kv,
                                opt_gqa=opt_gqa)
    ks, vs = _scales(sc)
    exp = ref.paged_pool_decode_ref(q, kv[0], kv[1], ks, vs, cl, phys, log,
                                    opt_kv=opt_kv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


@pytest.mark.parametrize("B,P,ps,Hkv,G,D", [
    (1, 4, 8, 1, 1, 64),       # MQA, single group (griffin-like)
    (3, 8, 16, 2, 7, 128),     # odd group count (yi-like 56/8)
    (2, 16, 32, 4, 4, 128),    # larger pages
    (2, 8, 16, 8, 1, 64),      # MHA-as-GQA (whisper: G=1)
])
def test_pool_decode_shape_sweep(B, P, ps, Hkv, G, D):
    q, kv, sc, phys, log = _pool_inputs(B, P, ps, Hkv, G, D, opt_kv=True)
    lens = (np.arange(B) * 17 + 3) % (P * ps) + 1
    cl = jnp.asarray(lens, jnp.int32)
    out = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                opt_gqa=True)
    exp = ref.paged_pool_decode_ref(q, kv[0], kv[1], sc[0], sc[1], cl,
                                    phys, log, opt_kv=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


def test_pool_decode_scattered_table():
    """Pages physically scattered across the shared pool (the refcounted
    allocator's normal state) must decode identically to contiguous
    placement with the same logical content."""
    B, P, ps, Hkv, G, D = 1, 4, 16, 2, 4, 64
    q, kv, sc, phys, log = _pool_inputs(B, P, ps, Hkv, G, D, opt_kv=True)
    cl = jnp.array([P * ps], jnp.int32)
    base = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                 opt_gqa=True)
    perm = jnp.array([3, 1, 0, 2], jnp.int32)
    kv_s = kv.at[:, perm].set(kv[:, :P])          # scatter the 4 pages
    sc_s = sc.at[:, perm].set(sc[:, :P])
    out = ops.paged_pool_decode(q, kv_s, sc_s, cl, perm[None], log,
                                opt_kv=True, opt_gqa=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(base, np.float32), atol=1e-5)


@pytest.mark.parametrize("window,sink", [(32, 1), (64, 2), (16, 0)])
def test_pool_decode_window_sweep(window, sink):
    B, P, ps = 2, 16, 16
    q, kv, sc, pt, _ = _pool_inputs(B, P, ps, 2, 4, 128, opt_kv=True)
    cl = jnp.array([P * ps, 100], jnp.int32)
    log = window_page_table(cl, P, ps, window, sink)
    phys = logical_to_physical(log, pt)
    out = ops.paged_pool_decode(q, kv, sc, cl, phys, log, opt_kv=True,
                                opt_gqa=True, window=window, sink_pages=sink)
    exp = ref.paged_pool_decode_ref(q, kv[0], kv[1], sc[0], sc[1], cl,
                                    phys, log, opt_kv=True, window=window,
                                    sink_pages=sink)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt_kv", [False, True])
@pytest.mark.parametrize("Hkv,D", [(2, 128), (1, 64), (4, 64)])
def test_cache_write_sweep(opt_kv, Hkv, D):
    B, S, P, ps = 2, 8, 8, 16
    kn = jax.random.normal(KEY, (B, S, Hkv, D), jnp.float32) \
        .astype(jnp.bfloat16)
    vn = jax.random.normal(jax.random.PRNGKey(9), (B, S, Hkv, D),
                           jnp.float32).astype(jnp.bfloat16)
    # lanes write DISJOINT global slots; -1 = SkipSet
    slots = jnp.array([[0, 5, -1, 17, 33, -1, 62, 2],
                       [64, -1, 73, 74, 75, 104, -1, 125]], jnp.int32)
    dt = jnp.float8_e4m3fn if opt_kv else jnp.bfloat16
    kv_c = jnp.zeros((2, P, ps, Hkv, D), dt)
    sc_c = jnp.zeros((2, P, ps, Hkv), jnp.float32) if opt_kv else None
    kv2, sc2 = ops.kv_cache_write(kv_c, sc_c, kn, vn, slots, opt_kv=opt_kv)

    NS = P * ps
    flat_k = kv_c[0].reshape(NS, Hkv, D)
    flat_v = kv_c[1].reshape(NS, Hkv, D)
    zeros_s = jnp.zeros((NS, Hkv))
    ek, ev, esk, esv = ref.kv_cache_write_ref(
        kn, vn, slots, flat_k, flat_v, zeros_s, zeros_s, opt_kv=opt_kv)
    got = np.asarray(kv2[0].reshape(NS, Hkv, D)[:NS - 1], np.float32)
    expd = np.asarray(ek[:NS - 1], np.float32)
    # fp8 e4m3 (3-bit mantissa): allow 1 ULP rounding skew vs the oracle
    tol = np.maximum(np.abs(expd), 1.0) * 2.0 ** -3 + 1e-6
    assert np.all(np.abs(got - expd) <= tol)
    if opt_kv:
        np.testing.assert_allclose(
            np.asarray(sc2[0].reshape(NS, Hkv)[:NS - 1]),
            np.asarray(esk[:NS - 1]), atol=1e-7)


def test_cache_write_preserves_other_lines():
    """Aliasing semantics: unwritten cache lines keep their old contents."""
    B, S, Hkv, D, P, ps = 1, 2, 1, 64, 2, 8
    old = jnp.full((2, P, ps, Hkv, D), 7.0, jnp.bfloat16)
    kn = jnp.ones((B, S, Hkv, D), jnp.bfloat16)
    slots = jnp.array([[3, -1]], jnp.int32)
    kv2, _ = ops.kv_cache_write(old, None, kn, kn, slots, opt_kv=False)
    flat = np.asarray(kv2[0].reshape(P * ps, Hkv, D), np.float32)
    assert np.all(flat[3] == 1.0)
    untouched = [i for i in range(P * ps - 1) if i != 3]
    assert np.all(flat[untouched] == 7.0)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,Hq,Hkv,D,window", [
    (128, 8, 2, 64, 0),
    (128, 8, 2, 64, 32),
    (64, 4, 1, 128, 0),        # MQA
    (256, 14, 2, 64, 0),       # odd G=7
])
def test_flash_prefill_sweep(S, Hq, Hkv, D, window):
    B = 2
    q = jax.random.normal(KEY, (B, S, Hq, D)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D)) \
        .astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D)) \
        .astype(jnp.bfloat16)
    out = ops.flash_prefill(q, k, v, window=window, block_q=64, block_k=32)
    exp = ref.flash_prefill_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt_kv,opt_gqa,window,sink", [
    (False, True, 0, 0),
    (True, True, 0, 0),
    (True, False, 0, 0),       # Original MHA semantics: KV re-streamed
    (True, True, 32, 1),       # griffin-style local window + sink
])
def test_chunk_prefill_kernel_vs_reference(opt_kv, opt_gqa, window, sink):
    """The continuation-prefill kernel (scalar-prefetched page table +
    per-row positions) matches the jnp gather reference, including -1
    page skips and decode lanes (chunk of length 1 semantics)."""
    from repro.core.coopt import CoOptConfig
    from repro.core.opt_pa import paged_chunk_attention

    B, P, ps, Hkv, G, D, S = 2, 4, 16, 2, 4, 64, 8
    qk = jax.random.normal(jax.random.PRNGKey(7), (B, S, Hkv * G, D)) \
        .astype(jnp.bfloat16)
    _, kv, sc, phys, _ = _pool_inputs(B, P, ps, Hkv, G, D, opt_kv, seed=7)
    # lane 0: continuation chunk at positions [24, 32); lane 1: a decode
    # lane — one real token at position 40, padding clamped to it — with
    # its final page unallocated (-1: never DMA'd, masked in the reference)
    positions = jnp.stack([jnp.arange(24, 32),
                           jnp.full((S,), 40)]).astype(jnp.int32)
    phys = phys.at[1, P - 1].set(-1)

    ref_cfg = CoOptConfig(opt_kv=opt_kv, opt_gqa=opt_gqa, opt_pa=True,
                          use_kernel=False)
    exp = paged_chunk_attention(qk, kv, sc, positions, phys, ref_cfg,
                                window=window, sink_pages=sink)
    out = ops.paged_chunk_prefill(qk, positions, kv, sc, phys,
                                  opt_kv=opt_kv, opt_gqa=opt_gqa,
                                  window=window, sink_pages=sink)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


def test_flash_prefill_f32():
    B, S, Hq, Hkv, D = 1, 64, 4, 2, 64
    q = jax.random.normal(KEY, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D), jnp.float32)
    out = ops.flash_prefill(q, k, v, block_q=32, block_k=32)
    exp = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)
