"""Sharded KV pool: page-range ownership along the mesh (pod, data) axes,
shard-affine admission with prefix-affinity placement, per-shard preemption,
and bit-identical greedy serving vs the single-shard pool.

The BlockManager partition is pure host-side Python, so most tests run on a
single device; the mesh-gated test at the bottom exercises a real
(data=4, model=2) simulated mesh when the process was started with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI mesh-matrix
job).
"""
import jax
import numpy as np
import pytest

from repro.cache.block_manager import BlockManager, OutOfBlocks
from repro.configs import get_config
from repro.core.coopt import MODES, ORIGINAL
from repro.core.opt_kv import padded_pool_pages, shard_page_ranges
from repro.serving import Engine, EngineConfig, Request

CFG = get_config("qwen3-4b-reduced")


def _prompt(rng, n):
    return rng.integers(0, CFG.vocab_size, n, dtype=np.int32)


# ------------------------------------------------------------- partition --
def test_shard_ranges_tile_pages_axis():
    """Host page ranges are contiguous, disjoint, cover the pool, and line
    up with the device pages-axis shard boundaries (the final sentinel page
    comes out of the LAST shard's device range only)."""
    p_dev = padded_pool_pages(4 * 8, 4)
    assert p_dev == 32
    ranges = shard_page_ranges(p_dev - 1, 4)
    assert ranges == [(0, 8), (8, 16), (16, 24), (24, 31)]
    span = p_dev // 4
    for s, (lo, hi) in enumerate(ranges):
        assert lo == s * span                      # device shard boundary
        assert hi <= (s + 1) * span
    assert padded_pool_pages(30, 4) == 32          # rounds up
    assert padded_pool_pages(32, 1) == 32          # single shard: unchanged


def test_allocation_stays_in_shard_and_oob_is_per_shard():
    m = BlockManager(31, page_size=64, num_shards=4)
    pages, _ = m.allocate(1, 100, shard=2)
    assert all(16 <= p < 24 for p in pages)
    assert m.seq_shard(1) == 2 and m.shard_of(pages[0]) == 2
    m.allocate(2, 64 * 6, shard=2)                 # exhaust shard 2
    with pytest.raises(OutOfBlocks) as ei:
        m.allocate(3, 64, shard=2)
    assert ei.value.shard == 2
    # other shards remain fully allocatable
    assert m.free_pages_in(0) == 8
    assert m.can_allocate(64 * 8, shard=0)
    # append_token only draws from the sequence's own shard
    m.allocate(4, 64, shard=1)
    for _ in range(65):
        slot = m.append_token(4)
    assert 8 * 64 <= slot < 16 * 64


def test_prefix_cache_is_shard_local():
    """A committed prefix is only reusable on its own shard; the
    preferred_shard placement hint names where the chain-hash head lives."""
    m = BlockManager(16, page_size=4, num_shards=2)
    toks = list(range(9))                          # 2 full pages + 1
    m.allocate(1, 9, token_ids=toks, shard=0)
    m.commit_prefill(1, 9, token_ids=toks)
    assert m.preferred_shard(toks, 9) == 0
    _, cached_same = m.allocate(2, 9, token_ids=toks, shard=0)
    _, cached_other = m.allocate(3, 9, token_ids=toks, shard=1)
    assert cached_same == 8 and cached_other == 0
    assert m.preferred_shard(list(range(100, 109)), 9) is None


def test_per_shard_accounting_sums_to_totals():
    m = BlockManager(31, page_size=64, num_shards=4)
    m.allocate(1, 100, shard=0)
    m.allocate(2, 300, shard=3)
    assert sum(m.free_pages_in(s) for s in range(4)) == m.free_pages
    assert sum(m.pages_in_use_in(s) for s in range(4)) == m.pages_in_use
    assert sum(m.shard_capacity(s) for s in range(4)) == m.num_pages
    assert m.pages_in_use_in(0) == 2 and m.pages_in_use_in(3) == 5
    assert m.pages_in_use_in(1) == m.pages_in_use_in(2) == 0


# ------------------------------------------------------- engine, sharded --
def test_sharded_engine_bit_identical_greedy_and_shard_local_tables():
    """Acceptance: the sharded pool serves bit-identical greedy outputs to
    the single-shard pool, and at every step no lane's page table contains a
    page outside its request's shard range."""
    rng = np.random.default_rng(0)
    prompts = [_prompt(rng, n) for n in (30, 70, 15, 90)]

    def run(ns):
        eng = Engine(CFG, MODES["coopt"],
                     EngineConfig(num_lanes=4, max_len=256,
                                  prefill_buckets=(16, 32, 64, 128, 256),
                                  num_shards=ns))
        reqs = [Request(req_id=i, prompt=p, max_new_tokens=6,
                        arrival_time=float(i))
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.add_request(r)
        mgr = eng.scheduler.manager
        while eng.scheduler.has_work:
            eng.step()
            for r in eng.scheduler.running.values():
                lo, hi = mgr.shard_ranges[r.shard]
                table = eng.scheduler.page_table(r)
                live = table[table >= 0]
                assert np.all((live >= lo) & (live < hi)), \
                    f"cross-shard page in lane table: {table} vs [{lo},{hi})"
        return [r.output for r in reqs], eng.stats

    out1, _ = run(1)
    out8, s8 = run(8)
    assert out1 == out8
    assert s8.num_shards == 8 and len(s8.shard_pages) == 8
    assert sum(s8.shard_pages) == s8.pool_pages
    assert len(s8.shard_utilization()) == 8
    assert max(s8.peak_shard_pages_in_use) > 0


def test_least_loaded_placement_spreads_requests():
    eng = Engine(CFG, MODES["coopt"],
                 EngineConfig(num_lanes=4, max_len=256,
                              prefill_buckets=(16, 32, 64, 128, 256),
                              num_shards=4))
    rng = np.random.default_rng(7)
    reqs = [Request(req_id=i, prompt=_prompt(rng, 40), max_new_tokens=4,
                    arrival_time=float(i)) for i in range(4)]
    for r in reqs:
        eng.add_request(r)
    eng.step()
    # four equal cold requests land on four distinct shards
    assert sorted(r.shard for r in reqs) == [0, 1, 2, 3]
    eng.run()


def test_per_shard_pressure_preempts_youngest_on_that_shard():
    """Satellite: fill one shard while the other is empty — the YOUNGEST
    request on the pressured shard is preempted (not the oldest, not a
    request on another shard), resumes greedy-exact, and the cross-shard
    re-placement is counted as a placement miss in EngineStats."""
    rng = np.random.default_rng(2)
    shared = _prompt(rng, 64)                     # one full shared page
    pa = np.concatenate([shared, _prompt(rng, 6)])
    pb = np.concatenate([shared, _prompt(rng, 8)])

    def mk(ns, lanes):
        return Engine(CFG, ORIGINAL,                # bf16: bit-stable resume
                      EngineConfig(num_lanes=lanes, max_len=256,
                                   prefill_buckets=(16, 32, 64, 128, 256),
                                   num_shards=ns))

    def run(eng):
        a = Request(req_id=1, prompt=pa, max_new_tokens=120, arrival_time=0.0)
        b = Request(req_id=2, prompt=pb, max_new_tokens=100, arrival_time=1.0)
        eng.add_request(a)
        eng.step()            # A prefills fully; its page-0 hash commits
        eng.add_request(b)    # prefix affinity pins B to A's shard
        eng.run()
        return a, b

    # 2 shards of a (2 lanes x 4 pages) pool: shard 0 = 4 pages, shard 1 = 3
    eng = mk(2, lanes=2)
    a, b = run(eng)
    s = eng.stats
    assert a.shard == 0 and s.placement_prefix_hits >= 1  # B joined shard 0
    assert s.shard_preemptions[0] >= 1 and s.shard_preemptions[1] == 0
    assert b.num_preemptions >= 1 and a.num_preemptions == 0  # youngest hit
    assert s.placement_misses >= 1      # B re-placed off its prefix's shard
    assert len(a.output) == 120 and len(b.output) == 100

    # greedy-exact resume: identical tokens vs an unpressured engine
    a2, b2 = run(mk(1, lanes=3))
    assert a.output == a2.output and b.output == b2.output


def test_request_larger_than_shard_rejected():
    """A request is pinned to ONE shard, so the largest shard's page range
    caps what is servable — beyond it the request is REJECTED up front
    instead of live-locking in preempt/retry."""
    eng = Engine(CFG, MODES["coopt"],
                 EngineConfig(num_lanes=4, max_len=512,
                              prefill_buckets=(16, 32, 64, 128, 512),
                              num_shards=8))
    # shard capacity = 4*8/8 = 4 pages = 256 tokens < 300 + 8
    r = Request(req_id=1, prompt=_prompt(np.random.default_rng(3), 300),
                max_new_tokens=8)
    eng.add_request(r)
    eng.run()
    assert eng.stats.rejected == 1 and r.output == []


# ------------------------------------------------------------ mesh-gated --
@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (CI mesh-matrix job)")
def test_sharded_pool_on_simulated_mesh_bit_identical():
    """On a real (data=4, model=2) simulated mesh: the engine (handed the
    mesh directly) derives the matching host page-range partition, places
    the device cache pages-sharded, and serves bit-identical greedy outputs
    vs the unsharded single-device engine — here on the jnp (GSPMD)
    reference path; the kernel path's analogue lives in
    tests/test_sharded_kernels.py."""
    from repro.launch.mesh import kv_shard_count, make_sim_mesh

    mesh = make_sim_mesh(data=4, model=2)
    ns = kv_shard_count(mesh)
    assert ns == 4

    rng = np.random.default_rng(11)
    prompts = [_prompt(rng, n) for n in (30, 70, 45)]
    ecfg = EngineConfig(num_lanes=4, max_len=256,
                        prefill_buckets=(16, 32, 64, 128, 256))

    ref = Engine(CFG, MODES["coopt"], ecfg)
    out_ref = ref.generate(prompts, max_new_tokens=5)

    eng = Engine(CFG, MODES["coopt"], ecfg, mesh=mesh)  # shards derived
    assert eng.ecfg.num_shards == ns
    out_mesh = eng.generate(prompts, max_new_tokens=5)
    assert out_ref == out_mesh
    assert eng.stats.num_shards == ns
