"""Data pipelines: determinism, length statistics, trainability."""
import numpy as np

from repro.data import RequestStream, TrainPipeline, sharegpt_stream


def test_request_stream_deterministic():
    a = sharegpt_stream(1000, 5, seed=42)
    b = sharegpt_stream(1000, 5, seed=42)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.prompt, y.prompt)
        assert x.max_new_tokens == y.max_new_tokens


def test_request_lengths_plausible():
    reqs = sharegpt_stream(1000, 200, seed=0)
    plens = np.array([r.prompt_len for r in reqs])
    assert plens.min() >= 2 and plens.max() <= 2048
    med = np.median(plens)
    assert 60 <= med <= 400       # ShareGPT-ish median


def test_scale_shrinks_lengths():
    big = sharegpt_stream(1000, 50, seed=1, scale=1.0)
    small = sharegpt_stream(1000, 50, seed=1, scale=0.1)
    assert np.median([r.prompt_len for r in small]) < \
        np.median([r.prompt_len for r in big])


def test_train_pipeline_shapes_and_structure():
    p = TrainPipeline(vocab_size=128, batch=4, seq_len=16, seed=0)
    b = p.next_batch()
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
    # labels are next-token shifted
    b2 = p.next_batch()
    assert not np.array_equal(b["tokens"], b2["tokens"])


def test_train_pipeline_learnable_structure():
    """85% of transitions follow the fixed bigram table => the conditional
    entropy is well below log(V)."""
    p = TrainPipeline(vocab_size=64, batch=8, seq_len=256, seed=3)
    b = p.next_batch()
    toks, labels = b["tokens"], b["labels"]
    follows = 0
    for bb in range(8):
        succ = p._succ[toks[bb]]
        follows += np.mean(np.any(succ == labels[bb][:, None], axis=1))
    assert follows / 8 > 0.8
