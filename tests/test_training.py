"""Training substrate: AdamW semantics, loss descent, MoE aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import TrainPipeline
from repro.training import Trainer, adamw_init, adamw_update


def test_adamw_moves_against_gradient():
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.ones((4,), jnp.float32)}
    st = adamw_init(p)
    p2, st2, gn = adamw_update(p, g, st, lr=0.1, weight_decay=0.0)
    assert np.all(np.asarray(p2["w"]) < 1.0)
    assert float(gn) == pytest.approx(2.0)
    assert int(st2.step) == 1


def test_grad_clip_bounds_update():
    p = {"w": jnp.zeros((2,), jnp.float32)}
    g = {"w": jnp.full((2,), 1e6, jnp.float32)}
    st = adamw_init(p)
    p2, _, _ = adamw_update(p, g, st, lr=0.1, grad_clip=1.0,
                            weight_decay=0.0)
    assert np.all(np.abs(np.asarray(p2["w"])) <= 0.11)


def test_weight_decay_shrinks_weights():
    p = {"w": jnp.full((4,), 10.0, jnp.float32)}
    g = {"w": jnp.zeros((4,), jnp.float32)}
    st = adamw_init(p)
    p2, _, _ = adamw_update(p, g, st, lr=0.1, weight_decay=0.5)
    assert np.all(np.asarray(p2["w"]) < 10.0)


@pytest.mark.parametrize("arch", ["qwen3-4b-reduced", "rwkv6-7b-reduced"])
def test_loss_decreases(arch):
    cfg = get_config(arch)
    tr = Trainer(cfg, lr=2e-3)
    pipe = TrainPipeline(cfg.vocab_size, batch=4, seq_len=48, seed=0)
    hist = tr.fit(pipe, steps=20, log=None)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_moe_aux_losses_present():
    cfg = get_config("mixtral-8x22b-reduced")
    tr = Trainer(cfg, lr=1e-3)
    pipe = TrainPipeline(cfg.vocab_size, batch=2, seq_len=32, seed=0)
    m = tr.step(next(iter(pipe)))
    assert "load_balance" in m and m["load_balance"] > 0
    assert "router_z" in m
    assert m["loss"] >= m["nll"]
