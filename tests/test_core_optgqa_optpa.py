"""Opt-GQA (Eq. 7/8) and Opt-Pa (Eq. 9/10) numerics over the GLOBAL pool."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coopt import CoOptConfig, MODES
from repro.core.opt_gqa import fold_queries, group_index, mha_to_gqa, \
    unfold_outputs
from repro.core.opt_kv import identity_page_table
from repro.core.opt_pa import effective_page_group, paged_decode_attention
from repro.cache.quant import quantize_fp8
from repro.models.layers import causal_attention, repeat_kv


# ------------------------------------------------------------- Opt-GQA -----
def test_group_index_eq7():
    # H_q = 8, H_k = 2 -> H_g = 4; head i maps to group i // 4
    assert [group_index(i, 8, 2) for i in range(8)] == [0] * 4 + [1] * 4


def test_fold_unfold_roundtrip():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    assert jnp.all(unfold_outputs(fold_queries(q, 2)) == q)


def test_mha_to_gqa_mean_pools():
    wk = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8)  # d=4, Hq=4, D=2
    pk, _ = mha_to_gqa(wk, wk, num_kv_heads=2, head_dim=2)
    assert pk.shape == (4, 4)
    # group 0 = heads {0,1}: mean of cols (0,1) and (2,3)
    np.testing.assert_allclose(np.asarray(pk[:, 0]),
                               np.asarray((wk[:, 0] + wk[:, 2]) / 2))


def test_grouped_equals_expanded_attention():
    """Opt-GQA restructuring is numerically identical to MHA over
    duplicated KV heads (the paper's accuracy-preservation claim)."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 32, 8, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 2, 16), jnp.float32)
    grouped = causal_attention(q, k, v)
    expanded = causal_attention(q, repeat_kv(k, 4), repeat_kv(v, 4))
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(expanded),
                               atol=1e-5)


# ------------------------------------------------------------- Opt-Pa ------
def _paged(B=2, P=8, ps=16, Hq=8, Hkv=2, D=32, opt_kv=False, seed=0):
    """Global pool holding B lanes x P pages each (lane-identity layout)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    PT = B * P
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (PT, ps, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (PT, ps, Hkv, D), jnp.float32)
    if opt_kv:
        kq, ksc = quantize_fp8(k)
        vq, vsc = quantize_fp8(v)
        return q, jnp.stack([kq, vq]), jnp.stack([ksc, vsc])
    return q, jnp.stack([k, v]).astype(jnp.bfloat16), None


@settings(max_examples=10, deadline=None)
@given(cache_len=st.integers(1, 128), seed=st.integers(0, 100))
def test_blockwise_softmax_equals_flat(cache_len, seed):
    """Eq. 10 online block-wise softmax == flat softmax, any context len."""
    q, kv, sc = _paged(seed=seed)
    cl = jnp.array([cache_len, max(cache_len // 2, 1)], jnp.int32)
    flat = paged_decode_attention(q, kv, sc, cl,
                                  coopt=CoOptConfig(opt_pa=False))
    blk = paged_decode_attention(q, kv, sc, cl,
                                 coopt=CoOptConfig(opt_pa=True, page_group=2))
    np.testing.assert_allclose(np.asarray(flat, np.float32),
                               np.asarray(blk, np.float32), atol=2e-2)


def test_all_modes_agree_bf16():
    """The five paper modes are schedules, not approximations (fp8 aside):
    original / opt-gqa / opt-pa must agree to bf16 tolerance."""
    q, kv, sc = _paged()
    cl = jnp.array([100, 37], jnp.int32)
    outs = {}
    for name in ("original", "opt-gqa", "opt-pa"):
        outs[name] = np.asarray(paged_decode_attention(
            q, kv, sc, cl, coopt=MODES[name]), np.float32)
    np.testing.assert_allclose(outs["original"], outs["opt-gqa"], atol=2e-2)
    np.testing.assert_allclose(outs["original"], outs["opt-pa"], atol=2e-2)


def test_effective_page_group_pads_instead_of_degrading():
    """Regression: a page_group that does not divide P used to be halved
    all the way to 1 — a silent per-page scan with none of Eq. 10's block
    reduction. The page axis is now PADDED (masked) to the next multiple,
    keeping the configured group."""
    assert effective_page_group(8, 3) == (3, 9)     # pad 8 -> 9, group 3
    assert effective_page_group(8, 8) == (8, 8)     # divides: no pad
    assert effective_page_group(2, 8) == (2, 2)     # clamped to pool size
    assert effective_page_group(7, 4) == (4, 8)
    assert effective_page_group(1, 8) == (1, 1)


def test_blockwise_nondividing_page_group_matches_flat():
    """Numerics with the padded page axis: page_group=3 over an 8-page lane
    must equal the flat softmax (the pad pages are fully masked)."""
    q, kv, sc = _paged()
    cl = jnp.array([100, 37], jnp.int32)
    flat = paged_decode_attention(q, kv, sc, cl,
                                  coopt=CoOptConfig(opt_pa=False))
    blk = paged_decode_attention(
        q, kv, sc, cl, coopt=CoOptConfig(opt_pa=True, page_group=3))
    np.testing.assert_allclose(np.asarray(flat, np.float32),
                               np.asarray(blk, np.float32), atol=2e-2)


def test_explicit_page_table_matches_identity_default():
    """Passing the lane-identity table explicitly == the default."""
    q, kv, sc = _paged()
    cl = jnp.array([100, 37], jnp.int32)
    pt = identity_page_table(2, kv.shape[1])
    a = paged_decode_attention(q, kv, sc, cl, coopt=MODES["opt-pa"])
    b = paged_decode_attention(q, kv, sc, cl, coopt=MODES["opt-pa"],
                               page_table=pt)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_permuted_page_table_matches_contiguous():
    """A lane whose pages are scattered across the pool (the whole point of
    the shared allocator) must attend identically to a contiguous lane with
    the same logical content."""
    B, P, ps, Hq, Hkv, D = 1, 4, 16, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    pages_k = jax.random.normal(ks[1], (P, ps, Hkv, D), jnp.float32)
    pages_v = jax.random.normal(ks[2], (P, ps, Hkv, D), jnp.float32)
    perm = [2, 0, 3, 1]                       # physical placement
    scat_k = jnp.zeros((8, ps, Hkv, D)).at[jnp.array(perm)].set(pages_k)
    scat_v = jnp.zeros((8, ps, Hkv, D)).at[jnp.array(perm)].set(pages_v)
    cl = jnp.array([P * ps], jnp.int32)
    a = paged_decode_attention(
        q, jnp.stack([pages_k, pages_v]).astype(jnp.bfloat16), None, cl,
        coopt=MODES["opt-pa"],
        page_table=jnp.arange(P, dtype=jnp.int32)[None])
    b = paged_decode_attention(
        q, jnp.stack([scat_k, scat_v]).astype(jnp.bfloat16), None, cl,
        coopt=MODES["opt-pa"],
        page_table=jnp.array(perm, jnp.int32)[None])
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)


def test_fp8_mode_close_to_bf16():
    q, kvq, scq = _paged(opt_kv=True)
    _, kvb, _ = _paged(opt_kv=False)
    cl = jnp.array([128, 64], jnp.int32)
    a = paged_decode_attention(q, kvb, None, cl, coopt=MODES["original"])
    b = paged_decode_attention(q, kvq, scq, cl, coopt=MODES["coopt"])
    # fp8 K/V perturbs attention outputs by O(2^-3) of value scale
    err = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
    assert err.max() < 0.25, err.max()


def test_window_policy_matches_dense_when_window_covers_all():
    """Window >= context => block-sparse result == dense result."""
    q, kv, sc = _paged(P=4)
    cl = jnp.array([64, 40], jnp.int32)
    dense = paged_decode_attention(q, kv, sc, cl, coopt=MODES["original"])
    win = paged_decode_attention(q, kv, sc, cl, coopt=MODES["original"],
                                 window=4 * 16, sink_pages=1)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(win, np.float32), atol=2e-2)


def test_window_policy_drops_middle_tokens():
    """With a small window, only {sink + recent window} tokens attend."""
    B, P, ps, Hq, Hkv, D = 1, 8, 16, 4, 1, 32
    q = jnp.ones((B, Hq, D), jnp.float32)
    k = jnp.zeros((P, ps, Hkv, D))
    # middle token with huge key would dominate IF not skipped
    k = k.at[3, 0].set(100.0)
    v = jnp.ones_like(k)
    kv = jnp.stack([k, v]).astype(jnp.bfloat16)
    cl = jnp.array([128], jnp.int32)
    out = paged_decode_attention(q, kv, None, cl, coopt=MODES["original"],
                                 window=32, sink_pages=1)
    # all values are 1 where attended; the spike token is outside the window
    np.testing.assert_allclose(np.asarray(out, np.float32), 1.0, atol=1e-2)
